//! Property-based tests (seeded generator harness from
//! `util::proptest`; the proptest crate is unavailable offline) plus
//! corruption/failure-injection sweeps: randomly damaged inputs must
//! produce errors, never panics or silent wrong answers.

use av_simd::bag::{BagReader, BagWriter, Compression, MemoryChunkedFile};
use av_simd::engine::{PlayedRecord, SimContext, TaskOutput, TaskSpec};
use av_simd::msg::{Image, Message, PointCloud, Time};
use av_simd::pipe::{deserialize_stream, serialize_stream, PipeItem};
use av_simd::util::proptest::{check, check_n, gen};
use av_simd::util::prng::Prng;

// ---------- codecs ----------

#[test]
fn prop_pipe_stream_roundtrip() {
    check("pipe stream roundtrip", |rng| {
        gen::vec_of(rng, 20, |r| match r.below(4) {
            0 => PipeItem::Str(gen::ident(r, 24)),
            1 => PipeItem::I64(r.next_u64() as i64),
            2 => PipeItem::Bytes(gen::bytes(r, 512)),
            _ => PipeItem::File { name: gen::ident(r, 16), content: gen::bytes(r, 256) },
        })
    }, |items| {
        deserialize_stream(&serialize_stream(items)).unwrap() == *items
    });
}

#[test]
fn prop_task_spec_roundtrip() {
    check("task spec roundtrip", |rng| random_spec(rng), |spec| {
        TaskSpec::decode(&spec.encode()).unwrap() == *spec
    });
}

fn random_spec(rng: &mut Prng) -> TaskSpec {
    use av_simd::engine::{Action, OpCall, Source};
    let source = match rng.below(5) {
        0 => Source::Inline {
            records: gen::vec_of(rng, 8, |r| gen::bytes(r, 64)),
        },
        1 => Source::BagFile {
            data: if rng.next_bool(0.5) {
                av_simd::engine::DataRef::path(gen::ident(rng, 32))
            } else {
                let mut id = [0u8; 32];
                rng.fill_bytes(&mut id);
                av_simd::engine::DataRef::Manifest {
                    id: av_simd::storage::ManifestId(id),
                    peer: format!("{}:{}", gen::ident(rng, 8), 1 + rng.below(65_000)),
                }
            },
            topics: gen::vec_of(rng, 3, |r| gen::ident(r, 12)),
        },
        2 => Source::SynthFrames {
            seed: rng.next_u64(),
            count: rng.next_u32() % 100,
            width: 1 + rng.next_u32() % 64,
            height: 1 + rng.next_u32() % 64,
        },
        3 => Source::Scenarios {
            scenarios: gen::vec_of(rng, 8, |r| {
                let speed = r.range_f64(1.0, 30.0);
                av_simd::sim::encode_scenario(&av_simd::sim::random_scenario(r, speed))
            }),
        },
        _ => {
            let start = rng.below(1000);
            Source::Range { start, end: start + rng.below(1000) }
        }
    };
    let action = match rng.below(4) {
        0 => Action::Collect,
        1 => Action::Count,
        2 => Action::Episodes,
        _ => Action::SaveBag {
            dir: gen::ident(rng, 16),
            topic: gen::ident(rng, 12),
            type_name: gen::ident(rng, 12),
        },
    };
    TaskSpec {
        job_id: rng.next_u64(),
        task_id: rng.next_u32(),
        attempt: rng.next_u32() % 4,
        source,
        ops: gen::vec_of(rng, 4, |r| OpCall::new(gen::ident(r, 10), gen::bytes(r, 32))),
        action,
    }
}

#[test]
fn prop_played_record_roundtrip() {
    check("played record roundtrip", |rng| PlayedRecord {
        topic: format!("/{}", gen::ident(rng, 16)),
        type_name: gen::ident(rng, 16),
        time: Time::from_nanos(rng.next_u64()),
        data: gen::bytes(rng, 1024),
    }, |p| PlayedRecord::decode(&p.encode()).unwrap() == *p);
}

#[test]
fn prop_message_roundtrips() {
    check("image roundtrip", |rng| {
        Image::synthetic(1 + rng.next_u32() % 48, 1 + rng.next_u32() % 48, rng.next_u64())
    }, |img| Image::decode(&img.encode()).unwrap() == *img);
    check("pointcloud roundtrip", |rng| {
        PointCloud::synthetic(rng.below(512) as usize, rng.next_u64())
    }, |pc| PointCloud::decode(&pc.encode()).unwrap() == *pc);
}

// ---------- bag invariants ----------

fn random_bag_messages(rng: &mut Prng) -> Vec<(String, Time, Vec<u8>)> {
    let topics = ["/camera", "/lidar", "/imu"];
    gen::vec_of(rng, 40, |r| {
        (
            topics[r.below(3) as usize].to_string(),
            Time::from_nanos(r.below(1_000_000)),
            gen::bytes(r, 600),
        )
    })
}

#[test]
fn prop_bag_preserves_every_message_in_time_order() {
    check("bag roundtrip ordered", random_bag_messages, |msgs| {
        let mut w = BagWriter::new(
            MemoryChunkedFile::new(),
            Compression::None,
            2048, // small chunks: force multi-chunk bags
        )
        .unwrap();
        for (topic, t, data) in msgs {
            w.write_raw(topic, "raw", *t, data.clone()).unwrap();
        }
        let mut r = BagReader::open(w.finish().unwrap()).unwrap();
        let played = r.play(None).unwrap();
        if played.len() != msgs.len() {
            return false;
        }
        // time order
        if !played.windows(2).all(|p| p[0].time <= p[1].time) {
            return false;
        }
        // multiset equality of (topic, time, payload)
        let mut a: Vec<_> = msgs
            .iter()
            .map(|(tp, t, d)| (tp.clone(), *t, d.clone()))
            .collect();
        let mut b: Vec<_> = played
            .into_iter()
            .map(|m| (m.topic, m.time, m.data))
            .collect();
        a.sort();
        b.sort();
        a == b
    });
}

#[test]
fn prop_deflate_bag_equals_plain_bag_content() {
    check_n("deflate == none content", 24, random_bag_messages, |msgs| {
        let build = |c: Compression| {
            let mut w = BagWriter::new(MemoryChunkedFile::new(), c, 4096).unwrap();
            for (topic, t, data) in msgs {
                w.write_raw(topic, "raw", *t, data.clone()).unwrap();
            }
            let mut r = BagReader::open(w.finish().unwrap()).unwrap();
            r.play(None).unwrap()
        };
        build(Compression::None) == build(Compression::Deflate)
    });
}

#[test]
fn prop_corrupted_bag_errors_but_never_panics() {
    check_n("bag corruption safety", 48, |rng| {
        let msgs = random_bag_messages(rng);
        let mut w =
            BagWriter::new(MemoryChunkedFile::new(), Compression::None, 2048).unwrap();
        for (topic, t, data) in &msgs {
            w.write_raw(topic, "raw", *t, data.clone()).unwrap();
        }
        let bytes = w.finish().unwrap().to_vec();
        let pos = rng.below(bytes.len() as u64) as usize;
        let bit = 1u8 << rng.below(8);
        (bytes, pos, bit, msgs.len())
    }, |(bytes, pos, bit, n_msgs)| {
        let mut corrupt = bytes.clone();
        corrupt[*pos] ^= bit;
        // Either the bag fails to open / play (detected corruption), or —
        // if the flip hit dead padding — replays the exact message count.
        match BagReader::open(MemoryChunkedFile::from_bytes(&corrupt)) {
            Err(_) => true,
            Ok(mut r) => match r.play(None) {
                Err(_) => true,
                Ok(msgs) => msgs.len() == *n_msgs,
            },
        }
    });
}

#[test]
fn prop_corrupted_pipe_stream_never_panics() {
    check_n("pipe corruption safety", 64, |rng| {
        let items = gen::vec_of(rng, 8, |r| PipeItem::Bytes(gen::bytes(r, 128)));
        let buf = serialize_stream(&items);
        let pos = rng.below(buf.len() as u64) as usize;
        (buf, pos)
    }, |(buf, pos)| {
        let mut corrupt = buf.clone();
        corrupt[*pos] ^= 0xff;
        // must not panic; Ok is allowed when the flip is benign
        let _ = deserialize_stream(&corrupt);
        true
    });
}

#[test]
fn prop_truncated_task_spec_never_panics() {
    check_n("spec truncation safety", 64, |rng| {
        let spec = random_spec(rng);
        let buf = spec.encode();
        let cut = rng.below(buf.len() as u64) as usize;
        (buf, cut)
    }, |(buf, cut)| {
        let _ = TaskSpec::decode(&buf[..*cut]);
        true
    });
}

// ---------- engine invariants ----------

#[test]
fn prop_collect_is_partition_order_independent_multiset() {
    let sc = SimContext::local(3);
    check_n("parallelize/collect multiset identity", 16, |rng| {
        let records = gen::vec_of(rng, 50, |r| gen::bytes(r, 40));
        let partitions = 1 + rng.below(7) as usize;
        (records, partitions)
    }, |(records, partitions)| {
        let mut out = sc.parallelize(records.clone(), *partitions).collect().unwrap();
        let mut expect = records.clone();
        out.sort();
        expect.sort();
        out == expect
    });
}

#[test]
fn prop_count_equals_collect_len() {
    let sc = SimContext::local(2);
    check_n("count == collect.len", 12, |rng| {
        (rng.below(500), 1 + rng.below(6) as usize)
    }, |(n, _parts)| {
        let rdd = sc.range(*n);
        rdd.count().unwrap() == rdd.collect().unwrap().len() as u64
    });
}

#[test]
fn prop_scenario_and_result_codecs_total() {
    check("scenario codec", |rng| {
        let speed = rng.range_f64(5.0, 25.0);
        av_simd::sim::random_scenario(rng, speed)
    }, |s| {
        av_simd::sim::decode_scenario(&av_simd::sim::encode_scenario(s)).unwrap() == *s
    });
    check("episode result codec", random_episode_result, |r| {
        av_simd::sim::decode_result(&av_simd::sim::encode_result(r)).unwrap() == *r
    });
}

fn random_episode_result(rng: &mut Prng) -> av_simd::sim::EpisodeResult {
    // min_ttc/min_gap are INFINITY when no closing lead was ever seen —
    // the codec must round-trip the infinities too (but never sees NaN:
    // episodes are pure arithmetic on finite state).
    let maybe_inf = |rng: &mut Prng, lo: f64, hi: f64| {
        if rng.next_bool(0.2) { f64::INFINITY } else { rng.range_f64(lo, hi) }
    };
    av_simd::sim::EpisodeResult {
        scenario_id: format!("{}-x", gen::ident(rng, 24)),
        passed: rng.next_bool(0.5),
        collided: rng.next_bool(0.3),
        min_ttc: maybe_inf(rng, 0.0, 60.0),
        min_gap: maybe_inf(rng, -5.0, 100.0),
        max_brake: rng.range_f64(0.0, 10.0),
        emergency_ticks: rng.next_u32() % 1000,
        ticks: rng.next_u32() % 10_000,
    }
}

#[test]
fn prop_corrupted_scenario_and_result_records_never_panic() {
    check_n("scenario/result corruption safety", 64, |rng| {
        let speed = rng.range_f64(5.0, 25.0);
        let s = av_simd::sim::random_scenario(rng, speed);
        let mut buf = if rng.next_bool(0.5) {
            av_simd::sim::encode_scenario(&s)
        } else {
            av_simd::sim::encode_result(&random_episode_result(rng))
        };
        let pos = rng.below(buf.len() as u64) as usize;
        buf[pos] ^= 1 << rng.below(8);
        let cut = rng.below(buf.len() as u64 + 1) as usize;
        buf.truncate(cut);
        buf
    }, |buf| {
        // decode may fail (detected corruption) or succeed (benign flip),
        // but must never panic
        let _ = av_simd::sim::decode_scenario(buf);
        let _ = av_simd::sim::decode_result(buf);
        true
    });
}

// ---------- scenario matrix / sweep expansion invariants ----------

#[test]
fn prop_scenario_matrix_invariants_hold_across_ego_speeds() {
    check("matrix invariants", |rng| rng.range_f64(0.5, 40.0), |speed| {
        let m = av_simd::sim::scenario_matrix(*speed);
        // 8 x 3 x 3 = 72 minus the 6 unwanted non-interacting cases
        if m.len() != 66 {
            return false;
        }
        // every case keeps the requested speed and passes the filter
        if !m.iter().all(|s| s.ego_speed == *speed && s.is_interesting()) {
            return false;
        }
        // ids are unique
        let mut ids: Vec<String> = m.iter().map(|s| s.id()).collect();
        ids.sort();
        ids.dedup();
        ids.len() == 66
    });
}

fn random_sweep_spec(rng: &mut Prng) -> av_simd::sim::SweepSpec {
    av_simd::sim::SweepSpec {
        ego_speeds: gen::vec_of(rng, 3, |r| r.range_f64(5.0, 25.0)),
        dts: gen::vec_of(rng, 2, |r| r.range_f64(0.02, 0.2)),
        seeds: gen::vec_of(rng, 3, |r| r.next_u64()),
        speed_jitter: if rng.next_bool(0.5) { 0.0 } else { rng.range_f64(0.0, 0.2) },
        shard_size: 1 + rng.below(100) as usize,
        ..av_simd::sim::SweepSpec::default()
    }
}

#[test]
fn prop_sweep_expansion_is_deterministic_unique_and_shard_stable() {
    check_n("sweep expansion invariants", 32, random_sweep_spec, |spec| {
        let cases = spec.cases();
        if cases.len() != spec.case_count() {
            return false;
        }
        if cases != spec.cases() {
            return false; // expansion must be pure
        }
        // case ids unique even when grid values repeat
        let mut ids: Vec<String> = cases.iter().map(|c| c.case_id()).collect();
        ids.sort();
        ids.dedup();
        if ids.len() != cases.len() {
            return false;
        }
        // shards partition the case list in order, never straddling a dt
        let shards = spec.shards();
        let rejoined: Vec<_> = shards.iter().flatten().cloned().collect();
        rejoined == cases
            && shards.iter().all(|s| {
                !s.is_empty()
                    && s.len() <= spec.shard_size
                    && s.iter().all(|c| c.dt_index == s[0].dt_index)
            })
    });
}

// ---------- dynamics invariants ----------

#[test]
fn prop_dynamics_speed_bounded_and_yaw_finite() {
    use av_simd::msg::ControlCommand;
    use av_simd::sim::{step, VehicleParams, VehicleState};
    let p = VehicleParams::default();
    check("dynamics bounds", |rng| {
        let s = VehicleState::at(
            rng.range_f64(-100.0, 100.0),
            rng.range_f64(-100.0, 100.0),
            rng.range_f64(-3.2, 3.2),
            rng.range_f64(0.0, 40.0),
        );
        let cmd = ControlCommand {
            accel: rng.range_f64(-20.0, 20.0),
            steer: rng.range_f64(-2.0, 2.0),
        };
        (s, cmd)
    }, |(s, cmd)| {
        let next = step(s, cmd, &p, 0.05);
        next.v >= 0.0
            && next.v <= p.max_speed
            && next.pose.x.is_finite()
            && next.pose.y.is_finite()
            && next.pose.yaw.is_finite()
    });
}

#[test]
fn prop_collision_is_symmetric_and_reflexive() {
    use av_simd::sim::{collides, VehicleParams, VehicleState};
    let p = VehicleParams::default();
    check("collision symmetry", |rng| {
        let a = VehicleState::at(
            rng.range_f64(-10.0, 10.0),
            rng.range_f64(-10.0, 10.0),
            rng.range_f64(-3.2, 3.2),
            0.0,
        );
        let b = VehicleState::at(
            rng.range_f64(-10.0, 10.0),
            rng.range_f64(-10.0, 10.0),
            rng.range_f64(-3.2, 3.2),
            0.0,
        );
        (a, b)
    }, |(a, b)| {
        collides(a, b, &p) == collides(b, a, &p) && collides(a, a, &p)
    });
}

// ---------- storage / cache invariants ----------

#[test]
fn prop_blockstore_roundtrip_any_block_size() {
    let dir = std::env::temp_dir().join(format!(
        "av_simd_prop_store_{}_{:x}",
        std::process::id(),
        av_simd::util::now_nanos()
    ));
    let store = av_simd::storage::BlockStore::open(&dir).unwrap().with_block_size(1024);
    check_n("blockstore roundtrip", 24, |rng| {
        // object names must be path-safe (no '/'), per BlockStore rules
        (gen::ident(rng, 12).replace('/', "_"), gen::bytes(rng, 8192))
    }, |(name, data)| {
        store.put(name, data).unwrap();
        store.get(name).unwrap() == *data
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_bag_cache_never_exceeds_capacity() {
    use av_simd::bag::BagCache;
    check_n("cache capacity invariant", 16, |rng| {
        let capacity = 1000 + rng.below(4000);
        let ops = gen::vec_of(rng, 60, |r| {
            (gen::ident(r, 4), r.below(900) as usize, r.next_bool(0.3))
        });
        (capacity, ops)
    }, |(capacity, ops)| {
        let cache = BagCache::new(*capacity);
        for (key, size, is_get) in ops {
            if *is_get {
                let _ = cache.get(key);
            } else {
                let _ = cache.put(key, vec![0u8; *size]);
            }
            if cache.used_bytes() > *capacity {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_rpc_frames_roundtrip() {
    use av_simd::engine::rpc::{read_msg, write_msg, RpcMsg};
    check("rpc roundtrip", |rng| match rng.below(18) {
        0 => RpcMsg::RunTask(gen::bytes(rng, 512)),
        1 => RpcMsg::TaskOk(gen::bytes(rng, 512)),
        2 => RpcMsg::TaskErr(gen::ident(rng, 64)),
        3 => RpcMsg::Ping,
        4 => RpcMsg::Pong,
        5 => RpcMsg::Shutdown,
        6 => {
            let mut id = [0u8; 32];
            rng.fill_bytes(&mut id);
            RpcMsg::FetchManifest { id }
        }
        7 => RpcMsg::ManifestData(gen::bytes(rng, 512)),
        8 => {
            let mut manifest = [0u8; 32];
            rng.fill_bytes(&mut manifest);
            RpcMsg::FetchBlock { manifest, index: rng.next_u32() }
        }
        9 => RpcMsg::BlockData(gen::bytes(rng, 512)),
        10 => RpcMsg::FetchErr(gen::ident(rng, 64)),
        11 => RpcMsg::BlockAd {
            peer: format!("{}:{}", gen::ident(rng, 8), 1 + rng.below(65_000)),
            manifests: gen::vec_of(rng, 4, |r| {
                let mut id = [0u8; 32];
                r.fill_bytes(&mut id);
                id
            }),
        },
        12 => RpcMsg::Hello { version: rng.next_u32() },
        13 => RpcMsg::HelloOk {
            version: rng.next_u32(),
            worker_id: rng.next_u64(),
            now_ns: rng.next_u64(),
        },
        14 => RpcMsg::RunTaskTraced(gen::bytes(rng, 512)),
        15 => RpcMsg::TaskTrace(gen::bytes(rng, 512)),
        16 => RpcMsg::FetchStats,
        _ => RpcMsg::StatsData(gen::bytes(rng, 512)),
    }, |msg| {
        let mut buf = Vec::new();
        write_msg(&mut buf, msg).unwrap();
        let mut cur = &buf[..];
        read_msg(&mut cur).unwrap().unwrap() == *msg
    });
}

#[test]
fn prop_task_output_roundtrip() {
    check("task output roundtrip", |rng| match rng.below(3) {
        0 => TaskOutput::Records(gen::vec_of(rng, 10, |r| gen::bytes(r, 100))),
        1 => TaskOutput::Count(rng.next_u64()),
        _ => TaskOutput::Episodes(gen::vec_of(rng, 10, |r| {
            av_simd::sim::encode_result(&random_episode_result(r))
        })),
    }, |o| TaskOutput::decode(&o.encode()).unwrap() == *o);
}

// ---------- fuzz wire types (spec, coverage, corpus, shrink log) ----------

use av_simd::sim::{
    CorpusEntry, CoverageMap, Dim, FuzzCase, FuzzSpec, FuzzVerdict, ShrinkLog, ShrinkStep,
};

fn random_dim(rng: &mut Prng) -> Dim {
    Dim::ALL[rng.below(Dim::ALL.len() as u64) as usize]
}

fn random_fuzz_case(rng: &mut Prng) -> FuzzCase {
    let base = av_simd::sim::random_scenario(rng, rng.range_f64(2.0, 30.0));
    let n = rng.below(4) as usize;
    let mut mutations: Vec<(Dim, f64)> = Vec::new();
    while mutations.len() < n {
        let dim = random_dim(rng);
        if mutations.iter().any(|(d, _)| *d == dim) {
            continue;
        }
        let (lo, hi) = dim.range();
        let v = if dim.is_discrete() {
            rng.below(hi as u64) as f64
        } else {
            rng.range_f64(lo, hi)
        };
        mutations.push((dim, v));
    }
    FuzzCase { base, mutations }
}

fn random_fuzz_verdict(rng: &mut Prng) -> FuzzVerdict {
    // min_gap / min_ttc / aeb_trigger are +inf when the episode never
    // interacted — the codec must round-trip infinities
    let maybe_inf = |rng: &mut Prng, lo: f64, hi: f64| {
        if rng.next_bool(0.2) { f64::INFINITY } else { rng.range_f64(lo, hi) }
    };
    FuzzVerdict {
        collided: rng.next_bool(0.3),
        passed: rng.next_bool(0.5),
        min_gap: maybe_inf(rng, -2.0, 30.0),
        min_ttc: maybe_inf(rng, 0.0, 60.0),
        aeb_trigger: maybe_inf(rng, 0.0, 12.0),
        divergence: rng.range_f64(0.0, 8.0),
        ticks: rng.next_u32() % 10_000,
    }
}

fn random_shrink_log(rng: &mut Prng) -> ShrinkLog {
    ShrinkLog {
        steps: gen::vec_of(rng, 8, |r| ShrinkStep {
            pass: 1 + r.below(2) as u8,
            dim: random_dim(r),
            from: r.range_f64(-5.0, 30.0),
            to: r.range_f64(-5.0, 30.0),
            kept: r.next_bool(0.5),
        }),
    }
}

fn random_corpus_entry(rng: &mut Prng) -> CorpusEntry {
    let dt = rng.range_f64(0.01, 0.2);
    CorpusEntry {
        seed: rng.next_u64(),
        dt,
        horizon: dt + rng.range_f64(0.0, 20.0),
        case: random_fuzz_case(rng),
        verdict: random_fuzz_verdict(rng),
        shrunk: random_fuzz_case(rng),
        shrunk_verdict: random_fuzz_verdict(rng),
        log: random_shrink_log(rng),
    }
}

fn random_coverage_map(rng: &mut Prng) -> CoverageMap {
    let mut m = CoverageMap::default();
    for _ in 0..rng.below(40) {
        let key = rng.next_u32();
        for _ in 0..1 + rng.below(5) {
            m.observe(key);
        }
    }
    m
}

fn random_fuzz_spec(rng: &mut Prng) -> FuzzSpec {
    let rounds = 1 + rng.below(4) as u32;
    let round_size = 1 + rng.below(8) as u32;
    let dt = rng.range_f64(0.01, 0.2);
    let total = rounds as u64 * round_size as u64;
    let planted_n = rng.below(total.min(3) + 1) as usize;
    FuzzSpec {
        seed: rng.next_u64(),
        rounds,
        round_size,
        dt,
        horizon: dt + rng.range_f64(0.0, 20.0),
        max_mutations: 1 + rng.below(3) as u8,
        base_ego_speed: rng.range_f64(2.0, 30.0),
        planted: (0..planted_n).map(|_| random_fuzz_case(rng)).collect(),
    }
}

#[test]
fn prop_fuzz_codecs_roundtrip() {
    check("fuzz case roundtrip", random_fuzz_case, |c| {
        FuzzCase::decode(&c.encode()).unwrap() == *c
    });
    check("fuzz verdict roundtrip", random_fuzz_verdict, |v| {
        FuzzVerdict::decode(&v.encode()).unwrap() == *v
    });
    check("shrink log roundtrip", random_shrink_log, |l| {
        ShrinkLog::decode(&l.encode()).unwrap() == *l
    });
    check("corpus entry roundtrip", random_corpus_entry, |e| {
        CorpusEntry::decode(&e.encode()).unwrap() == *e
    });
    check("coverage map roundtrip", random_coverage_map, |m| {
        CoverageMap::decode(&m.encode()).unwrap() == *m
    });
    check("fuzz spec roundtrip", random_fuzz_spec, |s| {
        FuzzSpec::decode(&s.encode()).unwrap() == *s
    });
}

#[test]
fn prop_fuzz_codec_truncation_rejected() {
    check(
        "any strict prefix of a fuzz wire object is rejected",
        |rng| {
            let buf = match rng.below(4) {
                0 => random_fuzz_spec(rng).encode(),
                1 => random_coverage_map(rng).encode(),
                2 => random_corpus_entry(rng).encode(),
                _ => random_shrink_log(rng).encode(),
            };
            let cut = rng.below(buf.len() as u64) as usize;
            (buf, cut)
        },
        |(buf, cut)| {
            // all four are CRC-tailed: a strict prefix must never decode
            FuzzSpec::decode(&buf[..*cut]).is_err()
                && CoverageMap::decode(&buf[..*cut]).is_err()
                && CorpusEntry::decode(&buf[..*cut]).is_err()
                && ShrinkLog::decode(&buf[..*cut]).is_err()
        },
    );
}

#[test]
fn prop_fuzz_codec_bitflip_rejected() {
    check(
        "a single flipped bit fails a fuzz wire object's CRC",
        |rng| {
            let which = rng.below(4);
            let buf = match which {
                0 => random_fuzz_spec(rng).encode(),
                1 => random_coverage_map(rng).encode(),
                2 => random_corpus_entry(rng).encode(),
                _ => random_shrink_log(rng).encode(),
            };
            let byte = rng.below(buf.len() as u64) as usize;
            let bit = rng.below(8) as u8;
            (which, buf, byte, bit)
        },
        |(which, buf, byte, bit)| {
            let mut damaged = buf.clone();
            damaged[*byte] ^= 1 << bit;
            match which {
                0 => FuzzSpec::decode(&damaged).is_err(),
                1 => CoverageMap::decode(&damaged).is_err(),
                2 => CorpusEntry::decode(&damaged).is_err(),
                _ => ShrinkLog::decode(&damaged).is_err(),
            }
        },
    );
}

#[test]
fn fuzz_codec_trailing_bytes_rejected_even_with_valid_crc() {
    use av_simd::util::crc32;
    // junk appended to the body with the CRC *recomputed*, so only the
    // structural trailing-byte check can catch it
    let mut rng = Prng::new(0xF022);
    let with_junk = |buf: &[u8]| {
        let mut body = buf[..buf.len() - 4].to_vec();
        body.push(0xEE);
        let crc = crc32::hash(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        body
    };
    assert!(FuzzSpec::decode(&with_junk(&random_fuzz_spec(&mut rng).encode())).is_err());
    assert!(CoverageMap::decode(&with_junk(&random_coverage_map(&mut rng).encode())).is_err());
    assert!(CorpusEntry::decode(&with_junk(&random_corpus_entry(&mut rng).encode())).is_err());
    assert!(ShrinkLog::decode(&with_junk(&random_shrink_log(&mut rng).encode())).is_err());
}

// ---------- observability wire types (span batches, stats snapshots) ----------

use av_simd::engine::trace::{Span, SpanBatch, TraceCtx};
use av_simd::metrics::{HistogramSnapshot, MetricsSnapshot, HIST_BUCKETS};

fn random_span_batch(rng: &mut Prng) -> SpanBatch {
    SpanBatch {
        // u64::MAX is the "unknown worker" sentinel — round-trip it too
        worker_id: if rng.next_bool(0.1) { u64::MAX } else { rng.next_u64() },
        ctx: TraceCtx {
            job_id: rng.next_u64(),
            task_id: rng.next_u32(),
            attempt: rng.next_u32() % 4,
        },
        spans: gen::vec_of(rng, 12, |r| Span {
            name: gen::ident(r, 16),
            detail: if r.next_bool(0.5) { String::new() } else { gen::ident(r, 24) },
            start_ns: r.next_u64(),
            dur_ns: r.next_u64(),
            count: 1 + r.below(1000),
        }),
    }
}

fn random_metrics_snapshot(rng: &mut Prng) -> MetricsSnapshot {
    MetricsSnapshot {
        counters: gen::vec_of(rng, 6, |r| (gen::ident(r, 20), r.next_u64())),
        gauges: gen::vec_of(rng, 6, |r| (gen::ident(r, 20), r.next_u64())),
        histograms: gen::vec_of(rng, 4, |r| {
            let mut buckets = [0u64; HIST_BUCKETS];
            for b in buckets.iter_mut() {
                // mixed magnitudes so varint widths vary across buckets
                *b = r.next_u64() >> (r.below(64) as u32);
            }
            HistogramSnapshot {
                name: gen::ident(r, 20),
                buckets,
                sum_nanos: r.next_u64(),
                count: r.next_u64(),
            }
        }),
    }
}

#[test]
fn prop_observability_codecs_roundtrip() {
    check("span batch roundtrip", random_span_batch, |b| {
        SpanBatch::decode(&b.encode()).unwrap() == *b
    });
    check("metrics snapshot roundtrip", random_metrics_snapshot, |s| {
        MetricsSnapshot::decode(&s.encode()).unwrap() == *s
    });
}

#[test]
fn prop_observability_codec_truncation_rejected() {
    // Neither format is CRC-tailed, but both declare element counts up
    // front and reject trailing bytes, so a strict prefix can never
    // decode: the parser follows the same path over the identical prefix
    // bytes and runs out before finishing, or a shorter parse leaves an
    // unread tail and trips the trailing check.
    check(
        "any strict prefix of a span batch / stats snapshot is rejected",
        |rng| {
            let is_trace = rng.next_bool(0.5);
            let buf = if is_trace {
                random_span_batch(rng).encode()
            } else {
                random_metrics_snapshot(rng).encode()
            };
            let cut = rng.below(buf.len() as u64) as usize;
            (is_trace, buf, cut)
        },
        |(is_trace, buf, cut)| {
            if *is_trace {
                SpanBatch::decode(&buf[..*cut]).is_err()
            } else {
                MetricsSnapshot::decode(&buf[..*cut]).is_err()
            }
        },
    );
}

// ---------- perception kernels ----------

use av_simd::perception::lidar_odom::{brute_nearest, CorrGrid};
use av_simd::perception::{Classifier, Segmenter};

#[test]
fn prop_grid_nearest_matches_brute_force_including_ties() {
    // The spatial-grid correspondence search must return the exact same
    // index as the brute-force scan for every query — including distance
    // ties, which the brute scan resolves to the lowest point index.
    // Half the clouds live on a half-integer lattice so duplicate points
    // and exact equidistant queries are common, not incidental.
    check(
        "grid NN == brute-force NN (ties by lowest index)",
        |rng| {
            let lattice = rng.next_bool(0.5);
            let n = 3 + rng.below(120) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    if lattice {
                        (rng.below(12) as f64, rng.below(12) as f64)
                    } else {
                        (rng.range_f64(-50.0, 50.0), rng.range_f64(-50.0, 50.0))
                    }
                })
                .collect();
            let mut queries: Vec<(f64, f64)> = (0..40)
                .map(|_| {
                    if lattice {
                        // half-integer coords sit equidistant between
                        // lattice points — guaranteed tie candidates
                        (rng.below(26) as f64 * 0.5 - 1.0, rng.below(26) as f64 * 0.5 - 1.0)
                    } else {
                        (rng.range_f64(-70.0, 70.0), rng.range_f64(-70.0, 70.0))
                    }
                })
                .collect();
            // querying the points themselves hits zero-distance ties on
            // duplicated lattice points
            queries.extend(pts.iter().take(10).copied());
            (pts, queries)
        },
        |(pts, queries)| {
            let grid = CorrGrid::build(pts);
            queries.iter().all(|&q| grid.nearest(q) == brute_nearest(pts, q))
        },
    );
}

#[test]
fn prop_batched_perception_bit_identical_to_per_frame() {
    // The replay pipeline may group the same frames differently across
    // slicings; the report contract holds because batched inference is
    // bit-identical to per-frame inference for every grouping. Sweep
    // K ∈ {1, 2, 3, 8} over a mixed pool (native 32×32 and resampled
    // sizes) with ragged tails, comparing raw logit bits and exact
    // segmentation outputs against the one-frame-at-a-time path.
    let dir = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let c = Classifier::load(&dir).unwrap();
    let s = Segmenter::load(&dir).unwrap();
    let mut rng = Prng::new(0xBA7C4);
    let pool: Vec<Image> = (0..11)
        .map(|i| {
            let (w, h) = match i % 3 {
                0 => (32, 32),
                1 => (48, 24),
                _ => (17, 40),
            };
            Image::synthetic(w, h, rng.next_u64())
        })
        .collect();
    let single_logits: Vec<Vec<u32>> = pool
        .iter()
        .map(|img| {
            let r = c.classify(std::slice::from_ref(img)).unwrap().remove(0);
            r.logits.iter().map(|v| v.to_bits()).collect()
        })
        .collect();
    let single_segs: Vec<_> = pool.iter().map(|img| s.segment(img).unwrap()).collect();
    for k in [1usize, 2, 3, 8] {
        let mut batched_logits: Vec<Vec<u32>> = Vec::new();
        let mut batched_segs = Vec::new();
        for group in pool.chunks(k) {
            batched_logits.extend(
                c.classify(group)
                    .unwrap()
                    .into_iter()
                    .map(|r| r.logits.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()),
            );
            batched_segs.extend(s.segment_batch(group).unwrap());
        }
        assert_eq!(single_logits, batched_logits, "K={k}: classifier logits moved");
        assert_eq!(single_segs, batched_segs, "K={k}: segmentation moved");
    }
}

#[test]
fn prop_observability_codec_bitflip_never_panics() {
    check_n("span batch / stats snapshot corruption safety", 64, |rng| {
        let is_trace = rng.next_bool(0.5);
        let mut buf = if is_trace {
            random_span_batch(rng).encode()
        } else {
            random_metrics_snapshot(rng).encode()
        };
        let pos = rng.below(buf.len() as u64) as usize;
        buf[pos] ^= 1 << rng.below(8);
        (is_trace, buf)
    }, |(is_trace, buf)| {
        // unlike the CRC-tailed fuzz codecs these framed formats cannot
        // detect every flip — a benign decode is allowed, a panic is not
        if *is_trace {
            let _ = SpanBatch::decode(buf);
        } else {
            let _ = MetricsSnapshot::decode(buf);
        }
        true
    });
}
