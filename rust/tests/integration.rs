//! Cross-module integration tests: datagen → bag → engine → perception,
//! bus playback, config-driven contexts, DFS persistence.

use av_simd::bag::{BagReader, MemoryChunkedFile};
use av_simd::bus::{clock::Pace, play_bag, Broker, PlayOptions, QoS, SimClock};
use av_simd::datagen::{generate_drive, generate_drive_dir, DriveSpec};
use av_simd::engine::SimContext;
use av_simd::msg::{DetectionArray, Image, Message};
use av_simd::storage::BlockStore;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "av_simd_it_{tag}_{}_{:x}",
        std::process::id(),
        av_simd::util::now_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn datagen_to_distributed_perception() {
    let dir = tmp_dir("e2e");
    let dir_s = dir.to_str().unwrap();
    generate_drive_dir(dir_s, 3, &DriveSpec { frames: 6, ..DriveSpec::default() }).unwrap();

    let sc = SimContext::local(2);
    let outs = sc
        .bag_dir(dir_s, &["/camera"]).unwrap()
        .take_payload()
        .op("classify_images", vec![])
        .collect()
        .unwrap();
    assert_eq!(outs.len(), 18, "3 bags x 6 frames");
    for o in &outs {
        let det = DetectionArray::decode(o).unwrap();
        assert_eq!(det.detections.len(), 1);
        assert!(det.detections[0].score > 0.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bag_playback_feeds_live_graph_with_all_topics() {
    let (bag, _) = generate_drive(&DriveSpec { frames: 5, ..DriveSpec::default() }).unwrap();
    let broker = Broker::new();
    let cam = broker.subscribe::<Image>("/camera", QoS::lossless(64)).unwrap();
    let imu = broker
        .subscribe::<av_simd::msg::Imu>("/imu", QoS::lossless(64))
        .unwrap();
    let mut reader = BagReader::open(bag).unwrap();
    let clock = SimClock::new(Pace::FreeRun);
    let n = play_bag(&mut reader, &broker, &clock, &PlayOptions::default()).unwrap();
    assert_eq!(n, 5 + 5 + 25); // camera + lidar + imu
    let mut cams = 0;
    while cam.try_recv().is_some() {
        cams += 1;
    }
    let mut imus = 0;
    while imu.try_recv().is_some() {
        imus += 1;
    }
    assert_eq!(cams, 5);
    assert_eq!(imus, 25);
}

#[test]
fn bag_cache_accelerated_second_pass() {
    use av_simd::engine::{DataPlane, DataRef};

    let dir = tmp_dir("cache");
    let dir_s = dir.to_str().unwrap();
    let paths =
        generate_drive_dir(dir_s, 1, &DriveSpec { frames: 20, ..DriveSpec::default() })
            .unwrap();
    // the worker-side resolution path (paper §3.2's cache, behind the
    // data plane): first open loads from disk, the second replays the
    // same Arc-shared bytes from RAM
    let dp = DataPlane::new(64 << 20);
    let bag_ref = DataRef::path(paths[0].clone());
    let mut r1 = BagReader::open(dp.open(&bag_ref).unwrap()).unwrap();
    let n1 = r1.for_each(None, |_| Ok(())).unwrap();
    let mut r2 = BagReader::open(dp.open(&bag_ref).unwrap()).unwrap();
    let n2 = r2.for_each(None, |_| Ok(())).unwrap();
    assert_eq!(n1, n2);
    let (hits, misses, _) = dp.cache().stats();
    assert_eq!((hits, misses), (1, 1));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pipeline_with_binpipe_rotate_through_real_child() {
    // Requires the launcher binary for the child; skip when missing
    // (e.g. bare `cargo test` before `cargo build --release`).
    if !std::path::Path::new("target/release/av-simd").exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }
    // Run the binpipe op but point ChildSpec at the launcher via a custom
    // op, since test binaries have no user-logic mode.
    let sc = SimContext::local(2);
    sc.registry().register("binpipe_via_launcher", |_ctx, params, records| {
        let logic = std::str::from_utf8(params).unwrap().to_string();
        let spec = av_simd::pipe::ChildSpec {
            program: "target/release/av-simd".into(),
            args: vec!["user-logic".into(), logic],
            env: vec![("AV_SIMD_ARTIFACTS".into(), "artifacts".into())],
        };
        let items = records.into_iter().map(av_simd::pipe::PipeItem::Bytes).collect();
        let out = av_simd::pipe::pipe_through_child(&spec, items)?;
        Ok(out
            .into_iter()
            .map(|i| match i {
                av_simd::pipe::PipeItem::Bytes(b) => b,
                other => panic!("unexpected {other:?}"),
            })
            .collect())
    });
    let frames: Vec<Vec<u8>> =
        (0..6).map(|i| Image::synthetic(8, 12, i).encode()).collect();
    let out = sc
        .parallelize(frames, 2)
        .op("binpipe_via_launcher", b"rotate90".to_vec())
        .collect()
        .unwrap();
    assert_eq!(out.len(), 6);
    for o in out {
        let img = Image::decode(&o).unwrap();
        assert_eq!((img.width, img.height), (12, 8), "rotated in the child");
    }
}

#[test]
fn standalone_cluster_runs_jobs_via_spawned_processes() {
    if !std::path::Path::new("target/release/av-simd").exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }
    // StandaloneCluster spawns current_exe(); for tests that's the test
    // binary, which has no worker mode. Spawn launcher workers manually
    // and drive them with WorkerClient instead.
    use av_simd::engine::plan::{Action, Source, TaskSpec};
    use av_simd::engine::worker::WorkerClient;
    let addr = "127.0.0.1:7355";
    let mut child = std::process::Command::new("target/release/av-simd")
        .args(["worker", "--listen", addr, "--id", "0"])
        .stdout(std::process::Stdio::null())
        .spawn()
        .unwrap();
    let mut client = WorkerClient::connect(addr, std::time::Duration::from_secs(20)).unwrap();
    let out = client
        .run_task(&TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Range { start: 0, end: 1000 },
            ops: vec![],
            action: Action::Count,
        })
        .unwrap();
    assert_eq!(out, av_simd::engine::TaskOutput::Count(1000));
    client.shutdown().unwrap();
    child.wait().unwrap();
}

#[test]
fn save_bags_roundtrip_through_dfs() {
    let dir = tmp_dir("dfs");
    let sc = SimContext::local(2);
    let records: Vec<Vec<u8>> = (0..10u8).map(|i| vec![i; 100]).collect();
    let bag_dir = dir.join("bags");
    let paths = sc
        .parallelize(records.clone(), 2)
        .save_bags(bag_dir.to_str().unwrap(), "/rec", "raw")
        .unwrap();
    assert_eq!(paths.len(), 2);

    // push the bags into the DFS-lite store and pull them back intact
    let store = BlockStore::open(dir.join("dfs")).unwrap();
    for (i, p) in paths.iter().enumerate() {
        let bytes = std::fs::read(p).unwrap();
        store.put(&format!("part{i}"), &bytes).unwrap();
        let back = store.get(&format!("part{i}")).unwrap();
        assert_eq!(back, bytes);
        // and the retrieved bag still parses
        let mut r = BagReader::open(MemoryChunkedFile::from_bytes(&back)).unwrap();
        assert!(r.play(None).unwrap().len() >= 1);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_driven_local_context() {
    let cfg = av_simd::config::PlatformConfig::from_toml(
        "[cluster]\nmode = \"local\"\nworkers = 3\n",
    )
    .unwrap();
    let sc = SimContext::from_config(&cfg).unwrap();
    assert_eq!(sc.workers(), 3);
    assert_eq!(sc.backend(), "local");
    assert_eq!(sc.range(100).count().unwrap(), 100);
}

#[test]
fn scenario_matrix_distributed_equals_serial() {
    let matrix = av_simd::sim::scenario_matrix(10.0);
    let serial = av_simd::sim::run_matrix(
        &matrix,
        &av_simd::sim::EpisodeConfig::default(),
        &av_simd::sim::ControllerParams::default(),
    )
    .unwrap();

    let sc = SimContext::local(3);
    let records: Vec<Vec<u8>> = matrix.iter().map(av_simd::sim::encode_scenario).collect();
    let outs = sc
        .parallelize(records, 6)
        .op("run_scenario", vec![])
        .collect()
        .unwrap();
    let mut dist: Vec<av_simd::sim::EpisodeResult> = outs
        .iter()
        .map(|o| av_simd::sim::decode_result(o).unwrap())
        .collect();
    dist.sort_by(|a, b| a.scenario_id.cmp(&b.scenario_id));
    let mut ser = serial;
    ser.sort_by(|a, b| a.scenario_id.cmp(&b.scenario_id));
    assert_eq!(dist, ser, "distribution must not change simulation results");
}
