//! Fuzz-campaign integration suite: the determinism contract (a fixed
//! `--seed` produces byte-identical coverage maps, corpora, and shrunk
//! minimal counterexamples across {local, standalone} × {1, 2, 4}
//! workers), corpus durability (published counterexamples replay to the
//! same failure after the originals are gone and a GC pass has run),
//! and crash-resume chaos (a campaign killed by fault injection resumes
//! from its checkpoint to the same corpus as an uninterrupted run).
//!
//! Standalone clusters drive *in-process* `worker::serve` threads over
//! real TCP (the deploy-test pattern), so the whole suite runs under
//! plain `cargo test` with no release binary on disk.

use av_simd::engine::deploy::ClusterSpec;
use av_simd::engine::{worker, LocalCluster, StandaloneCluster};
use av_simd::sim::fuzz::{cutin_regression_case, Dim, FuzzDriver, FuzzSpec};
use av_simd::sim::run_corpus_replay;
use av_simd::storage::BlockStore;
use std::net::TcpListener;

fn artifact_dir() -> String {
    std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn local(workers: usize) -> LocalCluster {
    LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir())
}

/// Reserve an ephemeral port, then serve a worker on it from a thread.
fn spawn_worker(id: usize) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let a = addr.clone();
    let dir = artifact_dir();
    let h = std::thread::spawn(move || {
        worker::serve(&a, id, av_simd::full_op_registry(), &dir).unwrap();
    });
    (addr, h)
}

fn standalone(n: usize) -> (StandaloneCluster, Vec<std::thread::JoinHandle<()>>) {
    let mut hosts = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (addr, h) = spawn_worker(i);
        hosts.push(format!("\"{addr}\""));
        handles.push(h);
    }
    let spec = ClusterSpec::from_toml_text(&format!(
        "[cluster]\nname = \"fuzz-test\"\nconnect_timeout_ms = 5000\n\
         [workers]\nhosts = [{}]\n",
        hosts.join(", ")
    ))
    .unwrap();
    (StandaloneCluster::connect(&spec).unwrap(), handles)
}

/// A small campaign with the committed cut-in regression fixture planted
/// at the head of the schedule: 2 rounds × 6 cases, short horizon.
fn planted_spec() -> FuzzSpec {
    FuzzSpec {
        seed: 42,
        rounds: 2,
        round_size: 6,
        horizon: 6.0,
        planted: vec![cutin_regression_case()],
        ..FuzzSpec::default()
    }
}

fn temp_root(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("av_simd_fuzz_it_{tag}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// The acceptance matrix (satellite 1): fixed seed → byte-identical
/// `FuzzReport`s (coverage map, corpus, every shrunk counterexample)
/// across {local, standalone} × {1, 2, 4} workers — and the planted
/// failing scenario shrinks to the same ≤2-field minimal counterexample
/// everywhere.
#[test]
fn report_corpus_and_shrunk_counterexample_identical_across_backends_and_workers() {
    let driver = FuzzDriver::new(planted_spec());
    let reference = driver.run(&local(1)).unwrap();
    assert_eq!(reference.cases, 12);
    assert!(reference.failures >= 1, "planted cut-in must fail");
    assert!(!reference.corpus.is_empty(), "failure must reach the corpus");
    let minimal = &reference.corpus[0].shrunk;
    assert!(
        minimal.mutations.len() <= 2,
        "minimal counterexample uses {} mutated field(s): {}",
        minimal.mutations.len(),
        minimal.describe()
    );
    assert_eq!(
        minimal.mutations,
        vec![(Dim::BarrierManeuver, 1.0)],
        "shrinking must eliminate the two inert controller mutations"
    );

    let reference_bytes = reference.encode();
    for workers in [1usize, 2, 4] {
        let report = driver.run(&local(workers)).unwrap();
        assert_eq!(
            report.encode(),
            reference_bytes,
            "local x{workers} diverged from local x1"
        );

        let (cluster, handles) = standalone(workers);
        let report = driver.run(&cluster).unwrap();
        assert_eq!(
            report.encode(),
            reference_bytes,
            "standalone x{workers} diverged from local x1"
        );
        cluster.stop_workers();
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// Corpus durability: the published minimal counterexample replays to
/// the exact recorded failure with every other campaign artifact gone —
/// the original report dropped, and a GC pass run against an *empty*
/// live set (the `fuzz_corpus.roots` index alone must pin the entries).
#[test]
fn published_corpus_replays_after_original_data_is_gone() {
    let root = temp_root("durable");
    let driver = FuzzDriver::new(planted_spec());
    {
        let report = driver.run(&local(2)).unwrap();
        let ids = driver.publish_corpus(&report, &root).unwrap();
        // content addressing: the store-assigned ids are derivable from
        // the report alone
        assert_eq!(ids, report.corpus_ids());
        assert!(!ids.is_empty());
        // report (and campaign) dropped here — the store is all that's left
    }

    // GC with nothing explicitly live: the corpus index is a `.roots`
    // object, so every entry must survive the sweep
    let store = BlockStore::open(&root).unwrap();
    store.gc_with_roots(&[]).unwrap();

    let replay = run_corpus_replay(&local(2), &root).unwrap();
    assert!(!replay.entries.is_empty());
    assert_eq!(
        replay.mismatches(),
        0,
        "corpus entries must reproduce their recorded verdicts:\n{}",
        replay.render()
    );
    // replay verdicts must themselves be failures (the corpus only holds
    // counterexamples)
    for (id, v, _) in &replay.entries {
        assert!(v.failed(), "corpus entry {} replayed to a pass: {v:?}", id.short());
    }

    // and the replay outcome is backend-independent too
    let local_bytes = replay.encode();
    let (cluster, handles) = standalone(2);
    let remote = run_corpus_replay(&cluster, &root).unwrap();
    assert_eq!(remote.encode(), local_bytes, "standalone corpus replay diverged");
    cluster.stop_workers();
    for h in handles {
        h.join().unwrap();
    }
    std::fs::remove_dir_all(&root).ok();
}

/// The crash-resume chaos bar (satellite 3): a campaign aborted by
/// deterministic fault injection mid-round and mid-campaign must, on
/// resume from its durable checkpoint, re-execute only the missing
/// cases and emit a report — coverage map and corpus — byte-identical
/// to an uninterrupted run, on local and standalone backends.
#[test]
fn fault_aborted_campaign_resumes_from_checkpoint_to_identical_corpus() {
    use av_simd::engine::{CheckpointConfig, FaultPlan};

    let spec = FuzzSpec { rounds: 2, round_size: 4, ..planted_spec() };
    let total = spec.total_cases();
    let driver = FuzzDriver::new(spec);
    let reference = driver.run(&local(2)).unwrap().encode();

    // abort 2 completions into round 0 and 5 completions in (mid round 1)
    for abort_after in [2u64, 5] {
        for workers in [1usize, 2] {
            let root = temp_root(&format!("resume_{abort_after}_{workers}"));

            let cluster = local(workers);
            let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: false };
            let err = driver
                .run_hooked(
                    &cluster,
                    Some(&cfg),
                    Some(FaultPlan::none().abort_driver_after(abort_after)),
                )
                .unwrap_err();
            assert!(
                err.to_string().contains("fault injection"),
                "local x{workers}: expected an injected driver abort, got: {err}"
            );

            let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: true };
            let resumed = driver.run_checkpointed(&cluster, &cfg).unwrap();
            assert_eq!(
                resumed.encode(),
                reference,
                "local x{workers}, abort@{abort_after}: resumed campaign diverged"
            );
            assert_eq!(
                resumed.tasks as u64,
                total - abort_after,
                "local x{workers}, abort@{abort_after}: resume re-ran resolved cases"
            );
            std::fs::remove_dir_all(&root).ok();
        }

        // standalone: the fleet survives the driver crash; the resumed
        // driver dials the same workers
        let root = temp_root(&format!("resume_s_{abort_after}"));
        let (cluster, handles) = standalone(2);
        let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: false };
        let err = driver
            .run_hooked(
                &cluster,
                Some(&cfg),
                Some(FaultPlan::none().abort_driver_after(abort_after)),
            )
            .unwrap_err();
        assert!(
            err.to_string().contains("fault injection"),
            "standalone: expected an injected driver abort, got: {err}"
        );
        let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: true };
        let resumed = driver.run_checkpointed(&cluster, &cfg).unwrap();
        assert_eq!(
            resumed.encode(),
            reference,
            "standalone, abort@{abort_after}: resumed campaign diverged"
        );
        cluster.stop_workers();
        for h in handles {
            h.join().unwrap();
        }
        std::fs::remove_dir_all(&root).ok();
    }
}
