//! Distributed scenario-sweep integration tests: the same `SweepSpec`
//! must produce byte-identical `SweepReport`s on every backend and at
//! every parallelism — 1-worker `LocalCluster`, N-worker `LocalCluster`,
//! and a `StandaloneCluster` of spawned worker processes over TCP.
//! Determinism is the platform contract that makes a sharded Fig-1
//! matrix trustworthy: distribution must never change verdicts.

use av_simd::engine::{Cluster, LocalCluster, StandaloneCluster};
use av_simd::sim::{
    replay_shards, run_sweep, AdaptiveSharding, ShardSizing, SweepCase, SweepDriver,
    SweepReport, SweepSpec,
};
use std::time::Duration;

fn local(workers: usize) -> LocalCluster {
    LocalCluster::new(workers, av_simd::full_op_registry(), "artifacts")
}

/// A small but multi-shard spec (2 speeds × 2 dts × 2 seeds × 66 = 528
/// cases, 12+ shards) — enough to interleave tasks across workers.
fn small_spec() -> SweepSpec {
    SweepSpec {
        ego_speeds: vec![10.0, 14.0],
        dts: vec![0.05, 0.1],
        seeds: vec![1, 2],
        shard_size: 48,
        ..SweepSpec::default()
    }
}

#[test]
fn local_cluster_worker_count_does_not_change_the_report() {
    let spec = small_spec();
    let reference = run_sweep(&local(1), &spec).unwrap().encode();
    for workers in [2usize, 4, 7] {
        let report = run_sweep(&local(workers), &spec).unwrap();
        assert_eq!(
            report.encode(),
            reference,
            "local[{workers}] diverged from local[1]"
        );
    }
}

#[test]
fn shard_size_does_not_change_the_report() {
    // Sharding is part of the spec, but the *verdicts* must not depend on
    // how the case list is cut into tasks.
    let base = small_spec();
    let reference = run_sweep(&local(3), &base).unwrap().encode();
    for shard_size in [7usize, 64, 10_000] {
        let spec = SweepSpec { shard_size, ..small_spec() };
        let report = run_sweep(&local(3), &spec).unwrap();
        assert_eq!(
            report.encode(),
            reference,
            "shard_size {shard_size} changed the verdicts"
        );
    }
}

#[test]
fn standalone_cluster_matches_local_byte_for_byte() {
    // Needs the release launcher for worker processes; skip when absent
    // (bare `cargo test` before `cargo build --release`), matching the
    // other standalone integration tests.
    let launcher = std::path::Path::new("target/release/av-simd");
    if !launcher.exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }
    let spec = small_spec();
    let local_report = run_sweep(&local(2), &spec).unwrap();

    let cluster = StandaloneCluster::launch_program(launcher, 3, 7411, "artifacts").unwrap();
    let remote_report = run_sweep(&cluster, &spec).unwrap();
    cluster.shutdown();

    assert_eq!(
        remote_report.encode(),
        local_report.encode(),
        "standalone workers diverged from local threads"
    );
    assert_eq!(remote_report.total, spec.case_count());
}

/// Tracing must observe a sweep, never participate in it: with the
/// trace sink installed the report bytes equal the untraced reference
/// across worker counts and backends, and spans actually get recorded.
#[test]
fn traced_sweep_report_bytes_identical_across_backends() {
    use av_simd::engine::trace::{self, TraceLog};
    let spec = small_spec();
    let reference = run_sweep(&local(1), &spec).unwrap().encode();
    for workers in [1usize, 2, 4] {
        let log = TraceLog::new();
        let report = {
            let _guard = trace::install(log.clone());
            run_sweep(&local(workers), &spec).unwrap()
        };
        assert_eq!(
            report.encode(),
            reference,
            "tracing changed local[{workers}] sweep bytes"
        );
        assert!(!log.is_empty(), "traced local[{workers}] sweep recorded nothing");
    }

    let launcher = std::path::Path::new("target/release/av-simd");
    if !launcher.exists() {
        eprintln!("skipping standalone half: build target/release/av-simd first");
        return;
    }
    let cluster = StandaloneCluster::launch_program(launcher, 2, 7431, "artifacts").unwrap();
    let log = TraceLog::new();
    let report = {
        let _guard = trace::install(log.clone());
        run_sweep(&cluster, &spec).unwrap()
    };
    cluster.shutdown();
    assert_eq!(report.encode(), reference, "tracing changed standalone sweep bytes");
    assert!(!log.is_empty(), "traced standalone sweep recorded nothing");
}

#[test]
fn full_scale_sweep_runs_thousands_of_cases() {
    // The acceptance-scale run: the default spec is >= 1000 cases and
    // must survive a real multi-worker job with a sane report.
    let spec = SweepSpec::default();
    assert!(spec.case_count() >= 1000, "default spec must be platform-scale");
    let report = run_sweep(&local(4), &spec).unwrap();
    assert_eq!(report.total, spec.case_count());
    assert_eq!(report.total, report.passed + report.failing_total);
    assert_eq!(
        report.ttc_histogram.iter().sum::<u64>(),
        report.total as u64,
        "every episode lands in exactly one TTC bucket"
    );
    assert!(report.passed > 0, "controller must pass some cases");
    assert!(report.collisions > 0, "a jittered grid must expose collisions");
    assert!(report.tasks >= 4, "the sweep must actually shard");
    assert!(!report.worst.is_empty());
    // worst cases are sorted collisions-first
    assert!(
        report.worst[0].result.collided || report.collisions == 0,
        "worst case must be a collision when any exist"
    );
}

/// `small_spec` with adaptive sharding enabled: a short calibration
/// task, then calibrated shards for the remainder. `drift` controls
/// mid-sweep re-calibration: `f64::INFINITY` disables it, values just
/// above 1.0 make every drift check fire.
fn adaptive_spec_with(drift: f64, window: usize) -> SweepSpec {
    SweepSpec {
        adaptive: Some(AdaptiveSharding {
            target_task: Duration::from_millis(20),
            calibration_cases: 40,
            min_shard: 4,
            max_shard: 512,
            drift_threshold: drift,
            recalibration_window: window,
        }),
        ..small_spec()
    }
}

fn adaptive_spec() -> SweepSpec {
    adaptive_spec_with(1.5, 64)
}

/// Check an adaptive report's sharding record: calibration bounds, a
/// non-empty log, and that replaying the log yields exactly `tasks`
/// order-preserving dt-pure shards covering the whole case list.
fn assert_valid_adaptive_sharding(report: &SweepReport, spec: &SweepSpec) {
    match &report.sharding {
        ShardSizing::Adaptive { calibration_cases, log } => {
            assert!(*calibration_cases >= 1 && *calibration_cases <= 40);
            assert!(!log.is_empty(), "initial calibration must be recorded");
            assert!(log[0].measured_per_case > Duration::ZERO);
            assert!((4..=512).contains(&log[0].shard_size));
            let cases = spec.cases();
            let replayed = replay_shards(&cases, *calibration_cases, log);
            let rejoined: Vec<SweepCase> = replayed.iter().flatten().cloned().collect();
            assert_eq!(rejoined, cases, "log replay must partition the case list in order");
            assert_eq!(replayed.len(), report.tasks, "one replayed shard per task");
            for shard in &replayed {
                assert!(shard.iter().all(|c| c.dt_index == shard[0].dt_index));
            }
        }
        other => panic!("adaptive run recorded {other:?}"),
    }
}

#[test]
fn adaptive_sharding_is_byte_identical_across_worker_counts() {
    // sharding derives from *measured* wall time, so task boundaries
    // differ run to run — the verdict payload must not. Covers
    // re-calibration off (inf), default, and hair-trigger (1.0001 with a
    // 1-case window re-checks drift after every completed shard).
    let fixed_reference = run_sweep(&local(1), &small_spec()).unwrap().encode();
    for workers in [1usize, 3, 6] {
        for (drift, window) in [(f64::INFINITY, 64), (1.5, 64), (1.0001, 1)] {
            let spec = adaptive_spec_with(drift, window);
            let report = run_sweep(&local(workers), &spec).unwrap();
            assert_eq!(
                report.encode(),
                fixed_reference,
                "adaptive local[{workers}] drift={drift} diverged from fixed local[1]"
            );
            assert_valid_adaptive_sharding(&report, &spec);
            if !drift.is_finite() {
                match &report.sharding {
                    ShardSizing::Adaptive { log, .. } => assert_eq!(
                        log.len(),
                        1,
                        "disabled re-calibration must never extend the log"
                    ),
                    _ => unreachable!(),
                }
            }
        }
    }
}

#[test]
fn adaptive_sharding_matches_across_backends() {
    // acceptance: byte-equality on LocalCluster and StandaloneCluster
    // with adaptive sharding enabled
    let launcher = std::path::Path::new("target/release/av-simd");
    if !launcher.exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }
    let local_report = run_sweep(&local(2), &adaptive_spec()).unwrap();

    let cluster = StandaloneCluster::launch_program(launcher, 3, 7455, "artifacts").unwrap();
    let remote_report = run_sweep(&cluster, &adaptive_spec()).unwrap();
    cluster.shutdown();

    assert_eq!(
        remote_report.encode(),
        local_report.encode(),
        "adaptive standalone diverged from adaptive local"
    );
    // and both equal the fixed-sharding verdicts
    assert_eq!(
        local_report.encode(),
        run_sweep(&local(2), &small_spec()).unwrap().encode(),
        "adaptive sharding changed the verdicts"
    );
}

#[test]
fn cluster_spec_fleet_with_late_joiner_matches_local_bytes() {
    // The deploy-layer acceptance path: a standalone cluster dialed from
    // a ClusterSpec manifest (multiple worker endpoints), with one more
    // worker joining while the sweep is running — the report must be
    // byte-identical to a local run, with re-calibration enabled. The
    // workers are in-process `worker::serve` threads (same protocol as
    // worker processes), so this runs without the release binary.
    use av_simd::engine::deploy::ClusterSpec;
    use std::net::TcpListener;

    fn spawn_worker(id: usize) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let a = addr.clone();
        let h = std::thread::spawn(move || {
            av_simd::engine::worker::serve(&a, id, av_simd::full_op_registry(), "artifacts")
                .unwrap();
        });
        (addr, h)
    }

    let spec = adaptive_spec_with(1.0001, 1); // re-calibrate aggressively
    let local_reference = run_sweep(&local(2), &spec).unwrap();

    let (addr_a, h_a) = spawn_worker(0);
    let (addr_b, h_b) = spawn_worker(1);
    let manifest = format!(
        "[cluster]\nname = \"sweep-fleet\"\nconnect_timeout_ms = 10000\n\
         [workers]\nhosts = [\"{addr_a}\", \"{addr_b}\"]\n"
    );
    let cluster_spec = ClusterSpec::from_toml_text(&manifest).unwrap();
    assert_eq!(cluster_spec.addrs(), vec![addr_a, addr_b]);
    let cluster = std::sync::Arc::new(StandaloneCluster::connect(&cluster_spec).unwrap());
    assert_eq!(cluster.workers(), 2);

    // admit a third worker shortly after the sweep starts
    let (addr_c, h_c) = spawn_worker(2);
    let joiner = {
        let cluster = cluster.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            cluster.add_worker(&addr_c, Duration::from_secs(10)).unwrap();
        })
    };

    let remote_report = run_sweep(cluster.as_ref(), &spec).unwrap();
    joiner.join().unwrap();
    assert_eq!(cluster.workers(), 3, "late joiner must be in the fleet");

    assert_eq!(
        remote_report.encode(),
        local_reference.encode(),
        "ClusterSpec fleet with late joiner diverged from local"
    );
    assert_eq!(remote_report.total, spec.case_count());
    assert_valid_adaptive_sharding(&remote_report, &spec);

    cluster.stop_workers();
    drop(cluster);
    for h in [h_a, h_b, h_c] {
        h.join().unwrap();
    }
}

#[test]
fn retry_during_stream_preserves_case_order() {
    // poison the op chain with one transient failure per run: the retry
    // re-enters the stream immediately (no round barrier) and the
    // aggregated verdicts must still land in case order, byte-identical
    // to a clean run. SweepReport::aggregate cross-checks result i
    // against case i, so any misordering fails loudly inside run().
    use av_simd::engine::OpCall;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let clean = run_sweep(&local(3), &small_spec()).unwrap();

    let reg = av_simd::full_op_registry();
    let trips = Arc::new(AtomicUsize::new(0));
    let t = trips.clone();
    reg.register("poison_once", move |_c, _p, records| {
        if t.fetch_add(1, Ordering::SeqCst) == 0 {
            Err(av_simd::err!(Engine, "transient poison"))
        } else {
            Ok(records)
        }
    });
    let cluster = LocalCluster::new(3, reg, "artifacts");

    let spec = small_spec();
    let shards = spec.shards();
    let mut tasks = spec.task_specs_from(&shards, 77);
    for task in &mut tasks {
        task.ops.insert(0, OpCall::new("poison_once", vec![]));
    }
    let n_tasks = tasks.len();
    let (outs, job) = av_simd::engine::run_job(&cluster, tasks, 2).unwrap();
    assert_eq!(job.retries, 1, "exactly one transient failure to retry");

    let cases: Vec<_> = shards.iter().flatten().cloned().collect();
    let mut results = Vec::new();
    for out in outs {
        match out {
            av_simd::engine::TaskOutput::Episodes(rs) => {
                results.extend(rs.iter().map(|r| av_simd::sim::decode_result(r).unwrap()))
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    let poisoned = SweepReport::aggregate(
        &cases,
        &results,
        spec.worst_k,
        n_tasks,
        job.retries,
        job.wall,
    )
    .unwrap();
    assert_eq!(poisoned.encode(), clean.encode());
}

#[test]
fn skewed_shard_no_longer_serializes_the_job() {
    // one shard carries a deliberate straggler stall; with streaming
    // dispatch the other workers chew through the rest of the sweep
    // while it runs, so the job wall stays near the straggler wall —
    // nowhere near the serialized sum of all task time.
    use av_simd::engine::OpCall;

    const STRAGGLER_MS: u64 = 600;
    const WORKERS: usize = 4;

    let reg = av_simd::full_op_registry();
    reg.register("stall_first_shard", move |_c, params, records| {
        if !params.is_empty() {
            std::thread::sleep(Duration::from_millis(STRAGGLER_MS));
        }
        Ok(records)
    });
    let cluster = LocalCluster::new(WORKERS, reg, "artifacts");

    // a small sweep (66 cases) so even unoptimized episode math is tiny
    // next to the straggler stall
    let spec = SweepSpec {
        ego_speeds: vec![12.0],
        dts: vec![0.05],
        seeds: vec![1],
        shard_size: 8,
        ..SweepSpec::default()
    };
    let shards = spec.shards();
    let mut tasks = spec.task_specs_from(&shards, 78);
    assert!(tasks.len() >= 8, "need a real shard spread, got {}", tasks.len());
    for (i, task) in tasks.iter_mut().enumerate() {
        let marker = if i == 0 { vec![1] } else { vec![] };
        task.ops.insert(0, OpCall::new("stall_first_shard", marker));
    }

    let t0 = std::time::Instant::now();
    let (outs, report) = av_simd::engine::run_job(&cluster, tasks, 1).unwrap();
    let wall = t0.elapsed();
    assert_eq!(outs.len(), shards.len());
    assert_eq!(report.retries, 0);

    // the straggler pins one worker; every other shard must overlap it,
    // so the job wall stays near the straggler wall. The margin leaves
    // room for unoptimized episode math on a contended test runner while
    // still catching any return to queue-behind-the-straggler dispatch.
    assert!(
        wall < Duration::from_millis(STRAGGLER_MS) + Duration::from_millis(400),
        "skewed shard serialized the job: wall {wall:?}"
    );
    // and the straggler really ran: the job can't be faster than it
    assert!(wall >= Duration::from_millis(STRAGGLER_MS), "stall op didn't run: {wall:?}");
}

#[test]
fn report_roundtrips_and_decode_rejects_garbage() {
    let report = run_sweep(&local(2), &small_spec()).unwrap();
    let buf = report.encode();
    let back = SweepReport::decode(&buf).unwrap();
    assert_eq!(back.encode(), buf, "decode must preserve the payload");
    assert!(SweepReport::decode(&[]).is_err());
    assert!(SweepReport::decode(&[99]).is_err(), "unknown version rejected");
    let mut truncated = buf.clone();
    truncated.truncate(buf.len() / 2);
    assert!(SweepReport::decode(&truncated).is_err());
}

#[test]
fn driver_rejects_empty_specs() {
    let spec = SweepSpec { ego_speeds: vec![], ..SweepSpec::default() };
    let err = SweepDriver::new(spec).run(&local(1)).unwrap_err();
    assert!(err.to_string().contains("zero cases"), "{err}");
}

/// The sweep's corpus mode: a fuzz regression corpus built in a block
/// store replays through `run_corpus_replay` byte-identically across
/// backends and worker counts, and a bit-flipped corpus block fails
/// loudly with the damaged block's id in the error.
#[test]
fn corpus_replay_matches_across_backends_and_bit_flip_names_the_block() {
    use av_simd::engine::deploy::ClusterSpec;
    use av_simd::engine::StandaloneCluster;
    use av_simd::sim::fuzz::{cutin_regression_case, FuzzDriver, FuzzSpec};
    use av_simd::sim::run_corpus_replay;
    use av_simd::storage::{hex32, Manifest, DEFAULT_BLOCK_SIZE};

    let root = std::env::temp_dir()
        .join(format!("av_simd_sweep_corpus_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string();

    // build the fixture corpus deterministically: a short campaign with
    // the committed cut-in regression case planted at the head
    let spec = FuzzSpec {
        rounds: 1,
        round_size: 4,
        horizon: 6.0,
        planted: vec![cutin_regression_case()],
        ..FuzzSpec::default()
    };
    let driver = FuzzDriver::new(spec);
    let report = driver.run(&local(2)).unwrap();
    assert!(!report.corpus.is_empty(), "campaign must capture the planted failure");
    driver.publish_corpus(&report, &root).unwrap();

    // byte-identical replay across worker counts and backends
    let reference = run_corpus_replay(&local(1), &root).unwrap();
    assert_eq!(reference.mismatches(), 0, "{}", reference.render());
    for workers in [2usize, 4] {
        let replay = run_corpus_replay(&local(workers), &root).unwrap();
        assert_eq!(
            replay.encode(),
            reference.encode(),
            "corpus replay local x{workers} diverged"
        );
    }
    {
        // standalone: in-process worker threads over TCP
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let a = addr.clone();
        let h = std::thread::spawn(move || {
            av_simd::engine::worker::serve(&a, 0, av_simd::full_op_registry(), "artifacts")
                .unwrap();
        });
        let cluster_spec = ClusterSpec::from_toml_text(&format!(
            "[cluster]\nname = \"corpus-test\"\nconnect_timeout_ms = 5000\n\
             [workers]\nhosts = [\"{addr}\"]\n"
        ))
        .unwrap();
        let cluster = StandaloneCluster::connect(&cluster_spec).unwrap();
        let replay = run_corpus_replay(&cluster, &root).unwrap();
        assert_eq!(
            replay.encode(),
            reference.encode(),
            "corpus replay over standalone diverged"
        );
        cluster.stop_workers();
        h.join().unwrap();
    }

    // bit-flip one byte of the first entry's block on disk: the replay
    // must refuse with the block id in the error, not drift silently
    let entry_bytes = report.corpus[0].encode();
    let block_id = Manifest::describe(&entry_bytes, DEFAULT_BLOCK_SIZE).blocks[0].id;
    let block_path = std::path::Path::new(&root)
        .join("blocks")
        .join(format!("{}.blk", hex32(&block_id)));
    let mut damaged = std::fs::read(&block_path).unwrap();
    damaged[0] ^= 0x01;
    std::fs::write(&block_path, &damaged).unwrap();

    let err = run_corpus_replay(&local(1), &root).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains(&hex32(&block_id)),
        "corruption error must name the damaged block: {msg}"
    );
    std::fs::remove_dir_all(&root).ok();
}
