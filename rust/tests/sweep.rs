//! Distributed scenario-sweep integration tests: the same `SweepSpec`
//! must produce byte-identical `SweepReport`s on every backend and at
//! every parallelism — 1-worker `LocalCluster`, N-worker `LocalCluster`,
//! and a `StandaloneCluster` of spawned worker processes over TCP.
//! Determinism is the platform contract that makes a sharded Fig-1
//! matrix trustworthy: distribution must never change verdicts.

use av_simd::engine::{Cluster, LocalCluster, StandaloneCluster};
use av_simd::sim::{run_sweep, SweepDriver, SweepReport, SweepSpec};

fn local(workers: usize) -> LocalCluster {
    LocalCluster::new(workers, av_simd::full_op_registry(), "artifacts")
}

/// A small but multi-shard spec (2 speeds × 2 dts × 2 seeds × 66 = 528
/// cases, 12+ shards) — enough to interleave tasks across workers.
fn small_spec() -> SweepSpec {
    SweepSpec {
        ego_speeds: vec![10.0, 14.0],
        dts: vec![0.05, 0.1],
        seeds: vec![1, 2],
        shard_size: 48,
        ..SweepSpec::default()
    }
}

#[test]
fn local_cluster_worker_count_does_not_change_the_report() {
    let spec = small_spec();
    let reference = run_sweep(&local(1), &spec).unwrap().encode();
    for workers in [2usize, 4, 7] {
        let report = run_sweep(&local(workers), &spec).unwrap();
        assert_eq!(
            report.encode(),
            reference,
            "local[{workers}] diverged from local[1]"
        );
    }
}

#[test]
fn shard_size_does_not_change_the_report() {
    // Sharding is part of the spec, but the *verdicts* must not depend on
    // how the case list is cut into tasks.
    let base = small_spec();
    let reference = run_sweep(&local(3), &base).unwrap().encode();
    for shard_size in [7usize, 64, 10_000] {
        let spec = SweepSpec { shard_size, ..small_spec() };
        let report = run_sweep(&local(3), &spec).unwrap();
        assert_eq!(
            report.encode(),
            reference,
            "shard_size {shard_size} changed the verdicts"
        );
    }
}

#[test]
fn standalone_cluster_matches_local_byte_for_byte() {
    // Needs the release launcher for worker processes; skip when absent
    // (bare `cargo test` before `cargo build --release`), matching the
    // other standalone integration tests.
    let launcher = std::path::Path::new("target/release/av-simd");
    if !launcher.exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }
    let spec = small_spec();
    let local_report = run_sweep(&local(2), &spec).unwrap();

    let cluster = StandaloneCluster::launch_program(launcher, 3, 7411, "artifacts").unwrap();
    let remote_report = run_sweep(&cluster, &spec).unwrap();
    cluster.shutdown();

    assert_eq!(
        remote_report.encode(),
        local_report.encode(),
        "standalone workers diverged from local threads"
    );
    assert_eq!(remote_report.total, spec.case_count());
}

#[test]
fn full_scale_sweep_runs_thousands_of_cases() {
    // The acceptance-scale run: the default spec is >= 1000 cases and
    // must survive a real multi-worker job with a sane report.
    let spec = SweepSpec::default();
    assert!(spec.case_count() >= 1000, "default spec must be platform-scale");
    let report = run_sweep(&local(4), &spec).unwrap();
    assert_eq!(report.total, spec.case_count());
    assert_eq!(report.total, report.passed + report.failing_total);
    assert_eq!(
        report.ttc_histogram.iter().sum::<u64>(),
        report.total as u64,
        "every episode lands in exactly one TTC bucket"
    );
    assert!(report.passed > 0, "controller must pass some cases");
    assert!(report.collisions > 0, "a jittered grid must expose collisions");
    assert!(report.tasks >= 4, "the sweep must actually shard");
    assert!(!report.worst.is_empty());
    // worst cases are sorted collisions-first
    assert!(
        report.worst[0].result.collided || report.collisions == 0,
        "worst case must be a collision when any exist"
    );
}

#[test]
fn report_roundtrips_and_decode_rejects_garbage() {
    let report = run_sweep(&local(2), &small_spec()).unwrap();
    let buf = report.encode();
    let back = SweepReport::decode(&buf).unwrap();
    assert_eq!(back.encode(), buf, "decode must preserve the payload");
    assert!(SweepReport::decode(&[]).is_err());
    assert!(SweepReport::decode(&[99]).is_err(), "unknown version rejected");
    let mut truncated = buf.clone();
    truncated.truncate(buf.len() / 2);
    assert!(SweepReport::decode(&truncated).is_err());
}

#[test]
fn driver_rejects_empty_specs() {
    let spec = SweepSpec { ego_speeds: vec![], ..SweepSpec::default() };
    let err = SweepDriver::new(spec).run(&local(1)).unwrap_err();
    assert!(err.to_string().contains("zero cases"), "{err}");
}
