//! Deploy-layer integration tests: `ClusterSpec`-driven fleets,
//! handshake/version enforcement, and mid-stream worker admission.
//!
//! Unlike the spawn-based standalone tests (which need the release
//! binary on disk), these drive *in-process* worker servers —
//! `engine::worker::serve` on a thread speaks exactly the protocol a
//! worker process does, so the whole deploy path (dial → handshake →
//! stream → shutdown) runs under plain `cargo test`.

use av_simd::engine::deploy::{self, ClusterSpec};
use av_simd::engine::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use av_simd::engine::worker::serve;
use av_simd::engine::{Action, Cluster, OpRegistry, Source, StandaloneCluster, TaskOutput, TaskSpec};
use std::net::TcpListener;
use std::time::Duration;

/// Reserve an ephemeral port, then serve a worker on it from a thread.
/// (The listener is dropped and rebound by `serve` — the same pattern
/// the in-crate RPC tests use.)
fn spawn_worker(id: usize, registry: OpRegistry) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let a = addr.clone();
    let h = std::thread::spawn(move || {
        serve(&a, id, registry, "artifacts").unwrap();
    });
    (addr, h)
}

fn spec_for(addrs: &[String], timeout_ms: u64) -> ClusterSpec {
    let hosts = addrs
        .iter()
        .map(|a| format!("\"{a}\""))
        .collect::<Vec<_>>()
        .join(", ");
    ClusterSpec::from_toml_text(&format!(
        "[cluster]\nname = \"test\"\nconnect_timeout_ms = {timeout_ms}\n\
         [workers]\nhosts = [{hosts}]\n"
    ))
    .unwrap()
}

fn count_task(id: u32, n: u64) -> TaskSpec {
    TaskSpec {
        job_id: 1,
        task_id: id,
        attempt: 0,
        source: Source::Range { start: 0, end: n },
        ops: vec![],
        action: Action::Count,
    }
}

#[test]
fn cluster_spec_fleet_runs_tasks() {
    let (addr_a, h_a) = spawn_worker(0, OpRegistry::with_builtins());
    let (addr_b, h_b) = spawn_worker(1, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_a, addr_b], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();
    assert_eq!(cluster.workers(), 2);

    let tasks: Vec<TaskSpec> = (0..12).map(|i| count_task(i, (i as u64 + 1) * 5)).collect();
    let results = cluster.run_tasks(&tasks);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(*r.as_ref().unwrap(), TaskOutput::Count((i as u64 + 1) * 5));
    }

    // connect-mode `shutdown` leaves the fleet up (externally managed)
    cluster.shutdown();
    let again = cluster.run_tasks(&[count_task(0, 7)]);
    assert_eq!(*again[0].as_ref().unwrap(), TaskOutput::Count(7));

    // explicit stop tears the workers down so the threads join
    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn late_joining_worker_is_admitted_into_a_running_stream() {
    // every task stalls long enough that one worker alone would need
    // ~20x the join delay — the late joiner must end up serving tasks
    let stall_registry = || {
        let reg = OpRegistry::with_builtins();
        reg.register("stall_and_tag", |c, _p, _records| {
            std::thread::sleep(Duration::from_millis(60));
            Ok(vec![vec![c.worker_id as u8]])
        });
        reg
    };
    let (addr_a, h_a) = spawn_worker(1, stall_registry());
    let spec = spec_for(&[addr_a], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();
    assert_eq!(cluster.workers(), 1);

    const TASKS: u64 = 20;
    let stream = cluster.open_stream();
    for i in 0..TASKS {
        let mut t = count_task(i as u32, 1);
        t.ops.push(av_simd::engine::OpCall::new("stall_and_tag", vec![]));
        t.action = Action::Collect;
        stream.submit(i, t);
    }

    // admit worker 2 while the stream is mid-flight
    let (addr_b, h_b) = spawn_worker(2, stall_registry());
    std::thread::sleep(Duration::from_millis(50));
    cluster.add_worker(&addr_b, Duration::from_secs(5)).unwrap();
    assert_eq!(cluster.workers(), 2);

    let mut served_by: Vec<u8> = Vec::new();
    for _ in 0..TASKS {
        let c = stream.next_completion().expect("all tasks must complete");
        match c.result.unwrap() {
            TaskOutput::Records(rs) => served_by.push(rs[0][0]),
            other => panic!("unexpected {other:?}"),
        }
    }
    stream.close();

    assert_eq!(served_by.len() as u64, TASKS);
    assert!(
        served_by.contains(&2),
        "late-joining worker never served a task: {served_by:?}"
    );
    assert!(
        served_by.contains(&1),
        "original worker starved: {served_by:?}"
    );

    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn worker_added_before_a_stream_serves_later_jobs() {
    let (addr_a, h_a) = spawn_worker(0, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_a], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();

    let (addr_b, h_b) = spawn_worker(1, OpRegistry::with_builtins());
    cluster.add_worker(&addr_b, Duration::from_secs(5)).unwrap();
    assert_eq!(cluster.workers(), 2, "fleet grows with no stream open");

    let tasks: Vec<TaskSpec> = (0..8).map(|i| count_task(i, 3)).collect();
    let results = cluster.run_tasks(&tasks);
    assert!(results.iter().all(|r| *r.as_ref().unwrap() == TaskOutput::Count(3)));

    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn version_mismatched_worker_is_rejected_at_cluster_connect() {
    // a fake worker that speaks a newer protocol version
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut writer = std::io::BufWriter::new(stream);
        if let Some(RpcMsg::Hello { .. }) = read_msg(&mut reader).unwrap() {
            write_msg(
                &mut writer,
                &RpcMsg::HelloOk { version: RPC_VERSION + 7, worker_id: 3, now_ns: 0 },
            )
            .unwrap();
        }
    });

    let spec = spec_for(&[addr.clone()], 5000);
    let err = match StandaloneCluster::connect(&spec) {
        Err(e) => e,
        Ok(_) => panic!("mismatched fleet must be rejected"),
    };
    let msg = err.to_string();
    assert!(msg.contains(&addr), "endpoint lost: {msg}");
    assert!(msg.contains("rpc v"), "version context lost: {msg}");
    assert!(msg.contains("test"), "cluster name lost: {msg}");
    h.join().unwrap();
}

#[test]
fn connect_failure_names_endpoint_and_attempts() {
    // port 1 is reserved: nothing will ever listen there
    let spec = spec_for(&["127.0.0.1:1".to_string()], 120);
    let err = match StandaloneCluster::connect(&spec) {
        Err(e) => e,
        Ok(_) => panic!("expected connect failure"),
    };
    let msg = err.to_string();
    assert!(msg.contains("127.0.0.1:1"), "endpoint lost: {msg}");
    assert!(msg.contains("attempt"), "attempt count lost: {msg}");
}

/// The fleet-telemetry acceptance bar: against a live two-process
/// `ClusterSpec` fleet (real `target/release/av-simd` workers, so each
/// has its own metrics registry), the per-worker `worker_tasks_done`
/// counts fetched over `FetchStats` must sum to the job's task total —
/// and the `av-simd top` CLI must render the same fleet. Skipped when
/// the release binary is not on disk (CI builds it before testing).
#[test]
fn top_stats_sum_to_job_totals_across_a_live_fleet() {
    let launcher = std::path::Path::new("target/release/av-simd");
    if !launcher.exists() {
        eprintln!("skipping: build target/release/av-simd first");
        return;
    }

    // reserve two ephemeral loopback ports for the fleet
    let ports: Vec<u16> = (0..2)
        .map(|_| {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let p = l.local_addr().unwrap().port();
            drop(l);
            p
        })
        .collect();
    let toml = format!(
        "[cluster]\nname = \"top-test\"\nconnect_timeout_ms = 5000\n\
         [workers]\nhosts = [\"127.0.0.1:{}\", \"127.0.0.1:{}\"]\n\
         [launch]\nprogram = \"target/release/av-simd\"\n",
        ports[0], ports[1]
    );
    let spec = ClusterSpec::from_toml_text(&toml).unwrap();
    let (mut children, skipped) = deploy::launch_local_workers(&spec).unwrap();
    assert_eq!(children.len(), 2);
    assert_eq!(skipped, 0);

    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    while !deploy::probe(&spec).iter().all(|h| h.ok()) {
        assert!(std::time::Instant::now() < deadline, "fleet never came up");
        std::thread::sleep(Duration::from_millis(100));
    }

    // freshly launched processes start at zero, but take a baseline
    // anyway: the assertion below is about the *delta* this job causes
    let done_sum = |stats: &[deploy::WorkerStats]| -> u64 {
        stats
            .iter()
            .filter_map(|w| w.snapshot.as_ref())
            .map(|s| s.counter("worker_tasks_done"))
            .sum()
    };
    let before = done_sum(&deploy::probe_stats(&spec));

    let cluster = StandaloneCluster::connect(&spec).unwrap();
    let tasks: Vec<TaskSpec> = (0..12).map(|i| count_task(i, 5)).collect();
    let (outs, report) = av_simd::engine::run_job(&cluster, tasks, 1).unwrap();
    assert_eq!(outs.len(), 12);
    assert_eq!(report.tasks, 12);
    assert_eq!(report.retries, 0);

    let stats = deploy::probe_stats(&spec);
    assert_eq!(stats.len(), 2);
    for w in &stats {
        assert!(w.error.is_none(), "stats fetch failed: {w:?}");
        assert!(w.worker_id.is_some(), "handshake lost the worker id: {w:?}");
    }
    assert_eq!(
        done_sum(&stats) - before,
        report.tasks as u64,
        "per-worker done counts must sum to the job's task total"
    );
    let failed: u64 = stats
        .iter()
        .filter_map(|w| w.snapshot.as_ref())
        .map(|s| s.counter("worker_tasks_failed"))
        .sum();
    assert_eq!(failed, 0, "clean job must not raise failure counters");

    // the rendered table (the `top` body) names every endpoint
    let table = deploy::render_stats(&stats);
    for w in &stats {
        assert!(table.contains(&w.addr), "endpoint missing from table:\n{table}");
    }

    // and the CLI itself sees the same live fleet
    let spec_path = std::env::temp_dir().join(format!(
        "av_simd_top_spec_{}.toml",
        std::process::id()
    ));
    std::fs::write(&spec_path, &toml).unwrap();
    let out = std::process::Command::new(launcher)
        .args(["top", "--cluster-spec", spec_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "av-simd top failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("top-test"), "cluster name missing:\n{stdout}");
    for p in &ports {
        assert!(
            stdout.contains(&format!("127.0.0.1:{p}")),
            "worker row missing:\n{stdout}"
        );
    }
    std::fs::remove_file(&spec_path).ok();

    cluster.stop_workers();
    drop(cluster);
    for c in &mut children {
        // shutdown was sent — reap the process, killing as a fallback
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match c.try_wait().unwrap() {
                Some(_) => break,
                None if std::time::Instant::now() >= deadline => {
                    let _ = c.kill();
                    let _ = c.wait();
                    break;
                }
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }
}

#[test]
fn probe_reports_mixed_fleet_health() {
    let (addr_up, h) = spawn_worker(5, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_up.clone(), "127.0.0.1:1".to_string()], 200);
    let health = deploy::probe(&spec);
    assert_eq!(health.len(), 2);
    assert!(health[0].ok(), "{:?}", health[0]);
    assert_eq!(health[0].worker_id, Some(5), "handshake must report the worker id");
    assert!(!health[1].ok());
    assert!(health[1].error.as_ref().unwrap().contains("127.0.0.1:1"));

    // the probe connection must not have consumed the worker: a real
    // cluster can still dial and use it afterwards
    let cluster = StandaloneCluster::connect(&spec_for(&[addr_up], 5000)).unwrap();
    let results = cluster.run_tasks(&[count_task(0, 9)]);
    assert_eq!(*results[0].as_ref().unwrap(), TaskOutput::Count(9));
    cluster.stop_workers();
    drop(cluster);
    h.join().unwrap();
}
