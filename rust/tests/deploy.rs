//! Deploy-layer integration tests: `ClusterSpec`-driven fleets,
//! handshake/version enforcement, and mid-stream worker admission.
//!
//! Unlike the spawn-based standalone tests (which need the release
//! binary on disk), these drive *in-process* worker servers —
//! `engine::worker::serve` on a thread speaks exactly the protocol a
//! worker process does, so the whole deploy path (dial → handshake →
//! stream → shutdown) runs under plain `cargo test`.

use av_simd::engine::deploy::{self, ClusterSpec};
use av_simd::engine::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use av_simd::engine::worker::serve;
use av_simd::engine::{Action, Cluster, OpRegistry, Source, StandaloneCluster, TaskOutput, TaskSpec};
use std::net::TcpListener;
use std::time::Duration;

/// Reserve an ephemeral port, then serve a worker on it from a thread.
/// (The listener is dropped and rebound by `serve` — the same pattern
/// the in-crate RPC tests use.)
fn spawn_worker(id: usize, registry: OpRegistry) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let a = addr.clone();
    let h = std::thread::spawn(move || {
        serve(&a, id, registry, "artifacts").unwrap();
    });
    (addr, h)
}

fn spec_for(addrs: &[String], timeout_ms: u64) -> ClusterSpec {
    let hosts = addrs
        .iter()
        .map(|a| format!("\"{a}\""))
        .collect::<Vec<_>>()
        .join(", ");
    ClusterSpec::from_toml_text(&format!(
        "[cluster]\nname = \"test\"\nconnect_timeout_ms = {timeout_ms}\n\
         [workers]\nhosts = [{hosts}]\n"
    ))
    .unwrap()
}

fn count_task(id: u32, n: u64) -> TaskSpec {
    TaskSpec {
        job_id: 1,
        task_id: id,
        attempt: 0,
        source: Source::Range { start: 0, end: n },
        ops: vec![],
        action: Action::Count,
    }
}

#[test]
fn cluster_spec_fleet_runs_tasks() {
    let (addr_a, h_a) = spawn_worker(0, OpRegistry::with_builtins());
    let (addr_b, h_b) = spawn_worker(1, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_a, addr_b], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();
    assert_eq!(cluster.workers(), 2);

    let tasks: Vec<TaskSpec> = (0..12).map(|i| count_task(i, (i as u64 + 1) * 5)).collect();
    let results = cluster.run_tasks(&tasks);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(*r.as_ref().unwrap(), TaskOutput::Count((i as u64 + 1) * 5));
    }

    // connect-mode `shutdown` leaves the fleet up (externally managed)
    cluster.shutdown();
    let again = cluster.run_tasks(&[count_task(0, 7)]);
    assert_eq!(*again[0].as_ref().unwrap(), TaskOutput::Count(7));

    // explicit stop tears the workers down so the threads join
    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn late_joining_worker_is_admitted_into_a_running_stream() {
    // every task stalls long enough that one worker alone would need
    // ~20x the join delay — the late joiner must end up serving tasks
    let stall_registry = || {
        let reg = OpRegistry::with_builtins();
        reg.register("stall_and_tag", |c, _p, _records| {
            std::thread::sleep(Duration::from_millis(60));
            Ok(vec![vec![c.worker_id as u8]])
        });
        reg
    };
    let (addr_a, h_a) = spawn_worker(1, stall_registry());
    let spec = spec_for(&[addr_a], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();
    assert_eq!(cluster.workers(), 1);

    const TASKS: u64 = 20;
    let stream = cluster.open_stream();
    for i in 0..TASKS {
        let mut t = count_task(i as u32, 1);
        t.ops.push(av_simd::engine::OpCall::new("stall_and_tag", vec![]));
        t.action = Action::Collect;
        stream.submit(i, t);
    }

    // admit worker 2 while the stream is mid-flight
    let (addr_b, h_b) = spawn_worker(2, stall_registry());
    std::thread::sleep(Duration::from_millis(50));
    cluster.add_worker(&addr_b, Duration::from_secs(5)).unwrap();
    assert_eq!(cluster.workers(), 2);

    let mut served_by: Vec<u8> = Vec::new();
    for _ in 0..TASKS {
        let c = stream.next_completion().expect("all tasks must complete");
        match c.result.unwrap() {
            TaskOutput::Records(rs) => served_by.push(rs[0][0]),
            other => panic!("unexpected {other:?}"),
        }
    }
    stream.close();

    assert_eq!(served_by.len() as u64, TASKS);
    assert!(
        served_by.contains(&2),
        "late-joining worker never served a task: {served_by:?}"
    );
    assert!(
        served_by.contains(&1),
        "original worker starved: {served_by:?}"
    );

    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn worker_added_before_a_stream_serves_later_jobs() {
    let (addr_a, h_a) = spawn_worker(0, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_a], 5000);
    let cluster = StandaloneCluster::connect(&spec).unwrap();

    let (addr_b, h_b) = spawn_worker(1, OpRegistry::with_builtins());
    cluster.add_worker(&addr_b, Duration::from_secs(5)).unwrap();
    assert_eq!(cluster.workers(), 2, "fleet grows with no stream open");

    let tasks: Vec<TaskSpec> = (0..8).map(|i| count_task(i, 3)).collect();
    let results = cluster.run_tasks(&tasks);
    assert!(results.iter().all(|r| *r.as_ref().unwrap() == TaskOutput::Count(3)));

    cluster.stop_workers();
    drop(cluster);
    h_a.join().unwrap();
    h_b.join().unwrap();
}

#[test]
fn version_mismatched_worker_is_rejected_at_cluster_connect() {
    // a fake worker that speaks a newer protocol version
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut writer = std::io::BufWriter::new(stream);
        if let Some(RpcMsg::Hello { .. }) = read_msg(&mut reader).unwrap() {
            write_msg(
                &mut writer,
                &RpcMsg::HelloOk { version: RPC_VERSION + 7, worker_id: 3 },
            )
            .unwrap();
        }
    });

    let spec = spec_for(&[addr.clone()], 5000);
    let err = match StandaloneCluster::connect(&spec) {
        Err(e) => e,
        Ok(_) => panic!("mismatched fleet must be rejected"),
    };
    let msg = err.to_string();
    assert!(msg.contains(&addr), "endpoint lost: {msg}");
    assert!(msg.contains("rpc v"), "version context lost: {msg}");
    assert!(msg.contains("test"), "cluster name lost: {msg}");
    h.join().unwrap();
}

#[test]
fn connect_failure_names_endpoint_and_attempts() {
    // port 1 is reserved: nothing will ever listen there
    let spec = spec_for(&["127.0.0.1:1".to_string()], 120);
    let err = match StandaloneCluster::connect(&spec) {
        Err(e) => e,
        Ok(_) => panic!("expected connect failure"),
    };
    let msg = err.to_string();
    assert!(msg.contains("127.0.0.1:1"), "endpoint lost: {msg}");
    assert!(msg.contains("attempt"), "attempt count lost: {msg}");
}

#[test]
fn probe_reports_mixed_fleet_health() {
    let (addr_up, h) = spawn_worker(5, OpRegistry::with_builtins());
    let spec = spec_for(&[addr_up.clone(), "127.0.0.1:1".to_string()], 200);
    let health = deploy::probe(&spec);
    assert_eq!(health.len(), 2);
    assert!(health[0].ok(), "{:?}", health[0]);
    assert_eq!(health[0].worker_id, Some(5), "handshake must report the worker id");
    assert!(!health[1].ok());
    assert!(health[1].error.as_ref().unwrap().contains("127.0.0.1:1"));

    // the probe connection must not have consumed the worker: a real
    // cluster can still dial and use it afterwards
    let cluster = StandaloneCluster::connect(&spec_for(&[addr_up], 5000)).unwrap();
    let results = cluster.run_tasks(&[count_task(0, 9)]);
    assert_eq!(*results[0].as_ref().unwrap(), TaskOutput::Count(9));
    cluster.stop_workers();
    drop(cluster);
    h.join().unwrap();
}
