//! Distributed bag-replay integration suite: the determinism contract
//! (`ReplayReport` bytes identical across backends × worker counts ×
//! slice sizes, and equal to the single-process reference), retry
//! robustness under skewed slices, and codec property tests for the
//! replay wire types.
//!
//! Standalone clusters drive *in-process* `worker::serve` threads over
//! real TCP (the deploy-test pattern), so the whole suite runs under
//! plain `cargo test` with no release binary on disk.

use av_simd::engine::deploy::ClusterSpec;
use av_simd::engine::{run_job, worker, DataRef, LocalCluster, StandaloneCluster, TaskOutput};
use av_simd::sim::replay::{
    slices_from_cuts, write_fixture_bag, ReplayParams, ReplaySlice, ReplaySpec, ReplayVerdict,
    SliceJob,
};
use av_simd::sim::ReplayDriver;
use av_simd::util::proptest::{check_n, gen};
use av_simd::util::prng::Prng;
use std::net::TcpListener;
use std::time::Duration;

fn artifact_dir() -> String {
    std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Shared fixture bags: each `(frames, seed)` configuration is
/// generated **once** per test process (fixture generation runs full
/// synthetic episodes, so regenerating per test dominated suite time)
/// and handed out read-only. The content hash recorded at build time is
/// re-verified on every borrow, so a test that mutates a shared bag
/// fails the next borrower loudly instead of silently poisoning the
/// suite. Tests that *delete* their bag take a [`private_fixture`]
/// copy.
fn shared_fixture(frames: u32, seed: u64) -> String {
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::{Mutex, OnceLock};
    static BAGS: OnceLock<Mutex<HashMap<(u32, u64), (PathBuf, [u8; 32])>>> = OnceLock::new();
    let mut map = BAGS.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    let (path, built_hash) = map.entry((frames, seed)).or_insert_with(|| {
        let dir = std::env::temp_dir().join("av_simd_replay_it");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("shared_{frames}_{seed}_{}.bag", std::process::id()));
        write_fixture_bag(path.to_str().unwrap(), frames, seed).unwrap();
        let hash = av_simd::util::sha256::digest(&std::fs::read(&path).unwrap());
        (path, hash)
    });
    assert_eq!(
        av_simd::util::sha256::digest(&std::fs::read(&path).unwrap()),
        *built_hash,
        "a test mutated the shared fixture bag {}",
        path.display()
    );
    path.to_str().unwrap().to_string()
}

/// A private copy of the shared `(frames, seed)` bag for tests that
/// delete the file mid-test (the shared original stays untouched).
fn private_fixture(tag: &str, frames: u32, seed: u64) -> String {
    let src = shared_fixture(frames, seed);
    let path = std::env::temp_dir()
        .join("av_simd_replay_it")
        .join(format!("{tag}_{}.bag", std::process::id()));
    std::fs::copy(&src, &path).unwrap();
    path.to_str().unwrap().to_string()
}

/// Every shared configuration stays byte-identical to its build-time
/// hash (the borrow itself asserts it) no matter what the rest of the
/// suite did — including the configs whose users delete their bags.
#[test]
fn shared_fixture_bags_stay_pristine() {
    for (frames, seed) in [(16u32, 42u64), (24, 7), (20, 13), (12, 5), (8, 9), (12, 11)] {
        let first = shared_fixture(frames, seed);
        let again = shared_fixture(frames, seed);
        assert_eq!(first, again, "shared fixture must be built exactly once");
    }
}

/// Reserve an ephemeral port, then serve a worker on it from a thread.
fn spawn_worker(id: usize) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let a = addr.clone();
    let dir = artifact_dir();
    let h = std::thread::spawn(move || {
        worker::serve(&a, id, av_simd::full_op_registry(), &dir).unwrap();
    });
    (addr, h)
}

/// Connect a cluster to already-serving workers (connect mode: dropping
/// the cluster leaves the workers up).
fn connect_cluster(addrs: &[String]) -> StandaloneCluster {
    let hosts: Vec<String> = addrs.iter().map(|a| format!("\"{a}\"")).collect();
    let spec = ClusterSpec::from_toml_text(&format!(
        "[cluster]\nname = \"replay-test\"\nconnect_timeout_ms = 5000\n\
         [workers]\nhosts = [{}]\n",
        hosts.join(", ")
    ))
    .unwrap();
    StandaloneCluster::connect(&spec).unwrap()
}

fn standalone(n: usize) -> (StandaloneCluster, Vec<std::thread::JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (addr, h) = spawn_worker(i);
        addrs.push(addr);
        handles.push(h);
    }
    (connect_cluster(&addrs), handles)
}

/// The acceptance matrix: {local, standalone} × {1, 2, 4 workers} ×
/// {3, 7 slices}, every report byte-equal to the single-process
/// reference replay.
#[test]
fn report_bytes_identical_across_backends_workers_and_slice_sizes() {
    let bag = shared_fixture(16, 42);
    let reference = {
        let spec = ReplaySpec { bag: bag.clone(), ..ReplaySpec::default() };
        ReplayDriver::new(spec).reference(&artifact_dir()).unwrap()
    };
    assert_eq!(reference.stats.frames, 16, "{:?}", reference.stats);
    assert_eq!(reference.stats.odom.pairs + reference.stats.odom.skipped, 15);

    for slices in [3usize, 7] {
        let spec = ReplaySpec { bag: bag.clone(), slices, ..ReplaySpec::default() };
        let driver = ReplayDriver::new(spec);
        let (index, plan) = driver.plan().unwrap();
        assert!(plan.len() >= 2, "slicing degenerated to {} slice(s)", plan.len());

        for workers in [1usize, 2, 4] {
            // local (thread pool)
            let local = LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir());
            let report = driver.run_planned(&local, &index, &plan).unwrap();
            assert_eq!(
                report.encode(),
                reference.encode(),
                "local x{workers}, {slices} slices diverged"
            );

            // standalone (worker processes over TCP — in-process serve)
            let (cluster, handles) = standalone(workers);
            let report = driver.run_planned(&cluster, &index, &plan).unwrap();
            assert_eq!(
                report.encode(),
                reference.encode(),
                "standalone x{workers}, {slices} slices diverged"
            );
            cluster.stop_workers();
            for h in handles {
                h.join().unwrap();
            }
        }
    }
}

/// Skewed-slice stress: one slice covering ~10× the timeline of the
/// others, with a transient first-attempt failure injected into every
/// task — verdict bytes must still equal the clean run's, byte for
/// byte, and the retry must actually happen.
#[test]
fn skewed_slices_with_retries_keep_verdict_bytes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    let bag = shared_fixture(24, 7);
    let spec = ReplaySpec { bag: bag.clone(), slices: 12, ..ReplaySpec::default() };
    let driver = ReplayDriver::new(spec);
    let (index, _) = driver.plan().unwrap();

    // custom cuts: merge the first 10 of 12 balanced slices into one
    let cuts = index.cut_points(12);
    assert!(cuts.len() == 13, "need 12 distinct slices, got {}", cuts.len() - 1);
    let skewed_cuts = vec![cuts[0], cuts[10], cuts[11], cuts[12]];
    let slices = slices_from_cuts(&skewed_cuts, driver.effective_warmup(&index));
    assert_eq!(slices.len(), 3);
    assert!(
        (slices[0].end - slices[0].start) > 5 * (slices[1].end - slices[1].start),
        "slice 0 is not skewed: {slices:?}"
    );

    // clean distributed run
    let local = LocalCluster::new(3, av_simd::full_op_registry(), &artifact_dir());
    let clean = driver.run_planned(&local, &index, &slices).unwrap();
    assert_eq!(clean.encode(), driver.reference(&artifact_dir()).unwrap().encode());

    // poisoned run: every task fails its first attempt, then succeeds
    let reg = av_simd::full_op_registry();
    let seen = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
    let trips = Arc::new(AtomicUsize::new(0));
    let (s2, t2) = (seen.clone(), trips.clone());
    reg.register("poison_once", move |_c, params, records| {
        let task_id = params.first().copied().unwrap_or(0);
        if s2.lock().unwrap().insert(task_id) {
            t2.fetch_add(1, Ordering::SeqCst);
            return Err(av_simd::err!(Engine, "transient poison on task {task_id}"));
        }
        Ok(records)
    });
    let cluster = LocalCluster::new(3, reg, &artifact_dir());
    let mut tasks = driver.tasks(&slices);
    for t in &mut tasks {
        t.ops.insert(
            0,
            av_simd::engine::OpCall::new("poison_once", vec![t.task_id as u8]),
        );
    }
    let n_tasks = tasks.len();
    let (outs, job) = run_job(&cluster, tasks, 2).unwrap();
    assert_eq!(job.retries, n_tasks, "every task retried exactly once");
    assert_eq!(trips.load(Ordering::SeqCst), n_tasks);

    let mut verdicts = Vec::new();
    for out in outs {
        match out {
            TaskOutput::Replays(rs) => {
                assert_eq!(rs.len(), 1);
                verdicts.push(ReplayVerdict::decode(&rs[0]).unwrap());
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    let poisoned = driver.aggregate(&index, &slices, verdicts).unwrap();
    assert_eq!(
        poisoned.encode(),
        clean.encode(),
        "retries changed the replay verdicts"
    );
}

/// The data-plane acceptance bar: the same bag replayed via
/// worker-local path vs. manifest-based block fetch produces
/// byte-identical `ReplayReport`s, across {local, standalone-from-
/// ClusterSpec} × {1, 2, 4 workers} — with the bag *file deleted*
/// before any manifest-based run, so no worker (wherever its cwd) can
/// possibly resolve the path. The bytes must come through the engine.
#[test]
fn manifest_replay_bytes_equal_path_replay_without_the_bag_file() {
    let bag = private_fixture("dataplane", 16, 42);
    let spec = ReplaySpec { bag: bag.clone(), slices: 5, ..ReplaySpec::default() };

    // path-based reference, while the file still exists
    let by_path = ReplayDriver::new(spec.clone())
        .reference(&artifact_dir())
        .unwrap();

    // publish into a block store, then delete the bag file
    let store_root = std::env::temp_dir().join(format!(
        "av_simd_replay_it_store_{}",
        std::process::id()
    ));
    let mut driver = ReplayDriver::new(spec.clone());
    driver.publish(&store_root, "127.0.0.1").unwrap();
    std::fs::remove_file(&bag).unwrap();

    // the path no longer resolves anywhere — a path-based driver fails
    let err = ReplayDriver::new(spec).plan().unwrap_err();
    assert!(err.to_string().contains(&bag), "{err}");

    // planning off the published store still works (BagIndex scans the
    // verified blocks directly)
    let (index, plan) = driver.plan().unwrap();
    assert!(plan.len() >= 2, "slicing degenerated to {} slice(s)", plan.len());

    for workers in [1usize, 2, 4] {
        let local = LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir());
        let report = driver.run_planned(&local, &index, &plan).unwrap();
        assert_eq!(
            report.encode(),
            by_path.encode(),
            "manifest-based local x{workers} diverged from the path-based report"
        );

        let (cluster, handles) = standalone(workers);
        let report = driver.run_planned(&cluster, &index, &plan).unwrap();
        assert_eq!(
            report.encode(),
            by_path.encode(),
            "manifest-based standalone x{workers} diverged from the path-based report"
        );
        cluster.stop_workers();
        for h in handles {
            h.join().unwrap();
        }
    }
    // the manifest-based reference replay matches too (fetches from the
    // driver's own block server over loopback)
    assert_eq!(driver.reference(&artifact_dir()).unwrap().encode(), by_path.encode());
    std::fs::remove_dir_all(&store_root).ok();
}

/// The swarm acceptance bar: once one worker's block cache is warm, the
/// driver's copy of the blocks can disappear entirely — a cold sibling
/// joining the cluster still completes a manifest-only replay because
/// the warm worker advertised its cache (piggybacked `BlockAd`s) and
/// the provider orders it ahead of the driver in every task's peer
/// list.
#[test]
fn cold_worker_fetches_from_warm_sibling_after_driver_store_is_gone() {
    use av_simd::engine::{Action, Cluster, Source, TaskSpec};

    let bag = private_fixture("swarm", 12, 11);
    let spec = ReplaySpec { bag: bag.clone(), slices: 6, ..ReplaySpec::default() };
    let by_path = ReplayDriver::new(spec.clone()).reference(&artifact_dir()).unwrap();

    let store_root = std::env::temp_dir().join(format!(
        "av_simd_replay_it_swarm_{}",
        std::process::id()
    ));
    let mut driver = ReplayDriver::new(spec);
    let id = driver.publish(&store_root, "127.0.0.1").unwrap();
    std::fs::remove_file(&bag).unwrap();
    let (index, plan) = driver.plan().unwrap();

    // warm exactly one worker: a 1-worker cluster runs the whole replay,
    // so that worker's cache materializes every block of the manifest
    let (w1_addr, w1_handle) = spawn_worker(0);
    let one = connect_cluster(std::slice::from_ref(&w1_addr));
    let warm = driver.run_planned(&one, &index, &plan).unwrap();
    assert_eq!(warm.encode(), by_path.encode());
    drop(one); // connect mode: worker 0 keeps serving, cache intact

    // a cold sibling joins; the fresh cluster's swarm registry fills in
    // from ads riding on task replies, so run cheap count jobs until the
    // warm worker has answered (and advertised) at least once
    let (w2_addr, w2_handle) = spawn_worker(1);
    let cluster = connect_cluster(&[w1_addr, w2_addr]);
    let swarm = cluster.swarm().expect("standalone clusters track a swarm");
    for round in 0..50u32 {
        if !swarm.peers_for(&id).is_empty() {
            break;
        }
        let tasks: Vec<TaskSpec> = (0..4)
            .map(|i| TaskSpec {
                job_id: 9,
                task_id: round * 4 + i,
                attempt: 0,
                source: Source::Range { start: 0, end: 10 },
                ops: vec![],
                action: Action::Count,
            })
            .collect();
        run_job(&cluster, tasks, 1).unwrap();
    }
    assert!(
        !swarm.peers_for(&id).is_empty(),
        "warm worker never advertised its block cache"
    );

    // delete the driver's block store: from here on the *only* source of
    // the bag bytes is the warm worker's cache
    std::fs::remove_dir_all(&store_root).unwrap();

    // replay until the cold worker has served a manifest task (both
    // workers advertising proves it became resident — and with the
    // driver's store gone, those bytes can only have come from its
    // sibling); every run must stay byte-identical
    for _ in 0..20 {
        let report = driver.run_planned(&cluster, &index, &plan).unwrap();
        assert_eq!(report.encode(), by_path.encode(), "swarm-fetched replay diverged");
        if swarm.peers_for(&id).len() >= 2 {
            break;
        }
    }
    assert!(
        swarm.peers_for(&id).len() >= 2,
        "cold worker never became resident via its sibling: {:?}",
        swarm.peers_for(&id)
    );

    cluster.stop_workers();
    w1_handle.join().unwrap();
    w2_handle.join().unwrap();
    std::fs::remove_dir_all(&store_root).ok();
}

/// The crash-resume acceptance bar: a replay driver killed by
/// deterministic fault injection after 25% / 60% of its slices resolved
/// must, on restart against the same checkpoint store, re-execute
/// *only* the missing slices and produce a report byte-identical to an
/// uninterrupted run — across {local, standalone} × {1, 2 workers}.
#[test]
fn crashed_driver_resumes_to_byte_identical_report() {
    use av_simd::engine::{CheckpointConfig, FaultPlan};

    let bag = shared_fixture(20, 13);
    let spec = ReplaySpec { bag: bag.clone(), slices: 5, ..ReplaySpec::default() };
    let driver = ReplayDriver::new(spec.clone());
    let (index, plan) = driver.plan().unwrap();
    assert_eq!(plan.len(), 5, "fixture produced {} slice(s)", plan.len());
    let reference = {
        let local = LocalCluster::new(2, av_simd::full_op_registry(), &artifact_dir());
        driver.run_planned(&local, &index, &plan).unwrap()
    };

    // abort after 1 of 5 (25%) and 3 of 5 (60%) completions; the
    // scheduler folds exactly that many outputs into the checkpoint
    // before dying, so the resume workload is deterministic too
    for abort_after in [1usize, 3] {
        for workers in [1usize, 2] {
            // local backend
            {
                let root = std::env::temp_dir()
                    .join(format!(
                        "av_simd_crash_resume_l{abort_after}_{workers}_{}",
                        std::process::id()
                    ))
                    .to_str()
                    .unwrap()
                    .to_string();
                let cluster =
                    LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir());
                let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: false };
                let err = ReplayDriver::new(spec.clone())
                    .with_faults(FaultPlan::none().abort_driver_after(abort_after as u64))
                    .run_planned_checkpointed(&cluster, &index, &plan, &cfg)
                    .unwrap_err();
                assert!(
                    err.to_string().contains("fault injection"),
                    "local x{workers}: expected an injected driver abort, got: {err}"
                );

                let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: true };
                let resumed = ReplayDriver::new(spec.clone())
                    .run_planned_checkpointed(&cluster, &index, &plan, &cfg)
                    .unwrap();
                assert_eq!(
                    resumed.encode(),
                    reference.encode(),
                    "local x{workers}, abort@{abort_after}: resumed report diverged"
                );
                assert_eq!(
                    resumed.tasks,
                    plan.len() - abort_after,
                    "local x{workers}, abort@{abort_after}: resume re-ran resolved slices"
                );
                std::fs::remove_dir_all(&root).ok();
            }
            // standalone backend (fleet survives the driver crash; the
            // resumed driver dials the same workers)
            {
                let root = std::env::temp_dir()
                    .join(format!(
                        "av_simd_crash_resume_s{abort_after}_{workers}_{}",
                        std::process::id()
                    ))
                    .to_str()
                    .unwrap()
                    .to_string();
                let (cluster, handles) = standalone(workers);
                let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: false };
                let err = ReplayDriver::new(spec.clone())
                    .with_faults(FaultPlan::none().abort_driver_after(abort_after as u64))
                    .run_planned_checkpointed(&cluster, &index, &plan, &cfg)
                    .unwrap_err();
                assert!(
                    err.to_string().contains("fault injection"),
                    "standalone x{workers}: expected an injected driver abort, got: {err}"
                );

                let cfg = CheckpointConfig { root: root.clone(), every: 1, resume: true };
                let resumed = ReplayDriver::new(spec.clone())
                    .run_planned_checkpointed(&cluster, &index, &plan, &cfg)
                    .unwrap();
                assert_eq!(
                    resumed.encode(),
                    reference.encode(),
                    "standalone x{workers}, abort@{abort_after}: resumed report diverged"
                );
                assert_eq!(
                    resumed.tasks,
                    plan.len() - abort_after,
                    "standalone x{workers}, abort@{abort_after}: resume re-ran resolved \
                     slices"
                );
                cluster.stop_workers();
                for h in handles {
                    h.join().unwrap();
                }
                std::fs::remove_dir_all(&root).ok();
            }
        }
    }
}

/// Speculative re-execution must change *when* attempts run, never what
/// the report says: across backends × worker counts, with speculation
/// off and with an aggressive policy that duplicates nearly every task,
/// the report bytes equal the single-process reference.
#[test]
fn speculative_replay_bytes_match_reference_across_backends() {
    use av_simd::engine::Speculation;

    let bag = shared_fixture(12, 5);
    let spec = ReplaySpec { bag: bag.clone(), slices: 5, ..ReplaySpec::default() };
    let reference = ReplayDriver::new(spec.clone()).reference(&artifact_dir()).unwrap();

    // multiplier 0 drops the straggler threshold to its 1 ms floor, so
    // multi-worker runs really do launch duplicate attempts
    let aggressive = Speculation { enabled: true, multiplier: 0.0, min_samples: 1 };
    for speculation in [Speculation::default(), aggressive] {
        let driver = ReplayDriver::new(spec.clone()).with_speculation(speculation);
        let (index, plan) = driver.plan().unwrap();
        for workers in [1usize, 2, 4] {
            let local = LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir());
            let report = driver.run_planned(&local, &index, &plan).unwrap();
            assert_eq!(
                report.encode(),
                reference.encode(),
                "local x{workers}, speculation {speculation:?} diverged"
            );

            let (cluster, handles) = standalone(workers);
            let report = driver.run_planned(&cluster, &index, &plan).unwrap();
            assert_eq!(
                report.encode(),
                reference.encode(),
                "standalone x{workers}, speculation {speculation:?} diverged"
            );
            cluster.stop_workers();
            for h in handles {
                h.join().unwrap();
            }
        }
    }
}

/// A worker losing its block peer mid-job must surface a *retryable*
/// task error naming the manifest id, the block index, and the peer's
/// `host:port` (the PR-3 connect-error convention), so the scheduler
/// retries it and — when the peer never comes back — the job error
/// tells the operator exactly which fetch broke.
#[test]
fn lost_block_peer_is_retryable_and_names_manifest_block_and_peer() {
    use av_simd::engine::TaskCtx;

    let bag = shared_fixture(8, 9);
    let spec = ReplaySpec {
        bag: bag.clone(),
        slices: 2,
        max_retries: 1,
        ..ReplaySpec::default()
    };
    let store_root = std::env::temp_dir().join(format!(
        "av_simd_replay_it_lost_{}",
        std::process::id()
    ));
    let mut driver = ReplayDriver::new(spec);
    let id = driver.publish(&store_root, "127.0.0.1").unwrap();
    let (_, peer) = driver.published().unwrap();
    let (_, plan) = driver.plan().unwrap();
    let tasks = driver.tasks(&plan);
    // kill the peer: the manifest-based tasks now point at a dead addr
    driver.stop_publishing();

    // executor-level: the fetch failure is retryable and fully named
    let ctx = TaskCtx::new(0, artifact_dir());
    let reg = av_simd::full_op_registry();
    let err = av_simd::engine::executor::run_task(&ctx, &reg, &tasks[0]).unwrap_err();
    let msg = err.to_string();
    assert!(err.is_retryable(), "lost peer must be retryable: {msg}");
    assert!(msg.contains(&peer), "peer host:port lost: {msg}");

    // job-level: the scheduler retries, exhausts the budget, and the
    // job error still names the peer
    let cluster = LocalCluster::new(2, av_simd::full_op_registry(), &artifact_dir());
    let err = run_job(&cluster, tasks, 1).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains(&peer), "job error lost the peer: {msg}");
    assert!(
        msg.contains(&id.short()) || msg.contains("manifest"),
        "job error lost the manifest: {msg}"
    );
    std::fs::remove_dir_all(&store_root).ok();
}

/// A poisoned slice record in `Source::BagSlices` must fail the task
/// fast with a non-retryable error (data corruption, not a transient).
#[test]
fn poisoned_slice_record_fails_fast() {
    use av_simd::engine::{Action, OpCall, Source, TaskCtx, TaskSpec};

    let reg = av_simd::full_op_registry();
    let ctx = TaskCtx::new(0, artifact_dir());
    let spec = TaskSpec {
        job_id: 1,
        task_id: 0,
        attempt: 0,
        source: Source::BagSlices {
            data: DataRef::path("/nonexistent.bag"),
            topics: vec![],
            slices: vec![vec![0xff; 7]],
        },
        ops: vec![OpCall::new("run_replay", ReplayParams { rate: f64::INFINITY }.encode())],
        action: Action::Replays,
    };
    let err = av_simd::engine::executor::run_task(&ctx, &reg, &spec).unwrap_err();
    assert!(err.to_string().contains("poisoned"), "{err}");
    assert!(!err.is_retryable(), "corrupt slice must not be retried");
}

// ------------------------------------------------------------------
// trace acceptance: tracing must never change report bytes, and a
// traced standalone replay must account for ≥95% of task wall time
// ------------------------------------------------------------------

use av_simd::engine::trace::{self, TraceLog};

/// Trace tests install the process-global sink; serialize them so two
/// tests never fight over it (install is last-caller-wins).
fn trace_serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// The observability acceptance matrix: across {local, standalone} ×
/// {1, 2, 4 workers}, the replay report bytes are identical with the
/// trace sink installed or absent — tracing observes execution, it
/// never participates in it.
#[test]
fn traced_replay_report_bytes_identical_across_backends_and_workers() {
    let _serial = trace_serial();
    let bag = shared_fixture(16, 42);
    let spec = ReplaySpec { bag, slices: 5, ..ReplaySpec::default() };
    let driver = ReplayDriver::new(spec);
    let (index, plan) = driver.plan().unwrap();
    let reference = driver.reference(&artifact_dir()).unwrap();

    for workers in [1usize, 2, 4] {
        let local = LocalCluster::new(workers, av_simd::full_op_registry(), &artifact_dir());
        let off = driver.run_planned(&local, &index, &plan).unwrap();
        let log = TraceLog::new();
        let on = {
            let _guard = trace::install(log.clone());
            driver.run_planned(&local, &index, &plan).unwrap()
        };
        assert_eq!(
            on.encode(),
            off.encode(),
            "tracing changed local x{workers} report bytes"
        );
        assert_eq!(off.encode(), reference.encode(), "local x{workers} diverged");
        assert!(!log.is_empty(), "traced local x{workers} run recorded nothing");

        let (cluster, handles) = standalone(workers);
        let off = driver.run_planned(&cluster, &index, &plan).unwrap();
        let log = TraceLog::new();
        let on = {
            let _guard = trace::install(log.clone());
            driver.run_planned(&cluster, &index, &plan).unwrap()
        };
        assert_eq!(
            on.encode(),
            off.encode(),
            "tracing changed standalone x{workers} report bytes"
        );
        assert_eq!(off.encode(), reference.encode(), "standalone x{workers} diverged");
        assert!(!log.is_empty(), "traced standalone x{workers} run recorded nothing");
        cluster.stop_workers();
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// The trace-quality acceptance bar, over a real TCP fleet: worker
/// `task` spans must cover ≥ 95% of driver-observed task wall time,
/// every executed task must have shipped a span batch back, the
/// perception stages must all be present, and the exported Chrome
/// `trace_event` JSON must be loadable (structurally balanced, one
/// complete event per merged trace entry).
#[test]
fn standalone_traced_replay_covers_task_wall_and_exports_chrome_json() {
    use std::collections::BTreeSet;

    let _serial = trace_serial();
    let bag = shared_fixture(24, 7);
    let spec = ReplaySpec { bag, slices: 6, ..ReplaySpec::default() };
    let driver = ReplayDriver::new(spec);
    let (index, plan) = driver.plan().unwrap();

    let (cluster, handles) = standalone(2);
    let log = TraceLog::new();
    {
        let _guard = trace::install(log.clone());
        driver.run_planned(&cluster, &index, &plan).unwrap();
    }
    cluster.stop_workers();
    for h in handles {
        h.join().unwrap();
    }

    let events = log.events();
    // every attempt the driver timed has a worker-side task span
    let walled: BTreeSet<u32> = events
        .iter()
        .filter(|e| e.name == "task_wall")
        .map(|e| e.ctx.task_id)
        .collect();
    let spanned: BTreeSet<u32> = events
        .iter()
        .filter(|e| e.worker.is_some() && e.name == "task")
        .map(|e| e.ctx.task_id)
        .collect();
    assert_eq!(walled.len(), plan.len(), "driver timed {walled:?}");
    assert!(
        spanned.is_superset(&walled),
        "tasks without worker spans: {:?}",
        walled.difference(&spanned).collect::<Vec<_>>()
    );

    // coverage: worker task spans vs. driver-observed wall (the gap is
    // RPC framing + result decode, which must stay under 5%)
    let wall_ns: u64 = events
        .iter()
        .filter(|e| e.name == "task_wall")
        .map(|e| e.dur_ns)
        .sum();
    let task_ns: u64 = events
        .iter()
        .filter(|e| e.worker.is_some() && e.name == "task")
        .map(|e| e.dur_ns)
        .sum();
    assert!(wall_ns > 0, "driver observed no task wall time");
    let coverage = task_ns as f64 / wall_ns as f64;
    assert!(
        coverage >= 0.95,
        "worker spans cover only {:.1}% of task wall time",
        coverage * 100.0
    );

    // the perception stages and scheduler events all surfaced
    let names: BTreeSet<&str> = events.iter().map(|e| e.name.as_str()).collect();
    for required in [
        "submit", "queue_wait", "task_wall", "task", "source_load", "chunk_decode",
        "classify", "segment", "descriptors", "icp",
    ] {
        assert!(names.contains(required), "stage {required:?} missing from {names:?}");
    }

    // Chrome export: one complete ("ph":"X") event per merged entry,
    // structurally balanced outside string literals
    let path = std::env::temp_dir().join(format!(
        "av_simd_replay_it_trace_{}.json",
        std::process::id()
    ));
    log.write_chrome(&path).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(
        json.matches("\"ph\":\"X\"").count(),
        log.len(),
        "event count mismatch in chrome export"
    );
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in json.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => {
                depth -= 1;
                assert!(depth >= 0, "unbalanced chrome JSON");
            }
            _ => {}
        }
    }
    assert!(!in_str && depth == 0, "chrome JSON did not close cleanly");
}

// ------------------------------------------------------------------
// codec property tests
// ------------------------------------------------------------------

fn gen_spec(rng: &mut Prng) -> ReplaySpec {
    ReplaySpec {
        bag: format!("/data/{}.bag", gen::ident(rng, 12)),
        topics: gen::vec_of(rng, 4, |r| format!("/{}", gen::ident(r, 8))),
        slices: 1 + rng.below(64) as usize,
        warmup: Duration::from_nanos(rng.below(5_000_000_000)),
        rate: match rng.below(3) {
            0 => f64::INFINITY,
            1 => 0.0,
            _ => (1 + rng.below(1000)) as f64 / 10.0,
        },
        max_retries: rng.below(5) as usize,
    }
}

fn gen_verdict(rng: &mut Prng) -> ReplayVerdict {
    use av_simd::sim::replay::{
        ControlStats, LoopStats, OdometryStats, ReplayStats, SegStats, TopicStats,
    };
    let mut topics = std::collections::BTreeMap::new();
    for _ in 0..rng.below(5) {
        let t = TopicStats {
            messages: rng.below(10_000),
            gap_hist: std::array::from_fn(|_| rng.below(1000)),
        };
        topics.insert(format!("/{}", gen::ident(rng, 8)), t);
    }
    let messages = topics.values().map(|t: &TopicStats| t.messages).sum();
    let stats = ReplayStats {
        messages,
        topics,
        frames: rng.below(1000),
        detections: std::array::from_fn(|_| rng.below(500)),
        odom: OdometryStats {
            pairs: rng.below(1000),
            skipped: rng.below(10),
            abs_dx_um: rng.below(1 << 40) as i64 - (1 << 39),
            abs_dy_um: rng.below(1 << 40) as i64 - (1 << 39),
            abs_dtheta_urad: rng.below(1 << 30) as i64 - (1 << 29),
            travel_um: rng.below(1 << 40) as i64,
        },
        ctrl: ControlStats {
            pairs: rng.below(1000),
            emergency: rng.below(100),
            brake_cmds: rng.below(100),
            max_brake_q: rng.below(10_000_000) as i64,
            divergence_q: rng.below(1 << 40) as i64,
        },
        seg: SegStats {
            frames: rng.below(1000),
            pixels: std::array::from_fn(|_| rng.below(1 << 20)),
        },
        loops: LoopStats {
            pairs: rng.below(1000),
            similarity_q: rng.below(1 << 30) as i64 - (1 << 29),
            low_similarity: rng.below(100),
        },
    };
    ReplayVerdict { slice: rng.below(1 << 16) as u32, stats }
}

#[test]
fn replay_spec_codec_roundtrips() {
    check_n(
        "replay spec roundtrip",
        av_simd::util::proptest::default_cases(),
        gen_spec,
        |spec| {
            // byte-level fixpoint: tolerant of non-finite rate values
            let enc = spec.encode();
            match ReplaySpec::decode(&enc) {
                Ok(back) => back.encode() == enc,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn replay_verdict_codec_roundtrips() {
    check_n(
        "replay verdict roundtrip",
        av_simd::util::proptest::default_cases(),
        gen_verdict,
        |v| ReplayVerdict::decode(&v.encode()).map(|b| b == *v).unwrap_or(false),
    );
}

#[test]
fn replay_report_codec_roundtrips() {
    use av_simd::sim::ReplayReport;
    check_n(
        "replay report roundtrip",
        av_simd::util::proptest::default_cases(),
        |rng| {
            let v = gen_verdict(rng);
            let start = rng.below(1 << 40);
            ReplayReport {
                start,
                end: start + 1 + rng.below(1 << 40),
                stats: v.stats,
                slices: 3,
                tasks: 3,
                retries: 1,
                speculations: 1,
                wall: Duration::from_millis(5),
            }
        },
        |r| {
            let enc = r.encode();
            match ReplayReport::decode(&enc) {
                Ok(back) => {
                    // execution facts are not part of the payload
                    back.encode() == enc
                        && back.stats == r.stats
                        && back.start == r.start
                        && back.end == r.end
                        && back.wall == Duration::ZERO
                }
                Err(_) => false,
            }
        },
    );
}

/// Slices and slice jobs: structured roundtrip plus rejection of
/// inverted windows.
#[test]
fn slice_codecs_roundtrip_under_fuzz() {
    check_n(
        "slice job roundtrip",
        av_simd::util::proptest::default_cases(),
        |rng| {
            let start = rng.below(1 << 50);
            let data = if rng.next_bool(0.5) {
                DataRef::path(format!("/bags/{}.bag", gen::ident(rng, 10)))
            } else {
                let mut id = [0u8; 32];
                rng.fill_bytes(&mut id);
                // 1–3 peers: the list must be non-empty to validate
                let peers = (0..1 + rng.below(3))
                    .map(|_| format!("{}:{}", gen::ident(rng, 8), 1 + rng.below(65_000)))
                    .collect();
                DataRef::Manifest { id: av_simd::storage::ManifestId(id), peers }
            };
            SliceJob {
                data,
                topics: gen::vec_of(rng, 3, |r| format!("/{}", gen::ident(r, 6))),
                slice: ReplaySlice {
                    index: rng.below(1 << 20) as u32,
                    warmup_start: start.saturating_sub(rng.below(1 << 20)),
                    start,
                    end: start + 1 + rng.below(1 << 30),
                },
            }
        },
        |job| SliceJob::decode(&job.encode()).map(|b| b == *job).unwrap_or(false),
    );
}
