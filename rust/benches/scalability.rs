//! Fig 7 reproduction — system scalability.
//!
//! Paper §4.2: "With the increase of computing resources, the
//! calculation time is also linearly reduced … it takes 3 hours to
//! process images using stand-alone processing, and only 25 minutes
//! after using eight Spark workers" (≈7.2× on 8 workers), plus the
//! 10,000-worker extrapolation over the Google-scale dataset.
//!
//! **Testbed substitution (DESIGN.md):** this container has ONE CPU
//! core, so CPU-bound DNN work cannot physically speed up with more
//! workers. Part 1 therefore runs the paper's workload shape with the
//! per-image compute replaced by a calibrated stall (50 ms/frame ≈ a
//! scaled §2.3 "0.3 s per image"); everything else — partitioning,
//! scheduling, task dispatch, collection — is the real platform path,
//! and the near-linear curve measures the *platform's* scaling overhead,
//! which is what Fig 7 claims. Part 2 reports the real PJRT
//! classification path for honesty (flat-to-degrading on 1 core).

use av_simd::engine::SimContext;
use av_simd::util::bench::fmt_duration;
use std::time::Instant;

fn sweep(
    title: &str,
    total: u32,
    run: impl Fn(&SimContext) -> u64,
) -> Vec<(usize, f64, f64)> {
    println!("\n== {title} ==");
    println!(
        "{:>8} {:>12} {:>14} {:>9} {:>11}",
        "workers", "wall", "frames/s", "speedup", "efficiency"
    );
    let mut t1 = None;
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let sc = SimContext::local(workers);
        let t = Instant::now();
        let n = run(&sc);
        let wall = t.elapsed();
        assert_eq!(n, total as u64);
        let base = *t1.get_or_insert(wall.as_secs_f64());
        let speedup = base / wall.as_secs_f64();
        println!(
            "{workers:>8} {:>12} {:>14.1} {:>8.2}x {:>10.1}%",
            fmt_duration(wall),
            total as f64 / wall.as_secs_f64(),
            speedup,
            100.0 * speedup / workers as f64
        );
        rows.push((workers, wall.as_secs_f64(), speedup));
        sc.shutdown();
    }
    rows
}

fn main() {
    let partitions = 16usize;

    // ---- Part 1: Fig 7 curve with calibrated per-frame compute ----
    let frames_each: u32 = 8;
    let total = partitions as u32 * frames_each;
    let stall_us: u64 = 50_000; // 50 ms/frame ≈ scaled paper 0.3 s/image
    let rows = sweep(
        &format!(
            "Fig 7: platform scaling, {total} frames x {} ms simulated perception",
            stall_us / 1000
        ),
        total,
        |sc| {
            sc.synth_frames(partitions, frames_each, 32, 32, 42)
                .simulate_compute(stall_us)
                .count()
                .unwrap()
        },
    );
    let (_, t1s, _) = rows[0];
    let (_, t8s, s8) = rows[rows.len() - 1];
    println!(
        "headline: 1 worker {} → 8 workers {} ({s8:.2}x; paper: 3 h → 25 min ≈ 7.2x)",
        fmt_duration(std::time::Duration::from_secs_f64(t1s)),
        fmt_duration(std::time::Duration::from_secs_f64(t8s)),
    );

    // extrapolation table like §4.2's closing paragraph
    let per_frame = stall_us as f64 / 1e6;
    for (name, frames) in [("KITTI-scale (100k frames)", 1e5), ("Google-scale (40M frames)", 4e7)]
    {
        let single = frames * per_frame / 3600.0;
        println!(
            "{name:<26} single machine {single:>9.1} h → 10,000 workers {:>7.4} h",
            single / 1e4
        );
    }

    // ---- Part 2: real PJRT classification (1-core honesty) ----
    let frames_each: u32 = std::env::var("AV_SIMD_BENCH_FRAMES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let total = partitions as u32 * frames_each;
    println!(
        "\n(real DNN path below: this testbed has 1 CPU core, so CPU-bound \
         classification cannot scale — reported for per-frame truth, see DESIGN.md)"
    );
    sweep(
        &format!("real PJRT classification, {total} frames"),
        total,
        |sc| {
            // warmup compiles executables on each worker thread
            sc.synth_frames(partitions, 1, 32, 32, 99)
                .op("classify_images", vec![])
                .count()
                .unwrap();
            sc.synth_frames(partitions, frames_each, 32, 32, 42)
                .op("classify_images", vec![])
                .count()
                .unwrap()
        },
    );
}
