//! §3.1 design-choice ablation — BinPipedRDD overhead.
//!
//! The paper chose Linux pipes over JNI for maintainability and asserts
//! the pipe is efficient enough. This bench quantifies the price of that
//! choice: the same partition of binary image records processed
//! (a) in-process (the JNI-design stand-in), (b) through a child process
//! via the Fig 4 pipe codec, and (c) codec-only (serialize + deserialize
//! with no process), for identity and rotate90 user logics.

use av_simd::engine::{OpCall, OpRegistry, TaskCtx};
use av_simd::msg::{Image, Message};
use av_simd::pipe::{deserialize_stream, serialize_stream, PipeItem};
use av_simd::util::bench::{print_table, speedup, Bench};

fn main() {
    // The binpipe op spawns current_exe(), which for a bench binary has
    // no user-logic mode. Use /bin/cat as the child for identity (the
    // stream is its own interchange format) — this measures true
    // process+pipe overhead; rotate90 runs via the launcher binary when
    // present.
    let n_imgs = 256usize;
    let side = 64u32;
    let records: Vec<Vec<u8>> =
        (0..n_imgs).map(|i| Image::synthetic(side, side, i as u64).encode()).collect();
    let total_bytes: f64 = records.iter().map(|r| r.len() as f64).sum();
    println!(
        "== §3.1 BinPipedRDD ablation: {n_imgs} images of {side}x{side} ({:.1} MiB/partition) ==",
        total_bytes / (1024.0 * 1024.0)
    );

    let reg = OpRegistry::with_builtins();
    let ctx = TaskCtx::new(0, "artifacts");

    // (c) codec-only: measures the encode/serialize stage itself.
    let codec_only = Bench::new("codec only (serialize+deserialize)")
        .warmup(1)
        .samples(10)
        .units(total_bytes, "B")
        .run(|| {
            let items: Vec<PipeItem> =
                records.iter().map(|r| PipeItem::Bytes(r.clone())).collect();
            let stream = serialize_stream(&items);
            let back = deserialize_stream(&stream).unwrap();
            assert_eq!(back.len(), n_imgs);
        });

    // (a) in-process identity (JNI stand-in).
    let inproc = Bench::new("identity in-process (JNI stand-in)")
        .warmup(1)
        .samples(10)
        .units(total_bytes, "B")
        .run(|| {
            let out = reg
                .apply_chain(
                    &ctx,
                    &[OpCall::new("binpipe_inproc", b"identity".to_vec())],
                    records.clone(),
                )
                .unwrap();
            assert_eq!(out.len(), n_imgs);
        });

    // (b) child process via pipes (/bin/cat = perfect identity child).
    let spec = av_simd::pipe::ChildSpec {
        program: "/bin/cat".into(),
        args: vec![],
        env: vec![],
    };
    let piped = Bench::new("identity via child pipe (paper's design)")
        .warmup(1)
        .samples(10)
        .units(total_bytes, "B")
        .run(|| {
            let items: Vec<PipeItem> =
                records.iter().map(|r| PipeItem::Bytes(r.clone())).collect();
            let out = av_simd::pipe::pipe_through_child(&spec, items).unwrap();
            assert_eq!(out.len(), n_imgs);
        });

    // real user logic through both paths
    let rot_inproc = Bench::new("rotate90 in-process")
        .warmup(1)
        .samples(5)
        .units(total_bytes, "B")
        .run(|| {
            let out = reg
                .apply_chain(
                    &ctx,
                    &[OpCall::new("binpipe_inproc", b"rotate90".to_vec())],
                    records.clone(),
                )
                .unwrap();
            assert_eq!(out.len(), n_imgs);
        });
    let launcher = std::path::Path::new("target/release/av-simd");
    let rot_piped = launcher.exists().then(|| {
        let spec = av_simd::pipe::ChildSpec {
            program: launcher.to_string_lossy().into_owned(),
            args: vec!["user-logic".into(), "rotate90".into()],
            env: vec![],
        };
        Bench::new("rotate90 via child pipe")
            .warmup(1)
            .samples(5)
            .units(total_bytes, "B")
            .run(|| {
                let items: Vec<PipeItem> =
                    records.iter().map(|r| PipeItem::Bytes(r.clone())).collect();
                let out = av_simd::pipe::pipe_through_child(&spec, items).unwrap();
                assert_eq!(out.len(), n_imgs);
            })
    });

    let mut rows = vec![codec_only, inproc.clone(), piped.clone(), rot_inproc.clone()];
    if let Some(rp) = rot_piped.clone() {
        rows.push(rp);
    }
    print_table("BinPipedRDD paths", &rows);
    // ratio >1 = pipe is slower than in-process by that factor
    println!(
        "pipe cost vs in-process (identity): {:.1}x slower (process spawn + 2x stream copy)",
        speedup(&piped, &inproc)
    );
    if let Some(rp) = rot_piped {
        println!(
            "pipe cost vs in-process (rotate90): {:.1}x slower — dominated by child startup \
             (~100 ms PJRT-linked binary init); real partitions are 100-1000x larger, \
             amortizing this to <5%",
            speedup(&rp, &rot_inproc)
        );
    } else {
        println!("(build target/release/av-simd for the rotate90 child-pipe row)");
    }
}
