//! §2.3 reproduction — the compute demand of playback simulation.
//!
//! Paper: "processing each image takes about 0.3 seconds … it takes more
//! than 100 hours to analyze the KITTI dataset alone, and … more than
//! 600,000 hours … for Google's autonomous driving project" on one
//! machine. This bench measures our per-message perception latencies
//! (classification b1/b8, segmentation, LiDAR descriptor), the full
//! bag→pipeline path, and prints the same extrapolation table.

use av_simd::bag::BagReader;
use av_simd::datagen::{generate_drive, DriveSpec};
use av_simd::msg::{Image, Message};
use av_simd::perception::{Classifier, Segmenter};
use av_simd::util::bench::{print_table, Bench};

fn main() {
    let artifact_dir =
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let classifier = Classifier::load(&artifact_dir).expect("run `make artifacts`");
    let segmenter = Segmenter::load(&artifact_dir).unwrap();
    let imgs: Vec<Image> = (0..8).map(|i| Image::synthetic(32, 32, i)).collect();
    let one = [imgs[0].clone()];

    let cls_b1 = Bench::new("classify batch=1")
        .warmup(2)
        .samples(20)
        .units(1.0, "img")
        .run(|| {
            classifier.classify(&one).unwrap();
        });
    let cls_b8 = Bench::new("classify batch=8")
        .warmup(2)
        .samples(20)
        .units(8.0, "img")
        .run(|| {
            classifier.classify(&imgs).unwrap();
        });
    let seg = Bench::new("segment batch=1")
        .warmup(2)
        .samples(20)
        .units(1.0, "img")
        .run(|| {
            segmenter.segment(&one[0]).unwrap();
        });
    let pc = av_simd::msg::PointCloud::synthetic(256, 3);
    let lidar = Bench::new("lidar descriptor")
        .warmup(2)
        .samples(20)
        .units(1.0, "scan")
        .run(|| {
            av_simd::perception::scan_descriptor(&artifact_dir, &pc).unwrap();
        });

    // full path: bag playback → decode → classify
    let (bag, _) = generate_drive(&DriveSpec { frames: 32, ..DriveSpec::default() }).unwrap();
    let bag_bytes = bag.to_vec();
    let pipeline = Bench::new("bag play → decode → classify (32 frames)")
        .warmup(1)
        .samples(5)
        .units(32.0, "img")
        .run(|| {
            let mut r = BagReader::open(av_simd::bag::MemoryChunkedFile::from_bytes(
                &bag_bytes,
            ))
            .unwrap();
            let mut frames = Vec::new();
            r.for_each(Some(&["/camera"]), |m| {
                frames.push(Image::decode(&m.data)?);
                Ok(())
            })
            .unwrap();
            classifier.classify(&frames).unwrap();
        });

    print_table("§2.3 per-message perception latency", &[cls_b1.clone(), cls_b8.clone(), seg, lidar, pipeline.clone()]);

    // extrapolation table, paper style
    let per_img = cls_b8.median().as_secs_f64() / 8.0;
    println!("\n== §2.3 extrapolation (single machine, batch-8 path) ==");
    println!("per-image latency: {:.1} ms   [paper: ~300 ms on 2017 hardware]", per_img * 1e3);
    for (name, images) in [
        ("KITTI 6h (100M images in paper's text)", 1.0e8),
        ("Google 40,000h (~2e9 frames proxy)", 2.0e9),
    ] {
        let hours = images * per_img / 3600.0;
        println!(
            "{name:<42} {hours:>12.0} h single-machine → {:>8.1} h on 10,000 workers",
            hours / 1e4
        );
    }
}
