//! Fig 6 reproduction — ROSBag cache performance.
//!
//! Paper §4.1: "the Small File Test, which repeatedly read and write
//! 1 million files with 1 KB in size, and the Large File Test, which
//! repeatedly read and write 100 thousand files with 1 MB in size …
//! with in-memory cache, the write performance gets improved by about 3X
//! and the read performance gets improved by 5X in the large file test,
//! by about 10X in the small file test."
//!
//! We run the same two shapes (message counts scaled to this testbed;
//! the ratio disk-vs-memory is the claim, not the absolute volume),
//! through the identical BagWriter/BagReader code — only the ChunkStore
//! differs. Disk writes fsync on flush so the page cache cannot fake
//! memory-speed writes.

use av_simd::bag::{
    BagReader, BagWriter, Compression, DiskChunkedFile, MemoryChunkedFile,
};
use av_simd::msg::Time;
use av_simd::util::bench::{print_table, speedup, Bench};
use av_simd::util::prng::Prng;

struct Shape {
    name: &'static str,
    n_msgs: usize,
    msg_size: usize,
    /// Bag chunk size: small for the small-file shape (per-chunk seek +
    /// read syscalls dominate, like the paper's million separate 1 KB
    /// files), large for the large-file shape.
    chunk_size: usize,
    paper_read_x: f64,
    paper_write_x: f64,
}

/// Drop the OS page cache so disk reads are honest cold reads (requires
/// root, silently skipped otherwise).
fn drop_page_cache() {
    let _ = std::process::Command::new("sync").status();
    let _ = std::fs::write("/proc/sys/vm/drop_caches", "3");
}

fn main() {
    let shapes = [
        Shape {
            name: "small-file (1 KB msgs)",
            n_msgs: scaled(100_000),
            msg_size: 1024,
            chunk_size: 8 * 1024,
            paper_read_x: 10.0,
            paper_write_x: 3.0,
        },
        Shape {
            name: "large-file (1 MB msgs)",
            n_msgs: scaled(100),
            msg_size: 1024 * 1024,
            chunk_size: 4 << 20,
            paper_read_x: 5.0,
            paper_write_x: 3.0,
        },
    ];
    let dir = std::env::temp_dir().join("av_simd_bench_cache");
    std::fs::create_dir_all(&dir).unwrap();

    println!("== Fig 6: ROSBag cache (disk ChunkedFile vs MemoryChunkedFile) ==");
    for shape in &shapes {
        let mut rng = Prng::new(7);
        let mut payload = vec![0u8; shape.msg_size];
        rng.fill_bytes(&mut payload);
        let total_bytes = (shape.n_msgs * shape.msg_size) as f64;
        let disk_path = dir.join(format!("bench_{}.bag", shape.msg_size));

        // ---- record (write) ----
        let disk_write = Bench::new(format!("{} record disk", shape.name))
            .warmup(1)
            .samples(3)
            .units(total_bytes, "B")
            .run(|| {
                let mut store = DiskChunkedFile::create(&disk_path).unwrap();
                store.set_sync_on_flush(true);
                let mut w = BagWriter::new(store, Compression::None, shape.chunk_size).unwrap();
                for i in 0..shape.n_msgs {
                    w.write_raw("/t", "raw", Time::from_nanos(i as u64), payload.clone())
                        .unwrap();
                }
                w.finish().unwrap();
            });
        let mem_write = Bench::new(format!("{} record memory", shape.name))
            .warmup(1)
            .samples(3)
            .units(total_bytes, "B")
            .run(|| {
                let mut w = BagWriter::new(
                    MemoryChunkedFile::new(),
                    Compression::None,
                    shape.chunk_size,
                )
                .unwrap();
                for i in 0..shape.n_msgs {
                    w.write_raw("/t", "raw", Time::from_nanos(i as u64), payload.clone())
                        .unwrap();
                }
                w.finish().unwrap();
            });

        // ---- play (read) ----
        // Build the in-memory bag once: the §3.2 cache scenario is "the
        // bag is already resident"; play borrows it without copying.
        let mut mem_bag = {
            let mut w = BagWriter::new(
                MemoryChunkedFile::new(),
                Compression::None,
                shape.chunk_size,
            )
            .unwrap();
            for i in 0..shape.n_msgs {
                w.write_raw("/t", "raw", Time::from_nanos(i as u64), payload.clone())
                    .unwrap();
            }
            w.finish().unwrap()
        };
        let disk_read = Bench::new(format!("{} play disk (cold cache)", shape.name))
            .warmup(1)
            .samples(3)
            .units(total_bytes, "B")
            .run(|| {
                drop_page_cache();
                let mut r = BagReader::open(DiskChunkedFile::open(&disk_path).unwrap()).unwrap();
                let n = r.for_each(None, |_| Ok(())).unwrap();
                assert_eq!(n as usize, shape.n_msgs);
            });
        let mem_read = Bench::new(format!("{} play memory", shape.name))
            .warmup(1)
            .samples(3)
            .units(total_bytes, "B")
            .run(|| {
                let mut r = BagReader::open(&mut mem_bag).unwrap();
                let n = r.for_each(None, |_| Ok(())).unwrap();
                assert_eq!(n as usize, shape.n_msgs);
            });

        print_table(
            &format!("{} — {} msgs", shape.name, shape.n_msgs),
            &[disk_write.clone(), mem_write.clone(), disk_read.clone(), mem_read.clone()],
        );
        println!(
            "  write speedup (memory vs disk): {:.1}x   [paper: ~{:.0}x]",
            speedup(&disk_write, &mem_write),
            shape.paper_write_x
        );
        println!(
            "  read  speedup (memory vs disk): {:.1}x   [paper: ~{:.0}x]",
            speedup(&disk_read, &mem_read),
            shape.paper_read_x
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Allow CI-style scaling via AV_SIMD_BENCH_SCALE (percent).
fn scaled(n: usize) -> usize {
    let pct: usize = std::env::var("AV_SIMD_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    (n * pct / 100).max(1)
}
