//! Configuration system.
//!
//! A real deployment drives the platform from a config file (cluster size,
//! ports, bag cache policy, artifact paths, simulation parameters). We
//! parse a TOML subset (tables, string/int/float/bool scalars, string
//! arrays, `#` comments) into a typed [`PlatformConfig`]; every field has a
//! production default and can be overridden by `AV_SIMD_*` environment
//! variables (env wins over file, file wins over default).

pub mod json;
mod toml;

pub use json::{flatten_json, parse_json, JsonValue};
pub use toml::{parse_toml, TomlValue};

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// How workers execute tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// Thread-pool executors inside the driver process.
    Local,
    /// Spawned worker processes connected over TCP.
    Standalone,
}

impl std::str::FromStr for ClusterMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "local" => Ok(ClusterMode::Local),
            "standalone" => Ok(ClusterMode::Standalone),
            other => Err(Error::Config(format!("unknown cluster mode '{other}'"))),
        }
    }
}

/// Engine / cluster section.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Cluster execution mode.
    pub mode: ClusterMode,
    /// Number of workers (threads in local mode, processes in standalone).
    pub workers: usize,
    /// Task slots per worker.
    pub slots_per_worker: usize,
    /// Base TCP port for standalone workers.
    pub base_port: u16,
    /// Max task retries before the job fails.
    pub task_retries: usize,
    /// Default partitions for parallelize / bag-dir reads.
    pub default_parallelism: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            mode: ClusterMode::Local,
            workers: 4,
            slots_per_worker: 1,
            base_port: 7077,
            task_retries: 2,
            default_parallelism: 8,
        }
    }
}

/// Bag / cache section (the paper's §3.2 knobs).
#[derive(Debug, Clone)]
pub struct BagConfig {
    /// Chunk size threshold before a chunk is sealed (bytes).
    pub chunk_size: usize,
    /// Use the in-memory MemoryChunkedFile cache for play/record.
    pub memory_cache: bool,
    /// Max bytes the in-memory bag cache may hold before eviction.
    pub cache_capacity: u64,
    /// Compression: "none" | "deflate".
    pub compression: String,
}

impl Default for BagConfig {
    fn default() -> Self {
        Self {
            chunk_size: 4 * 1024 * 1024,
            memory_cache: true,
            cache_capacity: 1024 * 1024 * 1024,
            compression: "none".into(),
        }
    }
}

/// Perception / runtime section.
#[derive(Debug, Clone)]
pub struct PerceptionConfig {
    /// Directory containing AOT artifacts (*.hlo.txt).
    pub artifact_dir: String,
    /// Batch size the classifier artifact was lowered with.
    pub batch: usize,
    /// Image side (images are square, RGB).
    pub image_size: usize,
    /// Number of classes in the classifier head.
    pub classes: usize,
}

impl Default for PerceptionConfig {
    fn default() -> Self {
        Self {
            artifact_dir: "artifacts".into(),
            batch: 8,
            image_size: 32,
            classes: 8,
        }
    }
}

/// Simulation section (Fig 1 scenario matrix + dynamics).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Simulation timestep (seconds).
    pub dt: f64,
    /// Episode horizon (seconds).
    pub horizon: f64,
    /// Ego cruise speed (m/s).
    pub ego_speed: f64,
    /// Random seed for scenario sampling and sensor noise.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { dt: 0.05, horizon: 12.0, ego_speed: 12.0, seed: 42 }
    }
}

/// Top-level typed configuration.
#[derive(Debug, Clone, Default)]
pub struct PlatformConfig {
    /// Engine / cluster section.
    pub cluster: ClusterConfig,
    /// Bag / cache section.
    pub bag: BagConfig,
    /// Perception / runtime section.
    pub perception: PerceptionConfig,
    /// Simulation section.
    pub sim: SimConfig,
}

impl PlatformConfig {
    /// Defaults → file (if given) → environment overrides.
    pub fn load(path: Option<&Path>) -> Result<Self> {
        let mut cfg = PlatformConfig::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| Error::Config(format!("read {}: {e}", p.display())))?;
            cfg.apply_toml(&parse_toml(&text)?)?;
        }
        cfg.apply_env();
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from TOML text (used by tests and the CLI `--config`).
    pub fn from_toml(text: &str) -> Result<Self> {
        let mut cfg = PlatformConfig::default();
        cfg.apply_toml(&parse_toml(text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_toml(&mut self, doc: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (key, val) in doc {
            let (section, field) = key
                .split_once('.')
                .ok_or_else(|| Error::Config(format!("top-level scalar '{key}' not allowed")))?;
            match section {
                "cluster" => match field {
                    "mode" => self.cluster.mode = val.as_str()?.parse()?,
                    "workers" => self.cluster.workers = val.as_usize()?,
                    "slots_per_worker" => self.cluster.slots_per_worker = val.as_usize()?,
                    "base_port" => self.cluster.base_port = val.as_usize()? as u16,
                    "task_retries" => self.cluster.task_retries = val.as_usize()?,
                    "default_parallelism" => {
                        self.cluster.default_parallelism = val.as_usize()?
                    }
                    _ => return Err(Error::Config(format!("unknown key '{key}'"))),
                },
                "bag" => match field {
                    "chunk_size" => self.bag.chunk_size = val.as_usize()?,
                    "memory_cache" => self.bag.memory_cache = val.as_bool()?,
                    "cache_capacity" => self.bag.cache_capacity = val.as_usize()? as u64,
                    "compression" => self.bag.compression = val.as_str()?.to_string(),
                    _ => return Err(Error::Config(format!("unknown key '{key}'"))),
                },
                "perception" => match field {
                    "artifact_dir" => self.perception.artifact_dir = val.as_str()?.into(),
                    "batch" => self.perception.batch = val.as_usize()?,
                    "image_size" => self.perception.image_size = val.as_usize()?,
                    "classes" => self.perception.classes = val.as_usize()?,
                    _ => return Err(Error::Config(format!("unknown key '{key}'"))),
                },
                "sim" => match field {
                    "dt" => self.sim.dt = val.as_f64()?,
                    "horizon" => self.sim.horizon = val.as_f64()?,
                    "ego_speed" => self.sim.ego_speed = val.as_f64()?,
                    "seed" => self.sim.seed = val.as_usize()? as u64,
                    _ => return Err(Error::Config(format!("unknown key '{key}'"))),
                },
                _ => return Err(Error::Config(format!("unknown section '{section}'"))),
            }
        }
        Ok(())
    }

    fn apply_env(&mut self) {
        if let Ok(v) = std::env::var("AV_SIMD_WORKERS") {
            if let Ok(n) = v.parse() {
                self.cluster.workers = n;
            }
        }
        if let Ok(v) = std::env::var("AV_SIMD_MODE") {
            if let Ok(m) = v.parse() {
                self.cluster.mode = m;
            }
        }
        if let Ok(v) = std::env::var("AV_SIMD_ARTIFACTS") {
            self.perception.artifact_dir = v;
        }
        if let Ok(v) = std::env::var("AV_SIMD_MEMORY_CACHE") {
            self.bag.memory_cache = v != "0" && v != "false";
        }
    }

    fn validate(&self) -> Result<()> {
        if self.cluster.workers == 0 {
            return Err(Error::Config("cluster.workers must be >= 1".into()));
        }
        if self.bag.chunk_size < 1024 {
            return Err(Error::Config("bag.chunk_size must be >= 1024".into()));
        }
        if !matches!(self.bag.compression.as_str(), "none" | "deflate") {
            return Err(Error::Config(format!(
                "bag.compression must be none|deflate, got '{}'",
                self.bag.compression
            )));
        }
        if self.sim.dt <= 0.0 || self.sim.horizon <= 0.0 {
            return Err(Error::Config("sim.dt and sim.horizon must be > 0".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        PlatformConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_file() {
        let cfg = PlatformConfig::from_toml(
            r#"
            # production cluster
            [cluster]
            mode = "standalone"
            workers = 8
            base_port = 9000

            [bag]
            chunk_size = 1048576
            memory_cache = false
            compression = "deflate"

            [perception]
            batch = 4
            image_size = 64

            [sim]
            dt = 0.02
            ego_speed = 15.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.mode, ClusterMode::Standalone);
        assert_eq!(cfg.cluster.workers, 8);
        assert_eq!(cfg.cluster.base_port, 9000);
        assert_eq!(cfg.bag.chunk_size, 1048576);
        assert!(!cfg.bag.memory_cache);
        assert_eq!(cfg.bag.compression, "deflate");
        assert_eq!(cfg.perception.batch, 4);
        assert!((cfg.sim.ego_speed - 15.5).abs() < 1e-9);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(PlatformConfig::from_toml("[cluster]\nbogus = 1\n").is_err());
    }

    #[test]
    fn unknown_section_rejected() {
        assert!(PlatformConfig::from_toml("[nope]\nx = 1\n").is_err());
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(PlatformConfig::from_toml("[cluster]\nworkers = 0\n").is_err());
    }

    #[test]
    fn bad_compression_rejected() {
        assert!(PlatformConfig::from_toml("[bag]\ncompression = \"lzma\"\n").is_err());
    }
}
