//! Minimal TOML-subset parser.
//!
//! Supports exactly what the platform's config files use: `[table]`
//! headers (one level), `key = value` with string / integer / float /
//! boolean / string-array values, `#` comments, and blank lines. Keys are
//! flattened to `"table.key"` in the output map. Anything outside the
//! subset is a parse error — config typos should fail loudly.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// An array of strings.
    StrArray(Vec<String>),
}

impl TomlValue {
    /// The string value, or a config error naming the actual type.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => Err(Error::Config(format!("expected non-negative int, got {other:?}"))),
        }
    }

    /// The value as a float (integers widen).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(Error::Config(format!("expected float, got {other:?}"))),
        }
    }

    /// The boolean value.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    /// The string-array value.
    pub fn as_str_array(&self) -> Result<&[String]> {
        match self {
            TomlValue::StrArray(v) => Ok(v),
            other => Err(Error::Config(format!("expected string array, got {other:?}"))),
        }
    }
}

/// Parse TOML-subset text into a flat `"section.key" → value` map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: unterminated table header", lineno + 1)))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(Error::Config(format!("line {}: bad table name '{name}'", lineno + 1)));
            }
            section = name.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
        let key = key.trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(Error::Config(format!("line {}: bad key '{key}'", lineno + 1)));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if out.contains_key(&full_key) {
            return Err(Error::Config(format!("line {}: duplicate key '{full_key}'", lineno + 1)));
        }
        out.insert(full_key, parse_value(val.trim(), lineno + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(Error::Config(format!("line {lineno}: empty value")));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| Error::Config(format!("line {lineno}: unterminated string")))?;
        if inner.contains('"') {
            return Err(Error::Config(format!("line {lineno}: embedded quote")));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| Error::Config(format!("line {lineno}: unterminated array")))?;
        let mut items = Vec::new();
        for item in inner.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            match parse_value(item, lineno)? {
                TomlValue::Str(v) => items.push(v),
                other => {
                    return Err(Error::Config(format!(
                        "line {lineno}: only string arrays supported, got {other:?}"
                    )))
                }
            }
        }
        return Ok(TomlValue::StrArray(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    Err(Error::Config(format!("line {lineno}: cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse_toml(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = false\nf = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc["a"], TomlValue::Int(1));
        assert_eq!(doc["b"], TomlValue::Float(2.5));
        assert_eq!(doc["c"], TomlValue::Str("hi".into()));
        assert_eq!(doc["d"], TomlValue::Bool(true));
        assert_eq!(doc["e"], TomlValue::Bool(false));
        assert_eq!(doc["f"], TomlValue::Int(1000));
    }

    #[test]
    fn sections_flatten() {
        let doc = parse_toml("[cluster]\nworkers = 8\n[bag]\nchunk_size = 4096\n").unwrap();
        assert_eq!(doc["cluster.workers"], TomlValue::Int(8));
        assert_eq!(doc["bag.chunk_size"], TomlValue::Int(4096));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = parse_toml("# header\n\na = 1 # trailing\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(doc["a"], TomlValue::Int(1));
        assert_eq!(doc["b"], TomlValue::Str("x # not a comment".into()));
    }

    #[test]
    fn string_arrays() {
        let doc = parse_toml("topics = [\"/camera\", \"/lidar\"]\n").unwrap();
        assert_eq!(
            doc["topics"].as_str_array().unwrap(),
            &["/camera".to_string(), "/lidar".to_string()]
        );
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse_toml("[unterminated\n").is_err());
        assert!(parse_toml("novalue =\n").is_err());
        assert!(parse_toml("x = \"unterminated\n").is_err());
        assert!(parse_toml("x = 1\nx = 2\n").is_err());
        assert!(parse_toml("weird key = 1\n").is_err());
        assert!(parse_toml("x = [1, 2]\n").is_err());
    }

    #[test]
    fn duplicate_across_sections_ok() {
        let doc = parse_toml("[a]\nx = 1\n[b]\nx = 2\n").unwrap();
        assert_eq!(doc["a.x"], TomlValue::Int(1));
        assert_eq!(doc["b.x"], TomlValue::Int(2));
    }
}
