//! Minimal JSON parser for deployment manifests.
//!
//! Cluster specs travel through provisioning systems that speak JSON
//! more readily than TOML, so [`crate::engine::deploy::ClusterSpec`]
//! accepts both. This is a strict recursive-descent parser for the full
//! JSON value grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); [`flatten_json`] then maps a two-level object of
//! scalars / string arrays onto the same flat `"section.key"` map the
//! TOML-subset parser produces, so both formats share one typed loader.

use super::toml::TomlValue;
use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string literal (escapes resolved).
    Str(String),
    /// An array of values.
    Array(Vec<JsonValue>),
    /// An object; insertion order is not preserved (keys sort).
    Object(BTreeMap<String, JsonValue>),
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json at byte {}: {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, text: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-utf8 number"))?;
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs are out of scope for manifests
                            let ch = char::from_u32(code)
                                .ok_or_else(|| self.err("bad \\u code point"))?;
                            out.push(ch);
                            self.pos += 4;
                        }
                        other => {
                            return Err(
                                self.err(&format!("unknown escape '\\{}'", other as char))
                            )
                        }
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte sequences pass
                    // through verbatim)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("non-utf8 string"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            if map.insert(key.clone(), val).is_some() {
                return Err(self.err(&format!("duplicate key '{key}'")));
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse one JSON value from `text`; trailing non-whitespace is an error.
pub fn parse_json(text: &str) -> Result<JsonValue> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after value"));
    }
    Ok(v)
}

/// Flatten a two-level JSON object (`{"section": {"key": value}}`) into
/// the same `"section.key" →` [`TomlValue`] map [`super::parse_toml`]
/// produces. Supported leaf values: strings, numbers (integral numbers
/// become [`TomlValue::Int`]), booleans, and arrays of strings — the
/// exact subset the TOML side accepts, so a manifest can be written in
/// either format and load through one code path.
pub fn flatten_json(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let JsonValue::Object(sections) = parse_json(text)? else {
        return Err(Error::Config("json manifest must be an object".into()));
    };
    let mut out = BTreeMap::new();
    for (section, val) in sections {
        let JsonValue::Object(fields) = val else {
            return Err(Error::Config(format!(
                "json manifest: top-level '{section}' must be an object"
            )));
        };
        for (key, leaf) in fields {
            let full = format!("{section}.{key}");
            let tv = match leaf {
                JsonValue::Str(s) => TomlValue::Str(s),
                JsonValue::Bool(b) => TomlValue::Bool(b),
                JsonValue::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => {
                    TomlValue::Int(n as i64)
                }
                JsonValue::Num(n) => TomlValue::Float(n),
                JsonValue::Array(items) => {
                    let mut strs = Vec::with_capacity(items.len());
                    for it in items {
                        match it {
                            JsonValue::Str(s) => strs.push(s),
                            other => {
                                return Err(Error::Config(format!(
                                    "json manifest: '{full}' array must hold strings, \
                                     got {other:?}"
                                )))
                            }
                        }
                    }
                    TomlValue::StrArray(strs)
                }
                other => {
                    return Err(Error::Config(format!(
                        "json manifest: unsupported value for '{full}': {other:?}"
                    )))
                }
            };
            out.insert(full, tv);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_parse() {
        assert_eq!(parse_json("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse_json("null").unwrap(), JsonValue::Null);
        assert_eq!(parse_json("-2.5e2").unwrap(), JsonValue::Num(-250.0));
        assert_eq!(
            parse_json(r#""a\n\"b\" A""#).unwrap(),
            JsonValue::Str("a\n\"b\" A".into())
        );
    }

    #[test]
    fn nested_structures_parse() {
        let v = parse_json(r#"{"a": [1, "x", {"b": false}], "c": {}}"#).unwrap();
        let JsonValue::Object(o) = v else { panic!() };
        let JsonValue::Array(a) = &o["a"] else { panic!() };
        assert_eq!(a.len(), 3);
        assert_eq!(o["c"], JsonValue::Object(BTreeMap::new()));
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2",
            "{\"a\":1,\"a\":2}", "{\"a\": nope}",
        ] {
            assert!(parse_json(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn flatten_matches_toml_shape() {
        let flat = flatten_json(
            r#"{
                "cluster": {"name": "lab", "connect_timeout_ms": 500},
                "workers": {"hosts": ["10.0.0.1:7077", "10.0.0.2:7077"], "capacity": 2},
                "launch": {"program": "target/release/av-simd"}
            }"#,
        )
        .unwrap();
        assert_eq!(flat["cluster.name"], TomlValue::Str("lab".into()));
        assert_eq!(flat["cluster.connect_timeout_ms"], TomlValue::Int(500));
        assert_eq!(flat["workers.capacity"], TomlValue::Int(2));
        assert_eq!(
            flat["workers.hosts"].as_str_array().unwrap().len(),
            2
        );
        assert_eq!(flat["launch.program"], TomlValue::Str("target/release/av-simd".into()));
    }

    #[test]
    fn flatten_rejects_wrong_shapes() {
        assert!(flatten_json("[1]").is_err());
        assert!(flatten_json(r#"{"a": 1}"#).is_err(), "top level must be objects");
        assert!(flatten_json(r#"{"a": {"b": [1]}}"#).is_err(), "non-string array");
        assert!(flatten_json(r#"{"a": {"b": null}}"#).is_err());
    }
}
