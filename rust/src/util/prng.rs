//! Deterministic PRNG (xoshiro256**) used everywhere randomness is needed:
//! synthetic data generation, scenario sampling, property tests, scheduler
//! jitter. Fully reproducible from a seed — no OS entropy on any path.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (upper half of [`Prng::next_u64`]).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough for our uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform in [lo, hi) for i64.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Bernoulli draw: true with probability `p`.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a buffer with random bytes (for synthetic payloads).
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Derive an independent child stream (for per-partition determinism).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_fills_odd_lengths() {
        let mut r = Prng::new(5);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Prng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
