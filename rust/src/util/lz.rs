//! Pure-std LZ77 byte codec used for bag chunk compression.
//!
//! The offline crate set has no `flate2`, so the bag's compressed mode is
//! backed by this deflate-class LZ: greedy hash-table matching over a
//! 64 KiB window, byte-aligned tokens. The format is internal to the bag
//! file format (we only ever read our own bags), so interoperability with
//! real DEFLATE is not a goal — determinism, safety on corrupt input, and
//! a strong ratio on redundant sensor payloads are.
//!
//! Token stream:
//! * `0x00..=0x7F` — literal run: token value + 1 literal bytes follow.
//! * `0x80..=0xFF` — match: length = (token − 0x80) + 4 (4..=131),
//!   followed by a u16-LE distance (1..=65535) back into the output.

use crate::error::{Error, Result};

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 131;
const MAX_DIST: usize = 65535;
const HASH_BITS: u32 = 15;

#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    for chunk in lits.chunks(128) {
        out.push((chunk.len() - 1) as u8);
        out.extend_from_slice(chunk);
    }
}

/// Compress `input`. Worst case output is input + ~1/128 overhead.
pub fn compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut pos = 0usize;

    while pos + MIN_MATCH <= input.len() {
        let h = hash4(&input[pos..]);
        let cand = table[h];
        table[h] = pos;
        let usable = cand != usize::MAX
            && pos - cand <= MAX_DIST
            && input[cand..cand + MIN_MATCH] == input[pos..pos + MIN_MATCH];
        if usable {
            let max = (input.len() - pos).min(MAX_MATCH);
            let mut len = MIN_MATCH;
            while len < max && input[cand + len] == input[pos + len] {
                len += 1;
            }
            flush_literals(&mut out, &input[lit_start..pos]);
            out.push(0x80 + (len - MIN_MATCH) as u8);
            out.extend_from_slice(&((pos - cand) as u16).to_le_bytes());
            // Seed a few positions inside the match so later data can
            // still reference it (sparse to keep compression O(n)).
            let step = (len / 8).max(1);
            let mut p = pos + step;
            while p < pos + len && p + MIN_MATCH <= input.len() {
                table[hash4(&input[p..])] = p;
                p += step;
            }
            pos += len;
            lit_start = pos;
        } else {
            pos += 1;
        }
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

/// Decompress into at most `expected_len` bytes. Any malformed token
/// (truncated run, zero/too-far distance, oversized output) is an
/// `Error::Corrupt` — never a panic, never unbounded allocation.
pub fn decompress(input: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected_len.min(1 << 26));
    let mut i = 0usize;
    while i < input.len() {
        let t = input[i];
        i += 1;
        if t < 0x80 {
            let n = t as usize + 1;
            if i + n > input.len() {
                return Err(Error::Corrupt("lz literal run truncated".into()));
            }
            out.extend_from_slice(&input[i..i + n]);
            i += n;
        } else {
            let len = (t - 0x80) as usize + MIN_MATCH;
            if i + 2 > input.len() {
                return Err(Error::Corrupt("lz match header truncated".into()));
            }
            let dist = u16::from_le_bytes([input[i], input[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(Error::Corrupt(format!(
                    "lz match distance {dist} invalid at output offset {}",
                    out.len()
                )));
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
        if out.len() > expected_len {
            return Err(Error::Corrupt(format!(
                "lz output exceeds declared length {expected_len}"
            )));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn roundtrip(data: &[u8]) {
        let packed = compress(data);
        let back = decompress(&packed, data.len()).unwrap();
        assert_eq!(back, data, "roundtrip failed for {} bytes", data.len());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[9; 4]);
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Prng::new(11);
        for n in [17usize, 100, 1000, 70_000] {
            let mut buf = vec![0u8; n];
            rng.fill_bytes(&mut buf);
            roundtrip(&buf);
        }
    }

    #[test]
    fn redundant_data_compresses_hard() {
        let data = vec![42u8; 80_000];
        let packed = compress(&data);
        assert!(packed.len() < data.len() / 16, "{} bytes", packed.len());
        roundtrip(&data);
    }

    #[test]
    fn repeated_structure_compresses() {
        let mut data = Vec::new();
        for i in 0..2_000u32 {
            data.extend_from_slice(b"topic:/camera type:Image payload=");
            data.extend_from_slice(&(i % 7).to_le_bytes());
        }
        let packed = compress(&data);
        assert!(packed.len() < data.len() / 4, "{} vs {}", packed.len(), data.len());
        roundtrip(&data);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let packed = compress(&data);
        let mut rng = Prng::new(3);
        for _ in 0..200 {
            let mut bad = packed.clone();
            let pos = rng.below(bad.len() as u64) as usize;
            bad[pos] ^= 1 << rng.below(8);
            // corrupt input may still decode to wrong bytes, but must not
            // panic and must respect the declared-length cap
            if let Ok(out) = decompress(&bad, data.len()) {
                assert!(out.len() <= data.len());
            }
        }
        // truncation at every point must be safe too
        for cut in 0..packed.len().min(64) {
            let _ = decompress(&packed[..cut], data.len());
        }
    }

    #[test]
    fn declared_length_is_enforced() {
        let data = vec![1u8; 500];
        let packed = compress(&data);
        assert!(decompress(&packed, 10).is_err(), "cap must trip");
    }
}
