//! Pure-std LZ77 byte codec used for bag chunk compression.
//!
//! The offline crate set has no `flate2`, so the bag's compressed mode is
//! backed by this deflate-class LZ. The token stream is byte-aligned and
//! versionless — the *format* below is the compatibility contract; the
//! encoder is free to pick any valid token sequence, and has changed
//! over time (greedy single-probe → hash chains with lazy matching).
//! [`decompress`] reads every stream either encoder ever produced, so
//! old bags keep replaying. Determinism, safety on corrupt input, and a
//! strong ratio on redundant sensor payloads are the goals.
//!
//! Token stream:
//! * `0x00..=0x7F` — literal run: token value + 1 literal bytes follow.
//! * `0x80..=0xFF` — match: length = (token − 0x80) + 4 (4..=131),
//!   followed by a u16-LE distance (1..=65535) back into the output.
//!
//! Encoder: hash-chain match search (multiple candidates per 4-byte
//! hash, bounded probes) with one-step lazy matching — if the position
//! after a found match starts a strictly longer match, the current byte
//! is emitted as a literal and the longer match wins. Decoder: pre-
//! validated block copies via `extend_from_within` (doubling windows for
//! overlapped matches) instead of a bounds-checked push per byte.
//!
//! ```
//! use av_simd::util::lz::{compress, decompress};
//!
//! let data = b"sensor payload sensor payload sensor payload".to_vec();
//! let packed = compress(&data);
//! assert!(packed.len() < data.len(), "redundant input must shrink");
//! // decompression is bounded by the declared output length
//! assert_eq!(decompress(&packed, data.len()).unwrap(), data);
//! assert!(decompress(&packed, 4).is_err(), "length cap is enforced");
//! ```

use crate::error::{Error, Result};

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 131;
const MAX_DIST: usize = 65535;
const HASH_BITS: u32 = 15;
/// Max hash-chain candidates probed per position. 32 probes finds
/// near-optimal matches on sensor payloads while keeping compression
/// O(n · CHAIN_LIMIT) worst case.
const CHAIN_LIMIT: usize = 32;

#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    for chunk in lits.chunks(128) {
        out.push((chunk.len() - 1) as u8);
        out.extend_from_slice(chunk);
    }
}

/// Hash-chain index: `head[h]` is the most recent position with hash
/// `h`; `prev[p & WINDOW_MASK]` links position `p` to the previous
/// position sharing its hash. The prev table is a 64 Ki ring, not one
/// slot per input byte: matches farther than [`MAX_DIST`] are unusable
/// anyway, and the walk stops at the first candidate beyond it — before
/// any slot that could have been overwritten by an aliased newer
/// position (same residue positions differ by the full window). Keeps
/// the working set ~640 KiB regardless of input size (a per-byte table
/// would be 8× the multi-megabyte bag chunks this compresses).
struct Chains {
    head: Vec<usize>,
    prev: Vec<usize>,
}

/// Ring size for the prev table; must be a power of two > [`MAX_DIST`].
const WINDOW: usize = 1 << 16;
const WINDOW_MASK: usize = WINDOW - 1;

impl Chains {
    fn new() -> Self {
        Self {
            head: vec![usize::MAX; 1 << HASH_BITS],
            prev: vec![usize::MAX; WINDOW],
        }
    }

    #[inline]
    fn insert(&mut self, input: &[u8], pos: usize) {
        let h = hash4(&input[pos..]);
        self.prev[pos & WINDOW_MASK] = self.head[h];
        self.head[h] = pos;
    }

    /// Longest match for `pos` among chained candidates (bounded walk).
    fn best_match(&self, input: &[u8], pos: usize) -> Option<(usize, usize)> {
        let max_len = (input.len() - pos).min(MAX_MATCH);
        if max_len < MIN_MATCH {
            return None;
        }
        let mut cand = self.head[hash4(&input[pos..])];
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut probes = 0;
        while cand != usize::MAX && probes < CHAIN_LIMIT {
            let dist = pos - cand;
            if dist > MAX_DIST {
                break; // chain is position-ordered: older is only farther
            }
            // quick reject: a longer match must at least extend past the
            // current best (best_len < max_len here, so both in bounds)
            if input[cand + best_len] == input[pos + best_len] {
                let mut len = 0;
                while len < max_len && input[cand + len] == input[pos + len] {
                    len += 1;
                }
                if len > best_len {
                    best_len = len;
                    best_dist = dist;
                    if len == max_len {
                        break;
                    }
                }
            }
            cand = self.prev[cand & WINDOW_MASK];
            probes += 1;
        }
        (best_len >= MIN_MATCH).then_some((best_len, best_dist))
    }
}

/// Compress `input`. Worst case output is input + ~1/128 overhead.
/// Deterministic: a pure function of the input bytes.
pub fn compress(input: &[u8]) -> Vec<u8> {
    let n = input.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n < MIN_MATCH {
        flush_literals(&mut out, input);
        return out;
    }
    let mut chains = Chains::new();
    // last position with MIN_MATCH bytes of lookahead (inclusive)
    let last = n - MIN_MATCH;
    let mut lit_start = 0usize;
    let mut pos = 0usize;

    while pos <= last {
        let Some((mut len, mut dist)) = chains.best_match(input, pos) else {
            chains.insert(input, pos);
            pos += 1;
            continue;
        };
        chains.insert(input, pos);
        // lazy step: prefer a strictly longer match starting one byte on
        if len < MAX_MATCH && pos + 1 <= last {
            if let Some((len2, dist2)) = chains.best_match(input, pos + 1) {
                if len2 > len {
                    pos += 1; // current byte joins the literal run
                    chains.insert(input, pos);
                    len = len2;
                    dist = dist2;
                }
            }
        }
        flush_literals(&mut out, &input[lit_start..pos]);
        out.push(0x80 + (len - MIN_MATCH) as u8);
        out.extend_from_slice(&(dist as u16).to_le_bytes());
        // index the match interior so later data can reference it
        let end = pos + len;
        let mut p = pos + 1;
        let insert_end = end.min(last + 1);
        while p < insert_end {
            chains.insert(input, p);
            p += 1;
        }
        pos = end;
        lit_start = pos;
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

/// The original greedy single-probe encoder (one hash-table slot, first
/// candidate wins, sparse interior seeding). Kept (not `cfg(test)`) as
/// the ratio/throughput baseline for `examples/bench_engine.rs` and the
/// cross-encoder decode tests; produces the same token format.
#[doc(hidden)]
pub fn compress_greedy(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut pos = 0usize;

    while pos + MIN_MATCH <= input.len() {
        let h = hash4(&input[pos..]);
        let cand = table[h];
        table[h] = pos;
        let usable = cand != usize::MAX
            && pos - cand <= MAX_DIST
            && input[cand..cand + MIN_MATCH] == input[pos..pos + MIN_MATCH];
        if usable {
            let max = (input.len() - pos).min(MAX_MATCH);
            let mut len = MIN_MATCH;
            while len < max && input[cand + len] == input[pos + len] {
                len += 1;
            }
            flush_literals(&mut out, &input[lit_start..pos]);
            out.push(0x80 + (len - MIN_MATCH) as u8);
            out.extend_from_slice(&((pos - cand) as u16).to_le_bytes());
            let step = (len / 8).max(1);
            let mut p = pos + step;
            while p < pos + len && p + MIN_MATCH <= input.len() {
                table[hash4(&input[p..])] = p;
                p += step;
            }
            pos += len;
            lit_start = pos;
        } else {
            pos += 1;
        }
    }
    flush_literals(&mut out, &input[lit_start..]);
    out
}

/// Decompress into at most `expected_len` bytes. Any malformed token
/// (truncated run, zero/too-far distance, oversized output) is an
/// `Error::Corrupt` — never a panic, never unbounded allocation.
pub fn decompress(input: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_into(input, expected_len, &mut out)?;
    Ok(out)
}

/// Decompress directly into a caller-owned buffer (cleared first) — the
/// zero-copy decode path: `BagReader` feeds one reused scratch `Vec`
/// per reader, so a replay slice decodes every chunk without a fresh
/// allocation each time. Identical output bytes and error behavior to
/// [`decompress`]; on error the buffer contents are unspecified (but
/// its length never exceeds `expected_len`).
pub fn decompress_into(input: &[u8], expected_len: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(expected_len.min(1 << 26));
    let mut i = 0usize;
    while i < input.len() {
        let t = input[i];
        i += 1;
        if t < 0x80 {
            let n = t as usize + 1;
            if i + n > input.len() {
                return Err(Error::Corrupt("lz literal run truncated".into()));
            }
            if out.len() + n > expected_len {
                return Err(Error::Corrupt(format!(
                    "lz output exceeds declared length {expected_len}"
                )));
            }
            out.extend_from_slice(&input[i..i + n]);
            i += n;
        } else {
            let len = (t - 0x80) as usize + MIN_MATCH;
            if i + 2 > input.len() {
                return Err(Error::Corrupt("lz match header truncated".into()));
            }
            let dist = u16::from_le_bytes([input[i], input[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(Error::Corrupt(format!(
                    "lz match distance {dist} invalid at output offset {}",
                    out.len()
                )));
            }
            if out.len() + len > expected_len {
                return Err(Error::Corrupt(format!(
                    "lz output exceeds declared length {expected_len}"
                )));
            }
            let start = out.len() - dist;
            if dist >= len {
                // disjoint: one block copy
                out.extend_from_within(start..start + len);
            } else {
                // overlapped (run-length style): doubling windows — each
                // pass can copy everything written since `start`
                let mut remaining = len;
                while remaining > 0 {
                    let take = remaining.min(out.len() - start);
                    out.extend_from_within(start..start + take);
                    remaining -= take;
                }
            }
        }
    }
    Ok(())
}

/// The original byte-at-a-time decoder (push-per-byte match copies),
/// kept (not `cfg(test)`) as the `bench_engine` baseline and the
/// differential-test oracle for [`decompress`].
#[doc(hidden)]
pub fn decompress_reference(input: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(expected_len.min(1 << 26));
    let mut i = 0usize;
    while i < input.len() {
        let t = input[i];
        i += 1;
        if t < 0x80 {
            let n = t as usize + 1;
            if i + n > input.len() {
                return Err(Error::Corrupt("lz literal run truncated".into()));
            }
            out.extend_from_slice(&input[i..i + n]);
            i += n;
        } else {
            let len = (t - 0x80) as usize + MIN_MATCH;
            if i + 2 > input.len() {
                return Err(Error::Corrupt("lz match header truncated".into()));
            }
            let dist = u16::from_le_bytes([input[i], input[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(Error::Corrupt(format!(
                    "lz match distance {dist} invalid at output offset {}",
                    out.len()
                )));
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
        if out.len() > expected_len {
            return Err(Error::Corrupt(format!(
                "lz output exceeds declared length {expected_len}"
            )));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn roundtrip(data: &[u8]) {
        let packed = compress(data);
        let back = decompress(&packed, data.len()).unwrap();
        assert_eq!(back, data, "roundtrip failed for {} bytes", data.len());
        // the fast decoder and the reference decoder must agree bit for bit
        let back_ref = decompress_reference(&packed, data.len()).unwrap();
        assert_eq!(back_ref, data);
        // streams from the old greedy encoder must still decode
        let packed_greedy = compress_greedy(data);
        assert_eq!(decompress(&packed_greedy, data.len()).unwrap(), data);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[9; 4]);
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Prng::new(11);
        for n in [17usize, 100, 1000, 70_000] {
            let mut buf = vec![0u8; n];
            rng.fill_bytes(&mut buf);
            roundtrip(&buf);
        }
    }

    #[test]
    fn redundant_data_compresses_hard() {
        let data = vec![42u8; 80_000];
        let packed = compress(&data);
        assert!(packed.len() < data.len() / 16, "{} bytes", packed.len());
        roundtrip(&data);
    }

    #[test]
    fn repeated_structure_compresses() {
        let mut data = Vec::new();
        for i in 0..2_000u32 {
            data.extend_from_slice(b"topic:/camera type:Image payload=");
            data.extend_from_slice(&(i % 7).to_le_bytes());
        }
        let packed = compress(&data);
        assert!(packed.len() < data.len() / 4, "{} vs {}", packed.len(), data.len());
        // the chained encoder must never lose to the old greedy one here
        let greedy = compress_greedy(&data);
        assert!(
            packed.len() <= greedy.len(),
            "chained {} worse than greedy {}",
            packed.len(),
            greedy.len()
        );
        roundtrip(&data);
    }

    #[test]
    fn mixed_sensor_like_payload_roundtrips() {
        // interleave noise with structure: the lazy-match seam cases
        // (literal-then-longer-match) show up at these boundaries
        let mut rng = Prng::new(0xA5);
        let mut data = Vec::new();
        for i in 0..500u32 {
            let mut noise = vec![0u8; (i % 13) as usize];
            rng.fill_bytes(&mut noise);
            data.extend_from_slice(&noise);
            data.extend_from_slice(b"/lidar/points frame=");
            data.extend_from_slice(&i.to_le_bytes());
            data.extend_from_slice(&[0xEE; 9]);
        }
        roundtrip(&data);
    }

    #[test]
    fn overlapped_matches_roundtrip() {
        // distances shorter than the match length exercise the doubling-
        // window copy in the fast decoder
        for period in [1usize, 2, 3, 5, 7] {
            let data: Vec<u8> = (0..10_000).map(|i| (i % period) as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let packed = compress(&data);
        let mut rng = Prng::new(3);
        for _ in 0..200 {
            let mut bad = packed.clone();
            let pos = rng.below(bad.len() as u64) as usize;
            bad[pos] ^= 1 << rng.below(8);
            // corrupt input may still decode to wrong bytes, but must not
            // panic and must respect the declared-length cap
            if let Ok(out) = decompress(&bad, data.len()) {
                assert!(out.len() <= data.len());
            }
        }
        // truncation at every point must be safe too
        for cut in 0..packed.len().min(64) {
            let _ = decompress(&packed[..cut], data.len());
        }
    }

    #[test]
    fn declared_length_is_enforced() {
        let data = vec![1u8; 500];
        let packed = compress(&data);
        assert!(decompress(&packed, 10).is_err(), "cap must trip");
    }

    #[test]
    fn decompress_into_reuses_buffer_across_chunks() {
        // one scratch Vec through several differently-sized payloads —
        // bytes must match the allocating API every time, including
        // after a failed decode left the buffer in a dirty state
        let mut rng = Prng::new(21);
        let mut scratch = Vec::new();
        for n in [1000usize, 17, 70_000, 0, 333] {
            let mut data = vec![0u8; n];
            rng.fill_bytes(&mut data);
            data.extend_from_slice(b"repeat repeat repeat repeat");
            let packed = compress(&data);
            decompress_into(&packed, data.len(), &mut scratch).unwrap();
            assert_eq!(scratch, data, "n={n}");
            assert!(decompress_into(&packed, 3, &mut scratch).is_err(), "cap must trip");
        }
    }
}
