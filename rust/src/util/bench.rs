//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `harness = false` benches in `rust/benches/` and by the
//! performance examples: warmup, fixed-iteration or fixed-time sampling,
//! and a median/p95 table printer whose rows mirror the paper's figures.

use std::time::{Duration, Instant};

/// Result of one measured case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    /// Wall time of each measured iteration.
    pub iters: Vec<Duration>,
    /// Work units (e.g. bytes or messages) processed per iteration, if any.
    pub units_per_iter: Option<f64>,
    pub unit_label: &'static str,
}

impl Sample {
    pub fn median(&self) -> Duration {
        let mut v = self.iters.clone();
        v.sort_unstable();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.iters.iter().min().unwrap()
    }

    pub fn p95(&self) -> Duration {
        let mut v = self.iters.clone();
        v.sort_unstable();
        v[(v.len() as f64 * 0.95) as usize % v.len()]
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.iters.iter().sum();
        total / self.iters.len() as u32
    }

    /// Units per second at the median, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter
            .map(|u| u / self.median().as_secs_f64())
    }
}

/// Bench runner: `Bench::new("name").warmup(2).samples(10).run(|| work())`.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    sample_iters: usize,
    units: Option<f64>,
    unit_label: &'static str,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup_iters: 1,
            sample_iters: 5,
            units: None,
            unit_label: "",
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.sample_iters = n.max(1);
        self
    }

    /// Declare throughput units processed per iteration (bytes, msgs, imgs).
    pub fn units(mut self, per_iter: f64, label: &'static str) -> Self {
        self.units = Some(per_iter);
        self.unit_label = label;
        self
    }

    pub fn run<F: FnMut()>(self, mut f: F) -> Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut iters = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t = Instant::now();
            f();
            iters.push(t.elapsed());
        }
        Sample {
            name: self.name,
            iters,
            units_per_iter: self.units,
            unit_label: self.unit_label,
        }
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Human-readable rate.
pub fn fmt_rate(r: f64, label: &str) -> String {
    if r >= 1e9 {
        format!("{:.2} G{label}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M{label}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} K{label}/s", r / 1e3)
    } else {
        format!("{r:.2} {label}/s")
    }
}

/// Print a fixed-width results table; also returns the rendered string so
/// benches can tee it into EXPERIMENTS.md fragments.
pub fn print_table(title: &str, samples: &[Sample]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    out.push_str(&format!(
        "{:<44} {:>12} {:>12} {:>12} {:>16}\n",
        "case", "median", "min", "p95", "throughput"
    ));
    for s in samples {
        let tp = s
            .throughput()
            .map(|r| fmt_rate(r, s.unit_label))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}\n",
            s.name,
            fmt_duration(s.median()),
            fmt_duration(s.min()),
            fmt_duration(s.p95()),
            tp
        ));
    }
    print!("{out}");
    out
}

/// Speedup of `b` relative to `a` (a.median / b.median).
pub fn speedup(a: &Sample, b: &Sample) -> f64 {
    a.median().as_secs_f64() / b.median().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_samples() {
        let s = Bench::new("noop").warmup(1).samples(7).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters.len(), 7);
        assert!(s.median() <= s.p95());
    }

    #[test]
    fn throughput_computed() {
        let s = Bench::new("sleepy")
            .samples(3)
            .units(1000.0, "msg")
            .run(|| std::thread::sleep(Duration::from_millis(2)));
        let tp = s.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1_000_000.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert!(fmt_duration(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_rate(2.5e6, "B").contains("MB/s"));
    }

    #[test]
    fn speedup_ratio() {
        let a = Sample {
            name: "slow".into(),
            iters: vec![Duration::from_millis(100)],
            units_per_iter: None,
            unit_label: "",
        };
        let b = Sample {
            name: "fast".into(),
            iters: vec![Duration::from_millis(20)],
            units_per_iter: None,
            unit_label: "",
        };
        assert!((speedup(&a, &b) - 5.0).abs() < 1e-9);
    }
}
