//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `harness = false` benches in `rust/benches/` and by the
//! performance examples: warmup, fixed-iteration or fixed-time sampling,
//! and a median/p95 table printer whose rows mirror the paper's figures.

use std::time::{Duration, Instant};

/// Result of one measured case.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Bench case name.
    pub name: String,
    /// Wall time of each measured iteration.
    pub iters: Vec<Duration>,
    /// Work units (e.g. bytes or messages) processed per iteration, if any.
    pub units_per_iter: Option<f64>,
    /// Unit label for throughput (e.g. `"bytes"`, `"tasks"`).
    pub unit_label: &'static str,
}

impl Sample {
    /// Median sample duration.
    pub fn median(&self) -> Duration {
        let mut v = self.iters.clone();
        v.sort_unstable();
        v[v.len() / 2]
    }

    /// Fastest sample (least noisy statistic on shared runners).
    pub fn min(&self) -> Duration {
        *self.iters.iter().min().unwrap()
    }

    /// 95th-percentile sample duration.
    pub fn p95(&self) -> Duration {
        // nearest-rank with the index clamped into range — the old
        // `% len` wrap could alias a high percentile back to the fastest
        // samples on small counts
        let mut v = self.iters.clone();
        v.sort_unstable();
        let idx = ((v.len() as f64 * 0.95) as usize).min(v.len() - 1);
        v[idx]
    }

    /// Mean sample duration.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.iters.iter().sum();
        total / self.iters.len() as u32
    }

    /// Units per second at the median, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter
            .map(|u| u / self.median().as_secs_f64())
    }
}

/// Bench runner: `Bench::new("name").warmup(2).samples(10).run(|| work())`.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    sample_iters: usize,
    units: Option<f64>,
    unit_label: &'static str,
}

impl Bench {
    /// Bench builder for case `name`.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup_iters: 1,
            sample_iters: 5,
            units: None,
            unit_label: "",
        }
    }

    /// Set warmup iterations (default 3); builder-style.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    /// Set measured samples (default 10); builder-style.
    pub fn samples(mut self, n: usize) -> Self {
        self.sample_iters = n.max(1);
        self
    }

    /// Declare throughput units processed per iteration (bytes, msgs, imgs).
    pub fn units(mut self, per_iter: f64, label: &'static str) -> Self {
        self.units = Some(per_iter);
        self.unit_label = label;
        self
    }

    /// Run the bench: warmups, then timed samples of `f`.
    pub fn run<F: FnMut()>(self, mut f: F) -> Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut iters = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t = Instant::now();
            f();
            iters.push(t.elapsed());
        }
        Sample {
            name: self.name,
            iters,
            units_per_iter: self.units,
            unit_label: self.unit_label,
        }
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Human-readable rate.
pub fn fmt_rate(r: f64, label: &str) -> String {
    if r >= 1e9 {
        format!("{:.2} G{label}/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M{label}/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} K{label}/s", r / 1e3)
    } else {
        format!("{r:.2} {label}/s")
    }
}

/// Print a fixed-width results table; also returns the rendered string so
/// benches can tee it into EXPERIMENTS.md fragments.
pub fn print_table(title: &str, samples: &[Sample]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    out.push_str(&format!(
        "{:<44} {:>12} {:>12} {:>12} {:>16}\n",
        "case", "median", "min", "p95", "throughput"
    ));
    for s in samples {
        let tp = s
            .throughput()
            .map(|r| fmt_rate(r, s.unit_label))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}\n",
            s.name,
            fmt_duration(s.median()),
            fmt_duration(s.min()),
            fmt_duration(s.p95()),
            tp
        ));
    }
    print!("{out}");
    out
}

/// Speedup of `b` relative to `a` (a.median / b.median).
pub fn speedup(a: &Sample, b: &Sample) -> f64 {
    a.median().as_secs_f64() / b.median().as_secs_f64()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn sample_json(s: &Sample) -> String {
    let tp = s
        .throughput()
        .map(|r| format!("{r:.3}"))
        .unwrap_or_else(|| "null".into());
    format!(
        "{{\"name\":\"{}\",\"samples\":{},\"median_ns\":{},\"min_ns\":{},\
         \"p95_ns\":{},\"mean_ns\":{},\"throughput_units_per_s\":{tp},\
         \"unit\":\"{}\"}}",
        json_escape(&s.name),
        s.iters.len(),
        s.median().as_nanos(),
        s.min().as_nanos(),
        s.p95().as_nanos(),
        s.mean().as_nanos(),
        json_escape(s.unit_label),
    )
}

/// Render a bench report as a JSON document: the measured samples plus
/// named scalar facts (speedups, ratios, config). Schema documented in
/// the README's benchmarking section.
pub fn report_json(title: &str, samples: &[Sample], facts: &[(&str, f64)]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": 1,\n  \"title\": \"{}\",\n", json_escape(title)));
    out.push_str(&format!(
        "  \"created_unix_ns\": {},\n",
        crate::util::now_nanos()
    ));
    out.push_str("  \"benches\": [\n");
    for (i, s) in samples.iter().enumerate() {
        let sep = if i + 1 == samples.len() { "" } else { "," };
        out.push_str(&format!("    {}{sep}\n", sample_json(s)));
    }
    out.push_str("  ],\n  \"facts\": {\n");
    for (i, (k, v)) in facts.iter().enumerate() {
        let sep = if i + 1 == facts.len() { "" } else { "," };
        let v = if v.is_finite() { format!("{v:.4}") } else { "null".into() };
        out.push_str(&format!("    \"{}\": {v}{sep}\n", json_escape(k)));
    }
    out.push_str("  }\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_samples() {
        let s = Bench::new("noop").warmup(1).samples(7).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters.len(), 7);
        assert!(s.median() <= s.p95());
    }

    #[test]
    fn throughput_computed() {
        let s = Bench::new("sleepy")
            .samples(3)
            .units(1000.0, "msg")
            .run(|| std::thread::sleep(Duration::from_millis(2)));
        let tp = s.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1_000_000.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert!(fmt_duration(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_rate(2.5e6, "B").contains("MB/s"));
    }

    fn sample_of_millis(ms: &[u64]) -> Sample {
        Sample {
            name: "t".into(),
            iters: ms.iter().map(|&m| Duration::from_millis(m)).collect(),
            units_per_iter: None,
            unit_label: "",
        }
    }

    #[test]
    fn p95_is_clamped_and_sane_on_small_sample_counts() {
        // 1 sample: p95 is that sample (the old `% len` math held here
        // only by accident of the wrap)
        assert_eq!(sample_of_millis(&[7]).p95(), Duration::from_millis(7));
        // 2 samples: index 1 (the slower one), never wrapped back to 0
        assert_eq!(sample_of_millis(&[5, 9]).p95(), Duration::from_millis(9));
        // 3 samples: (3*0.95)=2 → the max
        assert_eq!(sample_of_millis(&[3, 1, 2]).p95(), Duration::from_millis(3));
        // 20 samples 1..=20: index 19 → 20ms, and must be >= median
        let v: Vec<u64> = (1..=20).collect();
        let s = sample_of_millis(&v);
        assert_eq!(s.p95(), Duration::from_millis(20));
        assert!(s.p95() >= s.median());
        // 100 samples: nearest-rank 95th — index 95 → 96ms
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(sample_of_millis(&v).p95(), Duration::from_millis(96));
    }

    #[test]
    fn p95_never_below_median_for_any_count() {
        for n in 1..=40u64 {
            let v: Vec<u64> = (1..=n).collect();
            let s = sample_of_millis(&v);
            assert!(s.p95() >= s.median(), "n={n}: p95 {:?} < median {:?}", s.p95(), s.median());
        }
    }

    #[test]
    fn report_json_is_well_formed_enough() {
        let s = Bench::new("fmt\"check").samples(2).units(10.0, "B").run(|| {
            std::hint::black_box(1 + 1);
        });
        let j = report_json("t", &[s], &[("speedup", 2.0)]);
        assert!(j.contains("\"schema\": 1"));
        assert!(j.contains("fmt\\\"check"), "quotes must be escaped: {j}");
        assert!(j.contains("\"speedup\": 2.0000"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn speedup_ratio() {
        let a = Sample {
            name: "slow".into(),
            iters: vec![Duration::from_millis(100)],
            units_per_iter: None,
            unit_label: "",
        };
        let b = Sample {
            name: "fast".into(),
            iters: vec![Duration::from_millis(20)],
            units_per_iter: None,
            unit_label: "",
        };
        assert!((speedup(&a, &b) - 5.0).abs() < 1e-9);
    }
}
