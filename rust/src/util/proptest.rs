//! Hand-rolled property-testing helper (the `proptest` crate is not in the
//! offline crate set). Seeded generators + a fixed-iteration runner with
//! failure reporting that includes the case seed, so any failing case is
//! reproducible by rerunning with that seed.

use crate::util::prng::Prng;

/// Number of cases per property (overridable via `AV_SIMD_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("AV_SIMD_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` generated inputs. `gen` receives an
/// independent PRNG per case. Panics with the case seed on failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Prng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    check_n(name, default_cases(), gen, prop)
}

/// Like [`check`] with an explicit case count.
pub fn check_n<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    gen: impl Fn(&mut Prng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let base = std::env::var("AV_SIMD_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xA5EED_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Prng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n{input:#?}\n\
                 reproduce with AV_SIMD_PROP_SEED={base} (case index {case})"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::prng::Prng;

    /// Random byte payload, length in [0, max_len].
    pub fn bytes(rng: &mut Prng, max_len: usize) -> Vec<u8> {
        let n = rng.below(max_len as u64 + 1) as usize;
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    /// Random ASCII identifier (non-empty, [a-z0-9_/], length ≤ max_len).
    pub fn ident(rng: &mut Prng, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_/";
        let n = 1 + rng.below(max_len.max(1) as u64) as usize;
        (0..n)
            .map(|_| CHARS[rng.below(CHARS.len() as u64) as usize] as char)
            .collect()
    }

    /// Vec of T with length in [0, max_len].
    pub fn vec_of<T>(
        rng: &mut Prng,
        max_len: usize,
        mut f: impl FnMut(&mut Prng) -> T,
    ) -> Vec<T> {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n).map(|_| f(rng)).collect()
    }

    /// Finite f64 in [lo, hi).
    pub fn f64_in(rng: &mut Prng, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", |r| gen::bytes(r, 64), |b| {
            let mut x = b.clone();
            x.reverse();
            x.reverse();
            x == *b
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_reports_seed() {
        check_n("always false", 1, |r| r.next_u64(), |_| false);
    }

    #[test]
    fn ident_is_well_formed() {
        check("idents non-empty ascii", |r| gen::ident(r, 20), |s| {
            !s.is_empty() && s.len() <= 20 && s.is_ascii()
        });
    }
}
