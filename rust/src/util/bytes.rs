//! Binary encode/decode primitives shared by every wire/disk format in the
//! platform (bag records, RPC frames, BinPipedRDD streams, messages).
//!
//! Everything is little-endian. Variable-length integers use LEB128.

use crate::error::{Error, Result};

/// Append-only byte writer with typed put_* helpers.
#[derive(Default, Debug, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    /// Empty writer with `cap` bytes preallocated.
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    /// Consume into the underlying byte vector.
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// The bytes written so far.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`, little-endian.
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`, little-endian.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `i64`, little-endian.
    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32`, little-endian.
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64`, little-endian.
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint.
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Length-prefixed (varint) byte slice.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_varint(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    /// Raw bytes, no length prefix.
    pub fn put_raw(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Append a bool as one byte (0/1).
    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// f32 slice with varint count prefix.
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_varint(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Cursor-based byte reader; every getter checks bounds and returns
/// `Error::Corrupt` on truncation instead of panicking.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when the cursor is at the end.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Bytes consumed so far.
    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Corrupt(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    /// Read a little-endian `u32`.
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a little-endian `i64`.
    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a little-endian `f32`.
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `f64`.
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a bool byte (any non-zero is true).
    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    /// Read a LEB128 unsigned varint.
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            if shift >= 64 {
                return Err(Error::Corrupt("varint overflow".into()));
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Read a varint-length-prefixed byte slice (borrowed).
    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_varint()? as usize;
        self.take(n)
    }

    /// Read a varint-length-prefixed byte slice (owned).
    pub fn get_bytes_vec(&mut self) -> Result<Vec<u8>> {
        Ok(self.get_bytes()?.to_vec())
    }

    /// Read a varint-length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Corrupt("invalid utf-8 string".into()))
    }

    /// Read exactly `n` raw bytes (borrowed).
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Read a varint-count-prefixed `f32` list.
    pub fn get_f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() / 4 + 1 {
            return Err(Error::Corrupt(format!("f32 vec claims {n} elements")));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f32()?);
        }
        Ok(v)
    }
}

/// Read exactly `n` bytes from a `Read`, mapping EOF to `Error::Corrupt`.
pub fn read_exact_n<R: std::io::Read>(r: &mut R, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Corrupt(format!("unexpected EOF reading {n} bytes"))
        } else {
            Error::Io(e)
        }
    })?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u16(65535);
        w.put_u32(123456);
        w.put_u64(u64::MAX);
        w.put_i64(-42);
        w.put_f32(3.5);
        w.put_f64(-2.25);
        w.put_bool(true);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 65535);
        assert_eq!(r.get_u32().unwrap(), 123456);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f32().unwrap(), 3.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert!(r.get_bool().unwrap());
        assert!(r.is_empty());
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut w = ByteWriter::new();
            w.put_varint(v);
            let buf = w.into_vec();
            let mut r = ByteReader::new(&buf);
            assert_eq!(r.get_varint().unwrap(), v, "value {v}");
            assert!(r.is_empty());
        }
    }

    #[test]
    fn strings_and_bytes() {
        let mut w = ByteWriter::new();
        w.put_str("topic/ライダー");
        w.put_bytes(&[1, 2, 3]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_str().unwrap(), "topic/ライダー");
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.put_u64(1);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf[..5]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn bad_utf8_is_corrupt() {
        let mut w = ByteWriter::new();
        w.put_bytes(&[0xff, 0xfe]);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert!(matches!(r.get_str(), Err(Error::Corrupt(_))));
    }

    #[test]
    fn f32_slice_roundtrip() {
        let v: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let mut w = ByteWriter::new();
        w.put_f32_slice(&v);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_f32_vec().unwrap(), v);
    }

    #[test]
    fn varint_overflow_rejected() {
        let buf = [0xffu8; 11];
        let mut r = ByteReader::new(&buf);
        assert!(r.get_varint().is_err());
    }
}
