//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial) in pure std —
//! the offline crate set has no `crc32fast`. Used by the bag format's
//! record envelopes and the RPC framing hot path.
//!
//! Slicing-by-8: eight 256-entry tables let the inner loop fold 8 input
//! bytes per iteration with no inter-byte data dependency chain, ~4-6×
//! the classic byte-at-a-time loop (kept as [`hash_bytewise`] for the
//! differential tests and the `bench_engine` baseline). The tables are
//! built at compile time so there is no runtime init and no locking,
//! and the output is bit-identical to the one-table version — bags
//! written before the swap still verify.
//!
//! ```
//! // the standard CRC-32 check value
//! assert_eq!(av_simd::util::crc32::hash(b"123456789"), 0xCBF4_3926);
//! assert_eq!(av_simd::util::crc32::hash(b""), 0);
//! ```

/// Reflected polynomial for CRC-32/ISO-HDLC (zlib, gzip, rosbag).
const POLY: u32 = 0xEDB8_8320;

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    // table 0: the classic reflected table
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[0][i] = c;
        i += 1;
    }
    // table k advances table k-1 by one more zero byte
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = t[0][(prev & 0xff) as usize] ^ (prev >> 8);
            i += 1;
        }
        k += 1;
    }
    t
}

static TABLES: [[u32; 256]; 8] = build_tables();

/// CRC-32 of `data` (init `!0`, final xor `!0` — identical output to
/// `crc32fast::hash`).
pub fn hash(data: &[u8]) -> u32 {
    let mut c = !0u32;
    let mut chunks = data.chunks_exact(8);
    for ch in chunks.by_ref() {
        let lo = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]) ^ c;
        let hi = u32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]);
        c = TABLES[7][(lo & 0xff) as usize]
            ^ TABLES[6][((lo >> 8) & 0xff) as usize]
            ^ TABLES[5][((lo >> 16) & 0xff) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][(hi & 0xff) as usize]
            ^ TABLES[2][((hi >> 8) & 0xff) as usize]
            ^ TABLES[1][((hi >> 16) & 0xff) as usize]
            ^ TABLES[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        c = TABLES[0][((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// Byte-at-a-time reference implementation. Kept (not `cfg(test)`) as
/// the baseline for `examples/bench_engine.rs` and the differential
/// tests below; production callers use [`hash`].
#[doc(hidden)]
pub fn hash_bytewise(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = TABLES[0][((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32/ISO-HDLC check values.
        assert_eq!(hash(b""), 0x0000_0000);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_every_byte() {
        let base = vec![7u8; 64];
        let h = hash(&base);
        for i in 0..base.len() {
            let mut m = base.clone();
            m[i] ^= 1;
            assert_ne!(hash(&m), h, "flip at {i} undetected");
        }
    }

    #[test]
    fn stable_across_calls() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        assert_eq!(hash(&data), hash(&data));
    }

    #[test]
    fn sliced_matches_bytewise_at_every_length() {
        // every alignment/remainder combination through several 8-byte
        // blocks, plus a large buffer
        let mut rng = crate::util::prng::Prng::new(0x51ce);
        let mut buf = vec![0u8; 4096];
        rng.fill_bytes(&mut buf);
        for n in 0..64 {
            assert_eq!(hash(&buf[..n]), hash_bytewise(&buf[..n]), "len {n}");
        }
        for n in [100, 255, 256, 1023, 4096] {
            assert_eq!(hash(&buf[..n]), hash_bytewise(&buf[..n]), "len {n}");
        }
        // and at every offset, so misaligned starts are covered too
        for off in 0..16 {
            assert_eq!(hash(&buf[off..]), hash_bytewise(&buf[off..]), "offset {off}");
        }
    }
}
