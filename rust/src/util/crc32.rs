//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial) in pure std —
//! the offline crate set has no `crc32fast`. Used by the bag format's
//! record envelopes. Table-driven, 4 bytes per step; the table is built
//! at compile time so there is no runtime init and no locking.

/// Reflected polynomial for CRC-32/ISO-HDLC (zlib, gzip, rosbag).
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `data` (init `!0`, final xor `!0` — identical output to
/// `crc32fast::hash`, so bags written before the vendored swap still
/// verify).
pub fn hash(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard CRC-32/ISO-HDLC check values.
        assert_eq!(hash(b""), 0x0000_0000);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_every_byte() {
        let base = vec![7u8; 64];
        let h = hash(&base);
        for i in 0..base.len() {
            let mut m = base.clone();
            m[i] ^= 1;
            assert_ne!(hash(&m), h, "flip at {i} undetected");
        }
    }

    #[test]
    fn stable_across_calls() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        assert_eq!(hash(&data), hash(&data));
    }
}
