//! Shared utilities: byte codecs, deterministic PRNG, bench harness,
//! property-test helper, human formatting.

pub mod bench;
pub mod bytes;
pub mod prng;
pub mod proptest;

/// Format a byte count as a human-readable size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Monotonic nanosecond timestamp helper used by metrics and the sim clock.
pub fn now_nanos() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn now_is_monotonic_enough() {
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
    }
}
