//! Shared utilities: byte codecs, deterministic PRNG, bench harness,
//! property-test helper, human formatting.

pub mod bench;
pub mod bytes;
pub mod crc32;
pub mod lz;
pub mod prng;
pub mod proptest;
pub mod sha256;

/// Minimal leveled stderr logger (the `log` crate is not in the offline
/// crate set). Level order: error < warn < info < debug; the enabled
/// threshold comes from `AV_SIMD_LOG` (default `warn`; `off`/`none`
/// silences everything; any other unknown value means debug).
pub fn log_enabled(level: &str) -> bool {
    fn rank(l: &str) -> u8 {
        match l {
            "off" | "none" => 0,
            "error" => 1,
            "warn" => 2,
            "info" => 3,
            _ => 4,
        }
    }
    static THRESHOLD: std::sync::OnceLock<u8> = std::sync::OnceLock::new();
    let threshold = *THRESHOLD.get_or_init(|| {
        rank(std::env::var("AV_SIMD_LOG").as_deref().unwrap_or("warn"))
    });
    rank(level) <= threshold
}

/// `logmsg!("warn", "task {id} failed")` — leveled stderr logging with
/// zero formatting cost when the level is disabled. Every line carries a
/// monotonic `+MILLISms` offset from process start so interleaved worker
/// stderr is orderable during chaos runs.
#[macro_export]
macro_rules! logmsg {
    ($lvl:literal, $($arg:tt)*) => {
        if $crate::util::log_enabled($lvl) {
            eprintln!(
                "[av-simd {} +{}ms] {}",
                $lvl,
                $crate::util::mono_millis(),
                format!($($arg)*)
            );
        }
    };
}

/// Format a byte count as a human-readable size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Monotonic nanosecond timestamp helper used by metrics and the sim clock.
pub fn now_nanos() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

fn mono_anchor() -> std::time::Instant {
    static ANCHOR: std::sync::OnceLock<std::time::Instant> = std::sync::OnceLock::new();
    *ANCHOR.get_or_init(std::time::Instant::now)
}

/// Truly monotonic nanoseconds since this process's first clock read
/// (`Instant`-based, immune to wall-clock steps — unlike [`now_nanos`]).
/// Trace spans and log timestamps use this so intra-process ordering is
/// exact; cross-process alignment happens via the RPC handshake offset.
pub fn mono_nanos() -> u64 {
    mono_anchor().elapsed().as_nanos() as u64
}

/// Monotonic milliseconds since process start (see [`mono_nanos`]).
pub fn mono_millis() -> u64 {
    mono_nanos() / 1_000_000
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn now_is_monotonic_enough() {
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
    }

    #[test]
    fn mono_clock_is_monotonic_and_anchored() {
        let a = mono_nanos();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = mono_nanos();
        assert!(b > a, "mono_nanos must advance: {a} -> {b}");
        assert!(mono_millis() >= a / 1_000_000, "millis derive from the same anchor");
    }
}
