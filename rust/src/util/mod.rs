//! Shared utilities: byte codecs, deterministic PRNG, bench harness,
//! property-test helper, human formatting.

pub mod bench;
pub mod bytes;
pub mod crc32;
pub mod lz;
pub mod prng;
pub mod proptest;
pub mod sha256;

/// Minimal leveled stderr logger (the `log` crate is not in the offline
/// crate set). Level order: error < warn < info < debug; the enabled
/// threshold comes from `AV_SIMD_LOG` (default `warn`).
pub fn log_enabled(level: &str) -> bool {
    fn rank(l: &str) -> u8 {
        match l {
            "error" => 0,
            "warn" => 1,
            "info" => 2,
            _ => 3,
        }
    }
    static THRESHOLD: std::sync::OnceLock<u8> = std::sync::OnceLock::new();
    let threshold = *THRESHOLD.get_or_init(|| {
        rank(std::env::var("AV_SIMD_LOG").as_deref().unwrap_or("warn"))
    });
    rank(level) <= threshold
}

/// `logmsg!("warn", "task {id} failed")` — leveled stderr logging with
/// zero formatting cost when the level is disabled.
#[macro_export]
macro_rules! logmsg {
    ($lvl:literal, $($arg:tt)*) => {
        if $crate::util::log_enabled($lvl) {
            eprintln!("[av-simd {}] {}", $lvl, format!($($arg)*));
        }
    };
}

/// Format a byte count as a human-readable size.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Monotonic nanosecond timestamp helper used by metrics and the sim clock.
pub fn now_nanos() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn now_is_monotonic_enough() {
        let a = now_nanos();
        let b = now_nanos();
        assert!(b >= a);
    }
}
