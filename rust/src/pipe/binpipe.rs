//! BinPipedRDD child-process execution — the paper's §3 design decision:
//! Spark⇄ROS integration over **Linux pipes** rather than JNI, "a
//! unidirectional data channel … buffered by the kernel until it is read".
//!
//! [`pipe_through_child`] spawns a worker subprocess (our own binary in
//! `user-logic` mode), streams the serialized partition into its stdin
//! from a writer thread, and reads the transformed stream from its stdout
//! concurrently — both directions use the Fig 4 codec. stderr is captured
//! and surfaced in errors; non-zero exits fail the task.
//!
//! [`run_user_logic_stdio`] is the child side: decode stdin → apply the
//! named logic → encode stdout.

use super::codec::{PipeItem, StreamReader, StreamWriter};
use super::logic::LogicRegistry;
use crate::error::{Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::process::{Command, Stdio};

/// How the child process is launched.
#[derive(Debug, Clone)]
pub struct ChildSpec {
    /// Executable path (defaults to the current binary).
    pub program: String,
    /// Arguments (defaults to `["user-logic", <logic>]`).
    pub args: Vec<String>,
    /// Extra environment (artifact dir etc.).
    pub env: Vec<(String, String)>,
}

impl ChildSpec {
    /// Run `logic` via the current executable's `user-logic` mode.
    pub fn for_logic(logic: &str) -> Result<Self> {
        let exe = std::env::current_exe()
            .map_err(|e| Error::Pipe(format!("cannot locate current exe: {e}")))?;
        Ok(Self {
            program: exe.to_string_lossy().into_owned(),
            args: vec!["user-logic".into(), logic.into()],
            env: Vec::new(),
        })
    }
}

/// Pipe a partition of items through a child process.
pub fn pipe_through_child(spec: &ChildSpec, items: Vec<PipeItem>) -> Result<Vec<PipeItem>> {
    let mut cmd = Command::new(&spec.program);
    cmd.args(&spec.args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    for (k, v) in &spec.env {
        cmd.env(k, v);
    }
    let mut child = cmd
        .spawn()
        .map_err(|e| Error::Pipe(format!("spawn {}: {e}", spec.program)))?;

    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut stderr = child.stderr.take().expect("piped stderr");

    // Writer thread: stream items into the child. Kernel pipe buffers are
    // small (64 KiB), so writing and reading must be concurrent or large
    // partitions deadlock.
    let writer = std::thread::spawn(move || -> Result<()> {
        let mut sw = StreamWriter::new(BufWriter::with_capacity(256 * 1024, stdin));
        for item in &items {
            sw.write_item(item)?;
        }
        sw.finish()?;
        Ok(())
    });

    // stderr drain thread (avoid blocking the child on a full stderr pipe).
    let errs = std::thread::spawn(move || {
        let mut buf = String::new();
        let _ = stderr.read_to_string(&mut buf);
        buf
    });

    let mut sr = StreamReader::new(BufReader::with_capacity(256 * 1024, stdout));
    let out = sr.collect_items();

    let write_res = writer.join().expect("writer thread panicked");
    let stderr_text = errs.join().expect("stderr thread panicked");
    let status = child
        .wait()
        .map_err(|e| Error::Pipe(format!("wait for child: {e}")))?;

    if !status.success() {
        return Err(Error::Pipe(format!(
            "user-logic child exited with {status}; stderr:\n{}",
            stderr_text.trim()
        )));
    }
    write_res?;
    out
}

/// Child-side main: read a stream from `input`, apply `logic`, write the
/// result to `output`. Returns the number of input items processed.
pub fn run_user_logic_stdio(
    registry: &LogicRegistry,
    logic: &str,
    input: impl Read,
    output: impl Write,
) -> Result<usize> {
    let f = registry.get(logic)?;
    let mut sr = StreamReader::new(BufReader::with_capacity(256 * 1024, input));
    let items = sr.collect_items()?;
    let n = items.len();
    let results = f(items)?;
    let mut sw = StreamWriter::new(BufWriter::with_capacity(256 * 1024, output));
    for item in &results {
        sw.write_item(item)?;
    }
    sw.finish()?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stdio_roundtrip_identity() {
        let reg = LogicRegistry::with_builtins();
        let items = vec![
            PipeItem::Str("a".into()),
            PipeItem::Bytes(vec![1, 2, 3]),
        ];
        let input = super::super::codec::serialize_stream(&items);
        let mut out = Vec::new();
        let n = run_user_logic_stdio(&reg, "identity", &input[..], &mut out).unwrap();
        assert_eq!(n, 2);
        assert_eq!(super::super::codec::deserialize_stream(&out).unwrap(), items);
    }

    #[test]
    fn stdio_unknown_logic_errors() {
        let reg = LogicRegistry::with_builtins();
        let input = super::super::codec::serialize_stream(&[]);
        let mut out = Vec::new();
        assert!(run_user_logic_stdio(&reg, "bogus", &input[..], &mut out).is_err());
    }

    // Child-process tests use /bin/cat as a perfect "identity" user
    // program: the stream format is its own interchange, so cat must
    // round-trip it. Tests of the real `user-logic` subcommand live in
    // rust/tests/ (they need the built binary).
    #[test]
    fn pipe_through_cat_roundtrips() {
        let spec = ChildSpec {
            program: "/bin/cat".into(),
            args: vec![],
            env: vec![],
        };
        let items: Vec<PipeItem> = (0..100)
            .map(|i| PipeItem::Bytes(vec![i as u8; 1000]))
            .collect();
        let out = pipe_through_child(&spec, items.clone()).unwrap();
        assert_eq!(out, items);
    }

    #[test]
    fn large_partition_does_not_deadlock() {
        // > kernel pipe buffer in both directions simultaneously.
        let spec = ChildSpec { program: "/bin/cat".into(), args: vec![], env: vec![] };
        let items: Vec<PipeItem> =
            (0..64).map(|i| PipeItem::Bytes(vec![i as u8; 64 * 1024])).collect();
        let out = pipe_through_child(&spec, items.clone()).unwrap();
        assert_eq!(out.len(), items.len());
    }

    #[test]
    fn failing_child_reports_stderr() {
        let spec = ChildSpec {
            program: "/bin/sh".into(),
            args: vec!["-c".into(), "echo boom >&2; exit 3".into()],
            env: vec![],
        };
        let err = pipe_through_child(&spec, vec![]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("boom"), "stderr surfaced: {msg}");
    }

    #[test]
    fn child_emitting_garbage_is_pipe_error() {
        let spec = ChildSpec {
            program: "/bin/sh".into(),
            args: vec!["-c".into(), "cat > /dev/null; echo garbage".into()],
            env: vec![],
        };
        let err = pipe_through_child(&spec, vec![PipeItem::I64(1)]).unwrap_err();
        assert!(matches!(err, Error::Pipe(_)));
    }
}
