//! BinPipedRDD — binary data streaming between the engine and external
//! user programs over Linux pipes (paper §3.1, Fig 4).
//!
//! * [`codec`] — the uniform byte-array format + stream (de)serialization.
//! * [`logic`] — named user-logic transforms run inside the child.
//! * [`binpipe`] — parent/child process plumbing.

pub mod binpipe;
pub mod codec;
pub mod logic;

pub use binpipe::{pipe_through_child, run_user_logic_stdio, ChildSpec};
pub use codec::{deserialize_stream, serialize_stream, PipeItem, StreamReader, StreamWriter};
pub use logic::{LogicRegistry, LogicFn};
