//! BinPipedRDD wire codec — the paper's §3.1 encode/serialize stages
//! (Fig 4).
//!
//! "The encoding stage will encode all supported inputs format including
//! strings (e.g., file name) and integers (e.g., binary content size)
//! into our uniform format, which is based on byte array. Afterward, the
//! serialization stage will combine all byte arrays … into one single
//! binary stream."
//!
//! Stream layout:
//! ```text
//! stream := MAGIC:u32 version:u8 item* END
//! item   := TAG_* varint-len payload
//! END    := TAG_END
//! ```
//! Items are self-describing [`PipeItem`]s (string / i64 / raw bytes /
//! named file record), so arbitrary binary sensor data crosses the pipe
//! without any text assumption — the exact problem the paper calls out
//! with Spark's default text-based `PipedRDD`.

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::io::{BufRead, Write};

/// Stream header magic ("BPDR").
pub const STREAM_MAGIC: u32 = 0x4250_4452; // "BPDR"
/// Stream format version written after the magic.
pub const STREAM_VERSION: u8 = 1;

const TAG_END: u8 = 0;
const TAG_STR: u8 = 1;
const TAG_I64: u8 = 2;
const TAG_BYTES: u8 = 3;
const TAG_FILE: u8 = 4;

/// One element of a binary pipe stream — the paper's "uniform format".
#[derive(Debug, Clone, PartialEq)]
pub enum PipeItem {
    /// A string (e.g. a file name or topic).
    Str(String),
    /// An integer (e.g. a binary content size or count).
    I64(i64),
    /// Raw binary content (e.g. one encoded message or image).
    Bytes(Vec<u8>),
    /// A named binary file record (name + content), the unit the paper's
    /// examples use ("rotate the jpg file by 90 degrees").
    File { name: String, content: Vec<u8> },
}

impl PipeItem {
    /// Encode one item (the "encoding stage").
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            PipeItem::Str(s) => {
                w.put_u8(TAG_STR);
                w.put_str(s);
            }
            PipeItem::I64(v) => {
                w.put_u8(TAG_I64);
                w.put_i64(*v);
            }
            PipeItem::Bytes(b) => {
                w.put_u8(TAG_BYTES);
                w.put_bytes(b);
            }
            PipeItem::File { name, content } => {
                w.put_u8(TAG_FILE);
                w.put_str(name);
                w.put_bytes(content);
            }
        }
    }

    fn decode_from(tag: u8, r: &mut ByteReader<'_>) -> Result<Self> {
        match tag {
            TAG_STR => Ok(PipeItem::Str(r.get_str()?)),
            TAG_I64 => Ok(PipeItem::I64(r.get_i64()?)),
            TAG_BYTES => Ok(PipeItem::Bytes(r.get_bytes_vec()?)),
            TAG_FILE => Ok(PipeItem::File { name: r.get_str()?, content: r.get_bytes_vec()? }),
            other => Err(Error::Pipe(format!("unknown pipe item tag {other}"))),
        }
    }

    /// Approximate encoded size (for buffer pre-sizing).
    pub fn encoded_len(&self) -> usize {
        match self {
            PipeItem::Str(s) => s.len() + 6,
            PipeItem::I64(_) => 9,
            PipeItem::Bytes(b) => b.len() + 6,
            PipeItem::File { name, content } => name.len() + content.len() + 11,
        }
    }
}

/// Serialize a whole partition into one binary stream (the
/// "serialization stage").
pub fn serialize_stream(items: &[PipeItem]) -> Vec<u8> {
    let cap: usize = 16 + items.iter().map(|i| i.encoded_len()).sum::<usize>();
    let mut w = ByteWriter::with_capacity(cap);
    w.put_u32(STREAM_MAGIC);
    w.put_u8(STREAM_VERSION);
    for item in items {
        item.encode_into(&mut w);
    }
    w.put_u8(TAG_END);
    w.into_vec()
}

/// De-serialize a full in-memory stream.
pub fn deserialize_stream(buf: &[u8]) -> Result<Vec<PipeItem>> {
    let mut r = ByteReader::new(buf);
    let magic = r.get_u32()?;
    if magic != STREAM_MAGIC {
        return Err(Error::Pipe(format!("bad stream magic {magic:#x}")));
    }
    let ver = r.get_u8()?;
    if ver != STREAM_VERSION {
        return Err(Error::Pipe(format!("unsupported stream version {ver}")));
    }
    let mut items = Vec::new();
    loop {
        let tag = r.get_u8()?;
        if tag == TAG_END {
            break;
        }
        items.push(PipeItem::decode_from(tag, &mut r)?);
    }
    if !r.is_empty() {
        return Err(Error::Pipe(format!("{} trailing bytes after END", r.remaining())));
    }
    Ok(items)
}

/// Incremental stream writer over any `Write` (the child's stdout, the
/// parent's pipe-in): header, then items, then `finish()`.
pub struct StreamWriter<W: Write> {
    w: W,
    started: bool,
}

impl<W: Write> StreamWriter<W> {
    /// Writer over `w`; the header is emitted lazily.
    pub fn new(w: W) -> Self {
        Self { w, started: false }
    }

    fn ensure_header(&mut self) -> Result<()> {
        if !self.started {
            self.w.write_all(&STREAM_MAGIC.to_le_bytes())?;
            self.w.write_all(&[STREAM_VERSION])?;
            self.started = true;
        }
        Ok(())
    }

    /// Append one item (writes the header first if needed).
    pub fn write_item(&mut self, item: &PipeItem) -> Result<()> {
        self.ensure_header()?;
        let mut buf = ByteWriter::with_capacity(item.encoded_len());
        item.encode_into(&mut buf);
        self.w.write_all(buf.as_slice())?;
        Ok(())
    }

    /// Write END and flush; returns the inner writer.
    pub fn finish(mut self) -> Result<W> {
        self.ensure_header()?;
        self.w.write_all(&[TAG_END])?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Incremental stream reader over any `BufRead` (the parent reading the
/// child's stdout). Yields items until END.
pub struct StreamReader<R: BufRead> {
    r: R,
    header_read: bool,
    done: bool,
}

impl<R: BufRead> StreamReader<R> {
    /// Reader over `r`; the header is checked on first read.
    pub fn new(r: R) -> Self {
        Self { r, header_read: false, done: false }
    }

    fn read_u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b).map_err(map_eof)?;
        Ok(b[0])
    }

    fn read_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.read_u8()?;
            if shift >= 64 {
                return Err(Error::Pipe("varint overflow in stream".into()));
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn read_len_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.read_varint()? as usize;
        let mut buf = vec![0u8; n];
        self.r.read_exact(&mut buf).map_err(map_eof)?;
        Ok(buf)
    }

    fn read_str(&mut self) -> Result<String> {
        String::from_utf8(self.read_len_bytes()?)
            .map_err(|_| Error::Pipe("invalid utf-8 in stream".into()))
    }

    fn ensure_header(&mut self) -> Result<()> {
        if self.header_read {
            return Ok(());
        }
        let mut m = [0u8; 4];
        self.r.read_exact(&mut m).map_err(map_eof)?;
        if u32::from_le_bytes(m) != STREAM_MAGIC {
            return Err(Error::Pipe("bad stream magic from pipe".into()));
        }
        let ver = self.read_u8()?;
        if ver != STREAM_VERSION {
            return Err(Error::Pipe(format!("unsupported stream version {ver}")));
        }
        self.header_read = true;
        Ok(())
    }

    /// Next item, or `None` at END.
    pub fn next_item(&mut self) -> Result<Option<PipeItem>> {
        if self.done {
            return Ok(None);
        }
        self.ensure_header()?;
        let tag = self.read_u8()?;
        match tag {
            TAG_END => {
                self.done = true;
                Ok(None)
            }
            TAG_STR => Ok(Some(PipeItem::Str(self.read_str()?))),
            TAG_I64 => {
                let mut b = [0u8; 8];
                self.r.read_exact(&mut b).map_err(map_eof)?;
                Ok(Some(PipeItem::I64(i64::from_le_bytes(b))))
            }
            TAG_BYTES => Ok(Some(PipeItem::Bytes(self.read_len_bytes()?))),
            TAG_FILE => {
                let name = self.read_str()?;
                let content = self.read_len_bytes()?;
                Ok(Some(PipeItem::File { name, content }))
            }
            other => Err(Error::Pipe(format!("unknown pipe item tag {other}"))),
        }
    }

    /// Drain all remaining items.
    pub fn collect_items(&mut self) -> Result<Vec<PipeItem>> {
        let mut v = Vec::new();
        while let Some(item) = self.next_item()? {
            v.push(item);
        }
        Ok(v)
    }
}

fn map_eof(e: std::io::Error) -> Error {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        Error::Pipe("pipe stream truncated (child died?)".into())
    } else {
        Error::Io(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_items() -> Vec<PipeItem> {
        vec![
            PipeItem::Str("frame_000.rgb".into()),
            PipeItem::I64(-42),
            PipeItem::Bytes(vec![0, 1, 2, 255]),
            PipeItem::File { name: "scan_001.pc".into(), content: vec![9u8; 1000] },
        ]
    }

    #[test]
    fn stream_roundtrip_in_memory() {
        let items = sample_items();
        let buf = serialize_stream(&items);
        assert_eq!(deserialize_stream(&buf).unwrap(), items);
    }

    #[test]
    fn empty_stream_ok() {
        let buf = serialize_stream(&[]);
        assert!(deserialize_stream(&buf).unwrap().is_empty());
    }

    #[test]
    fn incremental_writer_matches_batch() {
        let items = sample_items();
        let mut sw = StreamWriter::new(Vec::new());
        for i in &items {
            sw.write_item(i).unwrap();
        }
        let buf = sw.finish().unwrap();
        assert_eq!(buf, serialize_stream(&items));
    }

    #[test]
    fn incremental_reader_roundtrip() {
        let items = sample_items();
        let buf = serialize_stream(&items);
        let mut sr = StreamReader::new(std::io::BufReader::new(&buf[..]));
        assert_eq!(sr.collect_items().unwrap(), items);
        // after END, keeps returning None
        assert!(sr.next_item().unwrap().is_none());
    }

    #[test]
    fn truncated_stream_is_pipe_error() {
        let items = sample_items();
        let buf = serialize_stream(&items);
        let cut = &buf[..buf.len() - 10];
        let mut sr = StreamReader::new(std::io::BufReader::new(cut));
        let res: Result<Vec<_>> = sr.collect_items();
        assert!(matches!(res, Err(Error::Pipe(_))));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = serialize_stream(&sample_items());
        buf[0] ^= 0xff;
        assert!(deserialize_stream(&buf).is_err());
        let mut sr = StreamReader::new(std::io::BufReader::new(&buf[..]));
        assert!(sr.next_item().is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut buf = serialize_stream(&sample_items());
        buf.push(7);
        assert!(deserialize_stream(&buf).is_err());
    }
}
