//! User-logic registry for the BinPipedRDD child process (paper Fig 4's
//! "User Logic" box).
//!
//! Each logic is a named transform over a stream of [`PipeItem`]s —
//! "ranges from simple tasks such as rotate the jpg file by 90 degrees …
//! to relatively complex tasks such as detecting pedestrians given the
//! binary sensor readings". Perception-backed logics are registered by
//! `perception::register_pipe_logics` so this module stays dependency-free.

use super::codec::PipeItem;
use crate::error::{Error, Result};
use crate::msg::{Image, Message, PixelFormat};
use std::collections::HashMap;
use std::sync::Arc;

/// A user-logic transform: whole-partition items in, items out.
pub type LogicFn = Arc<dyn Fn(Vec<PipeItem>) -> Result<Vec<PipeItem>> + Send + Sync>;

/// Registry of named user logics.
#[derive(Clone, Default)]
pub struct LogicRegistry {
    fns: HashMap<String, LogicFn>,
}

impl LogicRegistry {
    /// Empty registry (see [`LogicRegistry::with_builtins`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry pre-loaded with the built-in logics.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        register_builtins(&mut r);
        r
    }

    /// Register a user logic under `name`.
    pub fn register(
        &mut self,
        name: &str,
        f: impl Fn(Vec<PipeItem>) -> Result<Vec<PipeItem>> + Send + Sync + 'static,
    ) {
        self.fns.insert(name.to_string(), Arc::new(f));
    }

    /// Look up a logic by name (actionable error when missing).
    pub fn get(&self, name: &str) -> Result<LogicFn> {
        self.fns.get(name).cloned().ok_or_else(|| {
            Error::Pipe(format!(
                "unknown user logic '{name}' (known: {})",
                self.names().join(", ")
            ))
        })
    }

    /// All registered logic names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.fns.keys().cloned().collect();
        v.sort();
        v
    }
}

/// Rotate an RGB image 90° clockwise in place of its pixel buffer.
pub fn rotate90(img: &Image) -> Image {
    let (w, h) = (img.width as usize, img.height as usize);
    let bpp = img.format.bytes_per_pixel();
    let mut out = vec![0u8; img.data.len()];
    // dst(x, y) = src(y, h-1-x); dst dims are (h, w).
    for y in 0..h {
        for x in 0..w {
            let src = (y * w + x) * bpp;
            let (dx, dy) = (h - 1 - y, x);
            let dst = (dy * h + dx) * bpp;
            out[dst..dst + bpp].copy_from_slice(&img.data[src..src + bpp]);
        }
    }
    Image {
        header: img.header.clone(),
        width: img.height,
        height: img.width,
        format: img.format,
        data: out,
    }
}

/// Convert an RGB image to grayscale (luma-weighted).
pub fn grayscale(img: &Image) -> Image {
    match img.format {
        PixelFormat::Mono8 => img.clone(),
        PixelFormat::Rgb8 => {
            let data: Vec<u8> = img
                .data
                .chunks_exact(3)
                .map(|p| {
                    (0.299 * p[0] as f32 + 0.587 * p[1] as f32 + 0.114 * p[2] as f32) as u8
                })
                .collect();
            Image {
                header: img.header.clone(),
                width: img.width,
                height: img.height,
                format: PixelFormat::Mono8,
                data,
            }
        }
    }
}

fn map_image_items(
    items: Vec<PipeItem>,
    f: impl Fn(&Image) -> Image,
) -> Result<Vec<PipeItem>> {
    items
        .into_iter()
        .map(|item| match item {
            PipeItem::Bytes(b) => {
                let img = Image::decode(&b)?;
                Ok(PipeItem::Bytes(f(&img).encode()))
            }
            PipeItem::File { name, content } => {
                let img = Image::decode(&content)?;
                Ok(PipeItem::File { name, content: f(&img).encode() })
            }
            other => Ok(other), // pass through non-image items unchanged
        })
        .collect()
}

/// Register the dependency-free built-in logics.
pub fn register_builtins(r: &mut LogicRegistry) {
    // identity: bytes through untouched (pipe-overhead baseline).
    r.register("identity", Ok);

    // The paper's "rotate the jpg file by 90 degrees if needed" example.
    r.register("rotate90", |items| map_image_items(items, rotate90));

    r.register("grayscale", |items| map_image_items(items, grayscale));

    // Count bytes: emits a single I64 of total payload size (smoke logic).
    r.register("byte_count", |items| {
        let total: i64 = items
            .iter()
            .map(|i| match i {
                PipeItem::Bytes(b) => b.len() as i64,
                PipeItem::File { content, .. } => content.len() as i64,
                PipeItem::Str(s) => s.len() as i64,
                PipeItem::I64(_) => 8,
            })
            .sum();
        Ok(vec![PipeItem::I64(total)])
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup_and_error() {
        let r = LogicRegistry::with_builtins();
        assert!(r.get("identity").is_ok());
        let err = match r.get("nonsense") { Err(e) => e, Ok(_) => panic!("expected error") };
        assert!(err.to_string().contains("identity"), "error lists known logics");
    }

    #[test]
    fn rotate90_four_times_is_identity() {
        let img = Image::synthetic(6, 4, 5);
        let mut cur = img.clone();
        for _ in 0..4 {
            cur = rotate90(&cur);
        }
        assert_eq!(cur, img);
    }

    #[test]
    fn rotate90_transposes_dims() {
        let img = Image::synthetic(8, 4, 1);
        let rot = rotate90(&img);
        assert_eq!((rot.width, rot.height), (4, 8));
        rot.validate().unwrap();
    }

    #[test]
    fn rotate90_moves_corner_correctly() {
        // 2x2 RGB: pixels A B / C D → rotate cw → C A / D B
        let img = Image {
            header: Default::default(),
            width: 2,
            height: 2,
            format: PixelFormat::Rgb8,
            data: vec![
                1, 1, 1, 2, 2, 2, // A B
                3, 3, 3, 4, 4, 4, // C D
            ],
        };
        let rot = rotate90(&img);
        assert_eq!(rot.data, vec![3, 3, 3, 1, 1, 1, 4, 4, 4, 2, 2, 2]);
    }

    #[test]
    fn grayscale_output_is_mono() {
        let img = Image::synthetic(4, 4, 2);
        let g = grayscale(&img);
        assert_eq!(g.format, PixelFormat::Mono8);
        assert_eq!(g.data.len(), 16);
        g.validate().unwrap();
    }

    #[test]
    fn rotate_logic_via_registry() {
        let r = LogicRegistry::with_builtins();
        let f = r.get("rotate90").unwrap();
        let img = Image::synthetic(4, 6, 7);
        let out = f(vec![PipeItem::Bytes(img.encode())]).unwrap();
        match &out[0] {
            PipeItem::Bytes(b) => {
                let rot = Image::decode(b).unwrap();
                assert_eq!((rot.width, rot.height), (6, 4));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn byte_count_logic() {
        let r = LogicRegistry::with_builtins();
        let f = r.get("byte_count").unwrap();
        let out = f(vec![
            PipeItem::Bytes(vec![0; 10]),
            PipeItem::File { name: "x".into(), content: vec![0; 5] },
        ])
        .unwrap();
        assert_eq!(out, vec![PipeItem::I64(15)]);
    }
}
