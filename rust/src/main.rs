//! `av-simd` — the platform launcher.
//!
//! Subcommands:
//! * `worker --listen ADDR --id N [--slots S] [--artifacts DIR]` —
//!   standalone worker process (spawned by `StandaloneCluster`, or
//!   manually for multi-box); `--slots` bounds concurrent connections.
//! * `deploy --spec FILE [--launch]` — health-check (and optionally
//!   launch) a multi-host worker fleet from a `ClusterSpec` manifest.
//! * `user-logic NAME` — BinPipedRDD child mode: stream on stdin/stdout.
//! * `datagen --dir D [--bags N] [--frames F]` — synthesize a drive set.
//! * `perceive --dir D [--workers N] [--standalone]` — distributed image
//!   recognition over a bag directory (the Fig 7 workload).
//! * `scenarios [--workers N]` — distributed barrier-car matrix (Fig 1).
//! * `sweep [--workers N] [--standalone] ...` — parameterized scenario
//!   sweep (ego-speed grid × dt × seed × the Fig-1 matrix) sharded over
//!   the cluster, aggregated into a `SweepReport`.
//! * `replay --bag FILE ...` — shard a recorded drive into overlapping
//!   time slices, replay them through the perception pipeline on the
//!   cluster, aggregate a deterministic `ReplayReport`.
//! * `fuzz [--seed S] ...` — coverage-guided scenario fuzzing on the
//!   cluster: mutate scenario/controller values, shrink every failure
//!   to a minimal counterexample, publish a regression corpus;
//!   `--replay-corpus` re-executes a published corpus instead.
//! * `top --cluster-spec FILE [--watch SECS]` — live fleet telemetry:
//!   per-worker task counts, cache hit rates, bytes served, slots.
//! * `gc --store-root DIR [--keep ID,..]` — sweep a block store,
//!   deleting content-addressed objects not in the live set.
//! * `info` — registries, artifacts, config.
//!
//! `sweep`, `replay`, and `fuzz` accept `--trace FILE`: record
//! per-stage spans across the fleet and export a Chrome `trace_event`
//! JSON timeline (load via `chrome://tracing` or ui.perfetto.dev).
//! Tracing is observability-only — report bytes are identical with it
//! on or off.

use av_simd::cli::Args;
use av_simd::config::{ClusterMode, PlatformConfig};
use av_simd::engine::SimContext;
use av_simd::error::Result;
use av_simd::msg::Message;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&raw) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("av-simd: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "worker" => cmd_worker(&args),
        "deploy" => cmd_deploy(&args),
        "user-logic" => cmd_user_logic(&args),
        "datagen" => cmd_datagen(&args),
        "perceive" => cmd_perceive(&args),
        "scenarios" => cmd_scenarios(&args),
        "sweep" => cmd_sweep(&args),
        "replay" => cmd_replay(&args),
        "fuzz" => cmd_fuzz(&args),
        "top" => cmd_top(&args),
        "gc" => cmd_gc(&args),
        "info" => cmd_info(&args),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprint!("{HELP}");
            Err(av_simd::err!(Config, "unknown subcommand '{other}'"))
        }
    }
}

const HELP: &str = "\
av-simd — distributed simulation platform for autonomous driving

USAGE: av-simd <command> [flags]

COMMANDS:
  worker      --listen ADDR --id N [--slots S] [--artifacts DIR]
              serve tasks over TCP (S concurrent task slots, default 1)
  deploy      --spec FILE [--launch] [--stats]         health-check (and
              optionally launch) a multi-host fleet from a ClusterSpec
              manifest (TOML or JSON; see docs/OPERATIONS.md); --stats
              also fetches each worker's live telemetry snapshot
  user-logic  NAME                                     BinPipedRDD child mode
  datagen     --dir D [--bags N] [--frames F] [--size PX] [--seed S]
  perceive    --dir D [--workers N] [--standalone] [--base-port P]
  scenarios   [--workers N] [--ego-speed V]
  sweep       [--workers N] [--standalone] [--base-port P]
              [--cluster-spec FILE] [--shard-size N]
              [--adaptive] [--target-task-ms MS]
              [--recalibrate-drift F] [--recalibrate-window N]
              [--ego-speeds A,B,..] [--dts A,B,..] [--seeds A,B,..]
              [--jitter F] [--horizon S] [--worst K] [--record-worst DIR]
              [--checkpoint [ROOT]] [--resume]
  replay      --bag FILE [--slices N] [--warmup-ms MS] [--rate R]
              [--topics A,B,..] [--workers N] [--standalone]
              [--base-port P] [--cluster-spec FILE] [--verify]
              [--fixture-frames F] [--seed S]
              [--publish] [--store-root DIR] [--advertise HOST]
              [--speculate] [--speculate-multiplier F]
              [--speculate-min-samples N]
              [--checkpoint [ROOT]] [--resume]
              shard a recorded drive across the cluster and replay it
              through the perception pipeline; --publish ships the bag
              bytes through the engine (content-addressed blocks from a
              driver-side store) instead of requiring the path to
              resolve on every worker; --speculate re-runs straggling
              tasks on idle workers, first completion wins;
              --checkpoint persists every resolved slice into a durable
              record so --resume re-executes only what is missing
              (docs/OPERATIONS.md)
  fuzz        [--seed S] [--rounds N] [--round-size N] [--dt S]
              [--horizon S] [--max-mutations N] [--plant-cutin]
              [--workers N] [--standalone] [--base-port P]
              [--cluster-spec FILE] [--store-root DIR]
              [--checkpoint [ROOT]] [--resume]
              [--replay-corpus]
              coverage-guided scenario fuzzing: a seeded mutator perturbs
              scenario/controller values, a verdict-space coverage map
              steers mutation energy between rounds, every failing case
              is shrunk to a minimal counterexample; --store-root
              publishes the counterexamples as a content-addressed
              regression corpus (pinned by a fuzz_corpus.roots GC root
              list); --replay-corpus re-executes a published corpus and
              cross-checks every verdict byte-for-byte; --plant-cutin
              seeds the schedule with the known side-cut-in failure;
              --checkpoint/--resume make campaigns crash-resumable
              (docs/OPERATIONS.md)
  top         --cluster-spec FILE [--watch SECS]       live fleet
              telemetry: per-worker tasks done/failed, cache hit rate,
              block bytes served, slot occupancy; --watch re-renders
              every SECS seconds until interrupted
  gc          --store-root DIR [--keep ID,ID,..]       delete manifests
              not in the live set and every block only they referenced
  info        [--artifacts DIR]

  sweep/replay/fuzz also accept --trace FILE: record per-stage spans
  (queue wait, block fetch, chunk decode, perception phases, op
  execution) across the fleet and write a Chrome trace_event JSON
  timeline, plus a per-stage summary after the report
";

/// Build the execution cluster shared by `sweep`/`replay`/`fuzz`:
/// `--cluster-spec FILE` dials an externally managed (possibly
/// multi-host) fleet, `--standalone` spawns local worker processes over
/// TCP, otherwise an in-process thread pool. Returns the parsed spec
/// too (checkpoint/storage sections feed other flags).
fn make_cluster(
    args: &Args,
) -> Result<(
    Box<dyn av_simd::engine::Cluster>,
    Option<av_simd::engine::deploy::ClusterSpec>,
)> {
    use av_simd::engine::{LocalCluster, StandaloneCluster};

    let workers = args.get_usize("workers", 4)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let cluster_spec = match args.get("cluster-spec") {
        Some(p) => Some(av_simd::engine::deploy::ClusterSpec::load(std::path::Path::new(p))?),
        None => None,
    };
    let cluster: Box<dyn av_simd::engine::Cluster> = if let Some(cs) = &cluster_spec {
        // the fleet stays up after the job — see `av-simd deploy`
        Box::new(StandaloneCluster::connect(cs)?)
    } else if args.has("standalone") {
        let base_port = args.get_usize("base-port", 7077)? as u16;
        Box::new(StandaloneCluster::launch(workers, base_port, artifacts)?)
    } else {
        Box::new(LocalCluster::new(workers, av_simd::full_op_registry(), artifacts))
    };
    Ok((cluster, cluster_spec))
}

/// Resolve the durable-checkpoint configuration for `sweep`/`replay`:
/// the `--checkpoint [ROOT]` / `--resume` flags override the cluster
/// spec's `[checkpoint]` section; with neither, checkpointing is off.
fn checkpoint_config(
    args: &Args,
    cluster_spec: Option<&av_simd::engine::deploy::ClusterSpec>,
) -> Result<Option<av_simd::engine::CheckpointConfig>> {
    let from_spec = cluster_spec.and_then(|c| c.checkpoint.clone());
    let mut cfg = if args.has("checkpoint") {
        let mut c = from_spec.unwrap_or_default();
        if let Some(root) = args.get("checkpoint") {
            c.root = root.to_string();
        }
        Some(c)
    } else {
        from_spec
    };
    if args.has("resume") {
        match cfg.as_mut() {
            Some(c) => c.resume = true,
            None => {
                return Err(av_simd::err!(
                    Config,
                    "--resume needs --checkpoint (or a [checkpoint] section in the \
                     cluster spec)"
                ))
            }
        }
    }
    Ok(cfg)
}

/// Stage-span collection for one CLI job: created by [`trace_session`]
/// when `--trace FILE` is present, holding the shared [`TraceLog`] and
/// the guard that keeps it installed as the process-wide sink while the
/// job runs.
struct TraceSession {
    log: std::sync::Arc<av_simd::engine::TraceLog>,
    _guard: av_simd::engine::trace::TraceGuard,
    path: String,
}

/// Install a process-wide trace sink if `--trace FILE` was passed.
/// Must be called before the job runs and kept alive until
/// [`trace_finish`]; dropping the session uninstalls the sink.
fn trace_session(args: &Args) -> Option<TraceSession> {
    let path = args.get("trace")?.to_string();
    let log = av_simd::engine::TraceLog::new();
    let guard = av_simd::engine::trace::install(log.clone());
    Some(TraceSession { log, _guard: guard, path })
}

/// Write the Chrome `trace_event` JSON and print the per-stage summary,
/// then uninstall the sink. A `None` session (no `--trace`) is a no-op.
fn trace_finish(session: Option<TraceSession>) -> Result<()> {
    let Some(s) = session else { return Ok(()) };
    s.log.write_chrome(std::path::Path::new(&s.path))?;
    print!(
        "{}",
        av_simd::engine::trace::render_stages(&s.log.stage_totals(None))
    );
    println!("trace: {} event(s) written to {}", s.log.len(), s.path);
    Ok(())
}

fn cmd_top(args: &Args) -> Result<()> {
    use av_simd::engine::deploy;

    let path = args.require("cluster-spec")?;
    let spec = deploy::ClusterSpec::load(std::path::Path::new(path))?;
    let watch = args.get_u64("watch", 0)?;
    loop {
        println!(
            "cluster '{}' — {} worker endpoint(s)",
            spec.name,
            spec.workers.len()
        );
        let stats = deploy::probe_stats(&spec);
        print!("{}", deploy::render_stats(&stats));
        if watch == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_secs(watch.max(1)));
        println!();
    }
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    use av_simd::engine::deploy;

    let path = args.require("spec")?;
    let spec = deploy::ClusterSpec::load(std::path::Path::new(path))?;
    println!(
        "cluster '{}': {} worker endpoint(s), connect timeout {:?}",
        spec.name,
        spec.workers.len(),
        spec.connect_timeout
    );
    if args.has("launch") {
        let (children, skipped) = deploy::launch_local_workers(&spec)?;
        println!(
            "launched {} local worker(s){}",
            children.len(),
            if skipped > 0 {
                format!(" ({skipped} remote endpoint(s) must be launched on their hosts)")
            } else {
                String::new()
            }
        );
        // children are detached on purpose: the fleet outlives `deploy`
    }
    let health = deploy::probe(&spec);
    let mut down = 0usize;
    for h in &health {
        match (&h.error, h.worker_id) {
            (None, Some(id)) => println!("  {:<24} ok   worker id {id}", h.addr),
            _ => {
                down += 1;
                println!(
                    "  {:<24} DOWN {}",
                    h.addr,
                    h.error.as_deref().unwrap_or("unknown")
                );
            }
        }
    }
    if down > 0 {
        return Err(av_simd::err!(
            Engine,
            "{down}/{} worker(s) unhealthy",
            health.len()
        ));
    }
    println!("all {} worker(s) healthy", health.len());
    if args.has("stats") {
        let stats = deploy::probe_stats(&spec);
        print!("{}", deploy::render_stats(&stats));
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let listen = args.require("listen")?;
    let id = args.get_usize("id", 0)?;
    let slots = args.get_usize("slots", 1)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    av_simd::engine::worker::serve_with_slots(
        listen,
        id,
        av_simd::full_op_registry(),
        artifacts,
        slots,
    )
}

fn cmd_user_logic(args: &Args) -> Result<()> {
    let name = args
        .positional
        .first()
        .ok_or_else(|| av_simd::err!(Config, "user-logic needs a logic name"))?;
    let reg = av_simd::full_logic_registry();
    let stdin = std::io::stdin().lock();
    let stdout = std::io::stdout().lock();
    let n = av_simd::pipe::run_user_logic_stdio(&reg, name, stdin, stdout)?;
    eprintln!("user-logic {name}: processed {n} items");
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let dir = args.require("dir")?;
    let bags = args.get_usize("bags", 4)?;
    let frames = args.get_usize("frames", 50)? as u32;
    let size = args.get_usize("size", 32)? as u32;
    let seed = args.get_u64("seed", 42)?;
    let spec = av_simd::datagen::DriveSpec {
        frames,
        width: size,
        height: size,
        seed,
        ..Default::default()
    };
    let paths = av_simd::datagen::generate_drive_dir(dir, bags, &spec)?;
    let total: u64 = paths
        .iter()
        .map(|p| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
        .sum();
    println!(
        "generated {} bags ({} frames each, {}) in {dir}",
        paths.len(),
        frames,
        av_simd::util::human_bytes(total)
    );
    Ok(())
}

fn make_context(args: &Args) -> Result<SimContext> {
    let mut cfg = match args.get("config") {
        Some(p) => PlatformConfig::load(Some(std::path::Path::new(p)))?,
        None => PlatformConfig::default(),
    };
    cfg.cluster.workers = args.get_usize("workers", cfg.cluster.workers)?;
    cfg.cluster.base_port = args.get_usize("base-port", cfg.cluster.base_port as usize)? as u16;
    if args.has("standalone") {
        cfg.cluster.mode = ClusterMode::Standalone;
    }
    if let Some(a) = args.get("artifacts") {
        cfg.perception.artifact_dir = a.to_string();
    }
    SimContext::from_config(&cfg)
}

fn cmd_perceive(args: &Args) -> Result<()> {
    let dir = args.require("dir")?;
    let sc = make_context(args)?;
    let t = std::time::Instant::now();
    let detections = sc
        .bag_dir(dir, &["/camera"])?
        .take_payload()
        .op("classify_images", vec![])
        .collect()?;
    let wall = t.elapsed();
    let mut by_label = std::collections::BTreeMap::<String, usize>::new();
    for d in &detections {
        let det = av_simd::msg::DetectionArray::decode(d)?;
        for dd in det.detections {
            *by_label.entry(dd.label).or_default() += 1;
        }
    }
    println!(
        "classified {} frames in {:.2}s on {} {} workers ({:.1} frames/s)",
        detections.len(),
        wall.as_secs_f64(),
        sc.workers(),
        sc.backend(),
        detections.len() as f64 / wall.as_secs_f64()
    );
    for (label, n) in by_label {
        println!("  {label:<14} {n}");
    }
    sc.shutdown();
    Ok(())
}

fn cmd_scenarios(args: &Args) -> Result<()> {
    let ego_speed = args
        .get("ego-speed")
        .map(|v| v.parse::<f64>())
        .transpose()
        .map_err(|_| av_simd::err!(Config, "--ego-speed expects a number"))?
        .unwrap_or(12.0);
    let sc = make_context(args)?;
    let matrix = av_simd::sim::scenario_matrix(ego_speed);
    let records: Vec<Vec<u8>> = matrix.iter().map(av_simd::sim::encode_scenario).collect();
    let t = std::time::Instant::now();
    let outs = sc
        .parallelize(records, sc.workers() * 2)
        .op("run_scenario", vec![])
        .collect()?;
    let wall = t.elapsed();
    let mut passed = 0;
    let mut failed: Vec<String> = Vec::new();
    for o in &outs {
        let r = av_simd::sim::decode_result(o)?;
        if r.passed {
            passed += 1;
        } else {
            failed.push(r.scenario_id);
        }
    }
    println!(
        "scenario matrix: {}/{} passed in {:.2}s on {} workers",
        passed,
        outs.len(),
        wall.as_secs_f64(),
        sc.workers()
    );
    if !failed.is_empty() {
        failed.sort();
        println!("failed: {}", failed.join(", "));
    }
    sc.shutdown();
    Ok(())
}

fn parse_f64_list(args: &Args, name: &str, default: &[f64]) -> Result<Vec<f64>> {
    match args.get(name) {
        None => Ok(default.to_vec()),
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<std::result::Result<Vec<f64>, _>>()
            .map_err(|_| av_simd::err!(Config, "--{name} expects comma-separated numbers, got '{v}'")),
    }
}

fn parse_u64_list(args: &Args, name: &str, default: &[u64]) -> Result<Vec<u64>> {
    match args.get(name) {
        None => Ok(default.to_vec()),
        Some(v) => v
            .split(',')
            .map(|s| s.trim().parse::<u64>())
            .collect::<std::result::Result<Vec<u64>, _>>()
            .map_err(|_| av_simd::err!(Config, "--{name} expects comma-separated integers, got '{v}'")),
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use av_simd::engine::Cluster;
    use av_simd::sim::{SweepDriver, SweepSpec};

    let defaults = SweepSpec::default();
    let spec = SweepSpec {
        ego_speeds: parse_f64_list(args, "ego-speeds", &defaults.ego_speeds)?,
        dts: parse_f64_list(args, "dts", &defaults.dts)?,
        seeds: parse_u64_list(args, "seeds", &defaults.seeds)?,
        speed_jitter: match args.get("jitter") {
            None => defaults.speed_jitter,
            Some(v) => v
                .parse()
                .map_err(|_| av_simd::err!(Config, "--jitter expects a number, got '{v}'"))?,
        },
        horizon: match args.get("horizon") {
            None => defaults.horizon,
            Some(v) => v
                .parse()
                .map_err(|_| av_simd::err!(Config, "--horizon expects a number, got '{v}'"))?,
        },
        shard_size: args.get_usize("shard-size", defaults.shard_size)?,
        adaptive: if args.has("adaptive")
            || args.has("target-task-ms")
            || args.has("recalibrate-drift")
            || args.has("recalibrate-window")
        {
            let ms = args.get_u64("target-task-ms", 100)?;
            let base = av_simd::sim::AdaptiveSharding::default();
            let drift = match args.get("recalibrate-drift") {
                None => base.drift_threshold,
                Some(v) => v.parse().map_err(|_| {
                    av_simd::err!(Config, "--recalibrate-drift expects a number, got '{v}'")
                })?,
            };
            Some(av_simd::sim::AdaptiveSharding {
                target_task: std::time::Duration::from_millis(ms.max(1)),
                drift_threshold: drift,
                recalibration_window: args
                    .get_usize("recalibrate-window", base.recalibration_window)?,
                ..base
            })
        } else {
            None
        },
        worst_k: args.get_usize("worst", defaults.worst_k)?,
        ..defaults
    };

    let (cluster, cluster_spec) = make_cluster(args)?;
    let trace = trace_session(args);
    let driver = SweepDriver::new(spec);
    println!(
        "sweep: {} cases in {} shards on {} {} workers",
        driver.spec().case_count(),
        driver.spec().shards().len(),
        cluster.workers(),
        cluster.backend()
    );
    let report = match checkpoint_config(args, cluster_spec.as_ref())? {
        Some(cfg) => {
            println!(
                "checkpointing into {} (every {} shard(s), resume: {})",
                cfg.root, cfg.every, cfg.resume
            );
            driver.run_checkpointed(cluster.as_ref(), &cfg)?
        }
        None => driver.run(cluster.as_ref())?,
    };
    print!("{}", report.render());
    trace_finish(trace)?;
    if let Some(dir) = args.get("record-worst") {
        let paths = driver.record_worst(&report, dir)?;
        for p in paths {
            println!("recorded {p}");
        }
    }
    cluster.shutdown();
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    use av_simd::engine::Cluster;
    use av_simd::sim::{ReplayDriver, ReplaySpec};

    let bag = args.require("bag")?.to_string();
    // --fixture-frames N: synthesize a deterministic datagen drive at
    // --bag first (demos and smoke tests need no recorded data)
    if args.has("fixture-frames") {
        let frames = args.get_usize("fixture-frames", 20)? as u32;
        let seed = args.get_u64("seed", 42)?;
        av_simd::sim::replay::write_fixture_bag(&bag, frames, seed)?;
        println!("wrote fixture bag {bag} ({frames} frames, seed {seed})");
    }

    let defaults = ReplaySpec::default();
    let spec = ReplaySpec {
        bag,
        topics: match args.get("topics") {
            None => Vec::new(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        },
        slices: args.get_usize("slices", defaults.slices)?,
        warmup: std::time::Duration::from_millis(
            args.get_u64("warmup-ms", defaults.warmup.as_millis() as u64)?,
        ),
        rate: match args.get("rate") {
            None => defaults.rate,
            Some(v) => v
                .parse()
                .map_err(|_| av_simd::err!(Config, "--rate expects a number, got '{v}'"))?,
        },
        ..defaults
    };

    let artifacts = args.get_or("artifacts", "artifacts");
    let (cluster, cluster_spec) = make_cluster(args)?;
    let trace = trace_session(args);

    // speculation: CLI flags, else the cluster spec's [speculation]
    // section; the CLI fully overrides the manifest when any flag is set
    let speculation = if args.has("speculate")
        || args.has("speculate-multiplier")
        || args.has("speculate-min-samples")
    {
        let base = av_simd::engine::Speculation::on();
        let multiplier = match args.get("speculate-multiplier") {
            None => base.multiplier,
            Some(v) => {
                let m: f64 = v.parse().map_err(|_| {
                    av_simd::err!(Config, "--speculate-multiplier expects a number, got '{v}'")
                })?;
                if !(m.is_finite() && m > 0.0) {
                    return Err(av_simd::err!(
                        Config,
                        "--speculate-multiplier must be positive, got '{v}'"
                    ));
                }
                m
            }
        };
        av_simd::engine::Speculation {
            multiplier,
            min_samples: args.get_usize("speculate-min-samples", base.min_samples)?,
            ..base
        }
    } else {
        cluster_spec
            .as_ref()
            .and_then(|c| c.speculation)
            .unwrap_or_default()
    };

    let mut driver = ReplayDriver::new(spec).with_speculation(speculation);
    if args.has("publish") || args.has("store-root") {
        // resolution order: flag, then the cluster spec's [storage]
        // section, then a local default
        let store_root = args
            .get("store-root")
            .map(str::to_string)
            .or_else(|| cluster_spec.as_ref().and_then(|c| c.store_root.clone()))
            .unwrap_or_else(|| "blockstore".to_string());
        let advertise = args
            .get("advertise")
            .map(str::to_string)
            .or_else(|| cluster_spec.as_ref().and_then(|c| c.advertise_host.clone()))
            .unwrap_or_else(|| "127.0.0.1".to_string());
        let id = driver.publish(&store_root, &advertise)?;
        let (_, peer) = driver.published().expect("just published");
        println!(
            "published bag as manifest {} (store {store_root}, blocks served at {peer})",
            id.short()
        );
    }
    let (index, slices) = driver.plan()?;
    println!(
        "replay: {} messages / {} topics over {:.2} bag-s in {} slice(s) on {} {} \
         workers (warm-up {:?})",
        index.messages,
        index.topics.len(),
        index
            .time_range()
            .map(|(a, b)| (b.nanos - a.nanos) as f64 / 1e9)
            .unwrap_or(0.0),
        slices.len(),
        cluster.workers(),
        cluster.backend(),
        driver.effective_warmup(&index),
    );
    let report = match checkpoint_config(args, cluster_spec.as_ref())? {
        Some(cfg) => {
            println!(
                "checkpointing into {} (every {} slice(s), resume: {})",
                cfg.root, cfg.every, cfg.resume
            );
            driver.run_planned_checkpointed(cluster.as_ref(), &index, &slices, &cfg)?
        }
        None => driver.run_planned(cluster.as_ref(), &index, &slices)?,
    };
    print!("{}", report.render());
    // finish (and uninstall) the trace before --verify: the reference
    // execution is a correctness check, not part of the job timeline
    trace_finish(trace)?;
    if args.has("verify") {
        let reference = driver.reference(artifacts)?;
        if reference.encode() == report.encode() {
            println!("verify: distributed report byte-equal to single-process reference");
        } else {
            cluster.shutdown();
            return Err(av_simd::err!(
                Sim,
                "verify FAILED: distributed report differs from the single-process \
                 reference"
            ));
        }
    }
    cluster.shutdown();
    Ok(())
}

fn cmd_fuzz(args: &Args) -> Result<()> {
    use av_simd::engine::Cluster;
    use av_simd::sim::fuzz::{FuzzDriver, FuzzSpec};

    let (cluster, cluster_spec) = make_cluster(args)?;
    let trace = trace_session(args);

    // --replay-corpus: re-execute a published regression corpus and
    // cross-check every verdict against the recorded one, byte-for-byte
    if args.has("replay-corpus") {
        let store_root = args
            .get("store-root")
            .map(str::to_string)
            .or_else(|| cluster_spec.as_ref().and_then(|c| c.store_root.clone()))
            .ok_or_else(|| {
                av_simd::err!(Config, "--replay-corpus needs --store-root DIR")
            })?;
        let report = av_simd::sim::run_corpus_replay(cluster.as_ref(), &store_root)?;
        print!("{}", report.render());
        trace_finish(trace)?;
        cluster.shutdown();
        if report.mismatches() > 0 {
            return Err(av_simd::err!(
                Sim,
                "{} corpus entr(y/ies) no longer reproduce their recorded verdict",
                report.mismatches()
            ));
        }
        return Ok(());
    }

    let defaults = FuzzSpec::default();
    let spec = FuzzSpec {
        seed: args.get_u64("seed", defaults.seed)?,
        rounds: args.get_usize("rounds", defaults.rounds as usize)? as u32,
        round_size: args.get_usize("round-size", defaults.round_size as usize)? as u32,
        dt: match args.get("dt") {
            None => defaults.dt,
            Some(v) => v
                .parse()
                .map_err(|_| av_simd::err!(Config, "--dt expects a number, got '{v}'"))?,
        },
        horizon: match args.get("horizon") {
            None => defaults.horizon,
            Some(v) => v
                .parse()
                .map_err(|_| av_simd::err!(Config, "--horizon expects a number, got '{v}'"))?,
        },
        max_mutations: args.get_usize("max-mutations", defaults.max_mutations as usize)? as u8,
        planted: if args.has("plant-cutin") {
            vec![av_simd::sim::fuzz::cutin_regression_case()]
        } else {
            Vec::new()
        },
        ..defaults
    };

    let driver = FuzzDriver::new(spec);
    println!(
        "fuzz: seed {} — {} rounds x {} cases on {} {} workers",
        driver.spec().seed,
        driver.spec().rounds,
        driver.spec().round_size,
        cluster.workers(),
        cluster.backend()
    );
    let report = match checkpoint_config(args, cluster_spec.as_ref())? {
        Some(cfg) => {
            println!(
                "checkpointing into {} (every {} case(s), resume: {})",
                cfg.root, cfg.every, cfg.resume
            );
            driver.run_checkpointed(cluster.as_ref(), &cfg)?
        }
        None => driver.run(cluster.as_ref())?,
    };
    print!("{}", report.render());
    trace_finish(trace)?;
    if let Some(store_root) = args.get("store-root") {
        let ids = driver.publish_corpus(&report, store_root)?;
        println!(
            "published {} corpus entr{} into {store_root} (index {})",
            ids.len(),
            if ids.len() == 1 { "y" } else { "ies" },
            av_simd::sim::fuzz::CORPUS_INDEX
        );
    }
    cluster.shutdown();
    Ok(())
}

fn cmd_gc(args: &Args) -> Result<()> {
    use av_simd::storage::{BlockStore, ManifestId};

    let root = args.require("store-root")?;
    let live: Vec<ManifestId> = match args.get("keep") {
        None => Vec::new(),
        Some(v) => v
            .split(',')
            .map(|s| ManifestId::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?,
    };
    let store = BlockStore::open(root)?;
    let stats = store.gc(&live)?;
    println!(
        "gc {root}: deleted {} manifest(s) and {} block(s) ({} reclaimed), kept {} \
         manifest(s)",
        stats.manifests_deleted,
        stats.blocks_deleted,
        av_simd::util::human_bytes(stats.bytes_reclaimed),
        stats.manifests_kept
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    println!("operators:");
    for op in av_simd::full_op_registry().names() {
        println!("  {op}");
    }
    println!("user logics:");
    for l in av_simd::full_logic_registry().names() {
        println!("  {l}");
    }
    match av_simd::runtime::Manifest::load(
        std::path::Path::new(artifacts).join("manifest.txt").as_path(),
    ) {
        Ok(m) => {
            println!("artifacts ({artifacts}):");
            for name in m.names() {
                let sig = m.get(&name).unwrap();
                println!("  {name}: {:?} -> {:?}", sig.in_dims, sig.out_dims);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
