//! The engine's data plane: content-addressed task inputs shipped over
//! RPC instead of resolved from worker-local paths.
//!
//! The paper's platform distributes simulation data *to* the compute
//! nodes (Spark + an HDFS-like storage tier); nothing assumes a shared
//! filesystem. This module closes that gap for our engine: a task names
//! its bag input with a [`DataRef`] — either a worker-local `Path`
//! (back-compat; single box or genuinely shared storage) or a
//! `Manifest` (a `storage::ManifestId` plus an ordered list of *block
//! peers* that serve the bytes). Workers resolve manifests through
//! their [`DataPlane`]: an LRU byte cache (shared across all `--slots`
//! connections of a worker process) backed by [`BlockClient`] fetches
//! of individual content-addressed blocks over the
//! [`super::rpc`] framing. Every transfer is verified: the manifest
//! must hash to its id, and every block must hash to its address — a
//! lying or corrupted peer is detected at fetch time, never replayed.
//!
//! The serving side is [`BlockServer`], which answers
//! `FetchManifest`/`FetchBlock` from any [`BlockSource`]. The driver
//! publishes a bag into a `storage::BlockStore` (`publish_bag` →
//! manifest id) and serves from disk; *workers* additionally serve
//! their own `DataPlane` cache ([`BlockServer::serve_source`]), turning
//! distribution into a swarm: a cold worker's peer list names warm
//! sibling workers first and the driver last, so fetch bandwidth scales
//! with the fleet and the driver stops being a single point of failure
//! for data already replicated into worker caches. Peer failures fall
//! back to the next peer in the list — hash verification makes any
//! peer, sibling or driver, equally untrusted.

use crate::bag::BagCache;
use crate::engine::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use crate::error::{Error, Result};
use crate::storage::{
    hex32, verify_block, BlockChunkStore, BlockStore, Manifest, ManifestId,
};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Where a task's bag bytes come from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataRef {
    /// A filesystem path resolvable on the executing worker (the
    /// original model: single box, or storage genuinely mounted
    /// everywhere).
    Path(String),
    /// A content-addressed object: fetch the manifest and its blocks
    /// from the first reachable entry of `peers` and verify everything
    /// against `id`. The bytes are identical on every worker by
    /// construction, no matter which peer served them.
    Manifest {
        /// Content address of the published object.
        id: ManifestId,
        /// Ordered fetch sources (`host:port` each): warm sibling
        /// workers first, the driver's [`BlockServer`] last. A worker
        /// advances to the next peer on any connect or fetch failure.
        peers: Vec<String>,
    },
}

impl DataRef {
    /// Convenience constructor for the back-compat path form.
    pub fn path(p: impl Into<String>) -> Self {
        DataRef::Path(p.into())
    }

    /// Convenience constructor for a manifest ref served by one peer
    /// (the common driver-only case).
    pub fn manifest(id: ManifestId, peer: impl Into<String>) -> Self {
        DataRef::Manifest { id, peers: vec![peer.into()] }
    }

    /// Plan-time validation: malformed refs fail when the task is
    /// built/decoded, not deep inside a worker's bag open.
    pub fn validate(&self) -> Result<()> {
        match self {
            DataRef::Path(p) if p.is_empty() => {
                Err(Error::Engine("data ref: empty bag path".into()))
            }
            DataRef::Manifest { peers, .. } if peers.is_empty() => {
                Err(Error::Engine("data ref: empty block peer list".into()))
            }
            DataRef::Manifest { peers, .. } => {
                for peer in peers {
                    if peer.is_empty() || !peer.contains(':') {
                        return Err(Error::Engine(format!(
                            "data ref: block peer '{peer}' is not host:port"
                        )));
                    }
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Short description for logs / `Source::describe`.
    pub fn describe(&self) -> String {
        match self {
            DataRef::Path(p) => p.clone(),
            DataRef::Manifest { id, peers } => {
                let first = peers.first().map(String::as_str).unwrap_or("?");
                match peers.len() {
                    0 | 1 => format!("mf:{}@{first}", id.short()),
                    n => format!("mf:{}@{first}(+{} peer(s))", id.short(), n - 1),
                }
            }
        }
    }

    /// Serialize into a task-spec payload.
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            DataRef::Path(p) => {
                w.put_u8(0);
                w.put_str(p);
            }
            DataRef::Manifest { id, peers } => {
                w.put_u8(1);
                w.put_raw(&id.0);
                w.put_varint(peers.len() as u64);
                for peer in peers {
                    w.put_str(peer);
                }
            }
        }
    }

    /// Decode a [`DataRef::encode_into`] payload (validated).
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        let d = match r.get_u8()? {
            0 => DataRef::Path(r.get_str()?),
            1 => {
                let id: [u8; 32] = r.get_raw(32)?.try_into().unwrap();
                let n = r.get_varint()? as usize;
                let mut peers = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    peers.push(r.get_str()?);
                }
                DataRef::Manifest { id: ManifestId(id), peers }
            }
            other => {
                return Err(Error::Engine(format!("unknown data ref tag {other}")))
            }
        };
        d.validate()?;
        Ok(d)
    }
}

// ---------------------------------------------------------------------
// swarm registry
// ---------------------------------------------------------------------

/// Driver-side bookkeeping of which worker block-servers hold which
/// manifests, fed by [`super::rpc::RpcMsg::BlockAd`] frames piggybacked
/// on task replies. The scheduler consults it when building a task's
/// [`DataRef::Manifest`] peer list: warm sibling workers first, the
/// driver last. The registry is best-effort by design — a stale entry
/// (worker died, cache evicted) just costs the requester one failed
/// peer before it falls back, so advertisements never need to be acked
/// or expired.
#[derive(Clone, Default)]
pub struct SwarmRegistry {
    inner: Arc<std::sync::Mutex<HashMap<[u8; 32], Vec<String>>>>,
}

impl SwarmRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `peer`'s full advertised set: `peer` is removed from
    /// manifests it no longer advertises (eviction) and appended — in
    /// advertisement order, deduplicated — to each manifest it does.
    pub fn advertise(&self, peer: &str, manifests: &[[u8; 32]]) {
        let mut g = self.inner.lock().unwrap();
        for peers in g.values_mut() {
            peers.retain(|p| p != peer);
        }
        for id in manifests {
            let peers = g.entry(*id).or_default();
            if !peers.iter().any(|p| p == peer) {
                peers.push(peer.to_string());
            }
        }
        g.retain(|_, v| !v.is_empty());
    }

    /// Worker peers currently advertising `id`, in first-advertised
    /// order (the driver's own server is *not* in here — callers append
    /// it last as the authoritative fallback).
    pub fn peers_for(&self, id: &ManifestId) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .get(&id.0)
            .cloned()
            .unwrap_or_default()
    }

    /// Drop `peer` from every manifest it advertises — called when the
    /// worker's connection detaches with a transport death, so cold
    /// fetchers stop burning a connect-timeout on the corpse before
    /// falling back to the driver.
    pub fn evict(&self, peer: &str) {
        let mut g = self.inner.lock().unwrap();
        for peers in g.values_mut() {
            peers.retain(|p| p != peer);
        }
        g.retain(|_, v| !v.is_empty());
    }

    /// Number of manifests with at least one advertising peer.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when no peer has advertised anything.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------
// client
// ---------------------------------------------------------------------

/// RPC client for a block peer: fetches manifests and blocks with
/// end-to-end hash verification. Every error names the peer's
/// `host:port` and — for block fetches — the manifest id and block
/// index, mirroring the deploy layer's connect-error convention. All
/// fetch failures are `Error::Engine` (retryable): a worker that loses
/// its block peer mid-slice fails the *task*, which the scheduler may
/// re-run elsewhere.
pub struct BlockClient {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    /// The `host:port` this client dialed.
    pub peer: String,
}

impl BlockClient {
    /// Connect to a block peer, retrying with capped backoff until
    /// `timeout`, then verify the RPC version via the `Hello`
    /// handshake. Errors name the peer and the attempt count.
    pub fn connect(peer: &str, timeout: Duration) -> Result<Self> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = Duration::from_millis(1);
        let mut attempts = 0usize;
        let stream = loop {
            attempts += 1;
            match TcpStream::connect(peer) {
                Ok(s) => break s,
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::Engine(format!(
                            "block peer {peer} not reachable after {attempts} \
                             connect attempt(s) over {timeout:?}: {e}"
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(50));
                }
            }
        };
        stream.set_nodelay(true).ok();
        // Bound the handshake by the remaining budget (a wedged peer
        // must not hang the fetch forever).
        let remaining = deadline
            .saturating_duration_since(std::time::Instant::now())
            .max(Duration::from_millis(1));
        stream.set_read_timeout(Some(remaining)).ok();
        let mut c = Self {
            reader: std::io::BufReader::new(stream.try_clone()?),
            writer: std::io::BufWriter::new(stream),
            peer: peer.to_string(),
        };
        write_msg(&mut c.writer, &RpcMsg::Hello { version: RPC_VERSION })
            .map_err(|e| c.ctx_err("handshake", &e))?;
        match read_msg(&mut c.reader).map_err(|e| c.ctx_err("handshake", &e))? {
            Some(RpcMsg::HelloOk { version, .. }) if version == RPC_VERSION => {}
            Some(RpcMsg::HelloOk { version, .. }) => {
                return Err(Error::Engine(format!(
                    "block peer {peer} speaks rpc v{version} but this build needs \
                     v{RPC_VERSION} — redeploy"
                )));
            }
            other => {
                return Err(Error::Engine(format!(
                    "block peer {peer} answered handshake with {other:?}"
                )))
            }
        }
        // After the handshake, reads keep a *generous* cap instead of
        // none at all: a loaded peer may be slow, but a peer that stalls
        // mid-fetch (paused process, silent partition) must surface as a
        // retryable task error, not hang the worker's task thread
        // forever — the module's failure contract only holds if every
        // read eventually returns.
        c.reader
            .get_ref()
            .set_read_timeout(Some(BLOCK_READ_TIMEOUT))
            .ok();
        Ok(c)
    }

    fn ctx_err(&self, what: &str, e: &dyn std::fmt::Display) -> Error {
        Error::Engine(format!("{what} from block peer {}: {e}", self.peer))
    }

    /// Fetch and verify the manifest for `id`: the returned manifest's
    /// encoded bytes hash to `id`, so every block length and address in
    /// it is authenticated.
    pub fn fetch_manifest(&mut self, id: &ManifestId) -> Result<Manifest> {
        let what = format!("manifest {}", id.short());
        write_msg(&mut self.writer, &RpcMsg::FetchManifest { id: id.0 })
            .map_err(|e| self.ctx_err(&what, &e))?;
        let bytes = match read_msg(&mut self.reader).map_err(|e| self.ctx_err(&what, &e))? {
            Some(RpcMsg::ManifestData(b)) => b,
            Some(RpcMsg::FetchErr(m)) => return Err(self.ctx_err(&what, &m)),
            None => return Err(self.ctx_err(&what, &"peer hung up mid-fetch")),
            other => {
                return Err(self.ctx_err(&what, &format!("unexpected reply {other:?}")))
            }
        };
        if crate::util::sha256::digest(&bytes) != id.0 {
            return Err(self.ctx_err(
                &what,
                &"manifest bytes do not hash to the requested id",
            ));
        }
        Manifest::decode(&bytes).map_err(|e| self.ctx_err(&what, &e))
    }

    /// Fetch block `index` of `manifest` (whose id is `id`) and verify
    /// it against the manifest's `BlockRef`. Failures name the manifest
    /// id, block index, and this peer's `host:port`.
    pub fn fetch_block(
        &mut self,
        id: &ManifestId,
        index: u32,
        manifest: &Manifest,
    ) -> Result<Vec<u8>> {
        let what = format!("block {index} of manifest {}", id.short());
        let bref = manifest.blocks.get(index as usize).ok_or_else(|| {
            self.ctx_err(
                &what,
                &format!("manifest has only {} block(s)", manifest.blocks.len()),
            )
        })?;
        write_msg(
            &mut self.writer,
            &RpcMsg::FetchBlock { manifest: id.0, index },
        )
        .map_err(|e| self.ctx_err(&what, &e))?;
        let bytes = match read_msg(&mut self.reader).map_err(|e| self.ctx_err(&what, &e))? {
            Some(RpcMsg::BlockData(b)) => b,
            Some(RpcMsg::FetchErr(m)) => return Err(self.ctx_err(&what, &m)),
            None => return Err(self.ctx_err(&what, &"peer hung up mid-fetch")),
            other => {
                return Err(self.ctx_err(&what, &format!("unexpected reply {other:?}")))
            }
        };
        verify_block(&bytes, bref, manifest.block_offset(index as usize))
            .map_err(|e| self.ctx_err(&what, &e))?;
        Ok(bytes)
    }
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// Per-read socket cap on block fetches after the connect handshake
/// (ample for a 4 MiB block on any sane link; a peer that cannot move
/// one block in this long is treated as lost and the task retried).
const BLOCK_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Worker id a [`BlockServer`] reports in its `HelloOk` (distinguishes
/// block peers from task workers in probes and logs).
pub const BLOCK_PEER_ID: u64 = u64::MAX;

/// Anything a [`BlockServer`] can serve manifests and blocks from.
///
/// Two implementations exist: [`BlockStore`] (the driver's on-disk
/// store — the authoritative copy) and [`DataPlane`] (a worker's LRU
/// byte cache — best-effort swarm serving). A source is allowed to
/// *stop* having an object (cache eviction): it returns an error, the
/// server answers `FetchErr`, and the requester falls back to its next
/// peer. Requesters hash-verify everything, so a source never needs to
/// be trusted, only reachable.
pub trait BlockSource: Send + Sync {
    /// The encoded manifest bytes for `id` (must hash to `id`).
    fn manifest_bytes(&self, id: &ManifestId) -> Result<Vec<u8>>;
    /// The raw bytes of block `index` of `manifest` (id `id`).
    fn block_bytes(
        &self,
        id: &ManifestId,
        manifest: &Manifest,
        index: u32,
    ) -> Result<Vec<u8>>;
}

impl BlockSource for BlockStore {
    fn manifest_bytes(&self, id: &ManifestId) -> Result<Vec<u8>> {
        BlockStore::manifest_bytes(self, id)
    }

    fn block_bytes(
        &self,
        id: &ManifestId,
        manifest: &Manifest,
        index: u32,
    ) -> Result<Vec<u8>> {
        let bref = manifest.blocks.get(index as usize).ok_or_else(|| {
            Error::Storage(format!(
                "manifest {} has {} block(s), index {index} out of range",
                id.short(),
                manifest.blocks.len()
            ))
        })?;
        self.read_block(bref, manifest.block_offset(index as usize))
    }
}

impl BlockSource for DataPlane {
    /// Cache-resident manifests only — a miss (never fetched, or
    /// evicted) is an error, which the server relays as `FetchErr` and
    /// the requester survives by falling back to its next peer.
    fn manifest_bytes(&self, id: &ManifestId) -> Result<Vec<u8>> {
        self.cache
            .get(&format!("mf:{}", id.hex()))
            .map(|a| a.as_ref().clone())
            .ok_or_else(|| {
                Error::Storage(format!(
                    "manifest {} not resident in this worker's cache",
                    id.short()
                ))
            })
    }

    fn block_bytes(
        &self,
        id: &ManifestId,
        manifest: &Manifest,
        index: u32,
    ) -> Result<Vec<u8>> {
        let bref = manifest.blocks.get(index as usize).ok_or_else(|| {
            Error::Storage(format!(
                "manifest {} has {} block(s), index {index} out of range",
                id.short(),
                manifest.blocks.len()
            ))
        })?;
        self.cache
            .get(&format!("blk:{}", hex32(&bref.id)))
            .map(|a| a.as_ref().clone())
            .ok_or_else(|| {
                Error::Storage(format!(
                    "block {index} of manifest {} evicted from this worker's cache",
                    id.short()
                ))
            })
    }
}

/// A block peer: serves `FetchManifest`/`FetchBlock` requests from a
/// [`BlockSource`] over the engine's RPC framing. The driver runs one
/// over its [`BlockStore`] next to each job that ships data by
/// manifest; every worker runs one over its [`DataPlane`] cache (the
/// swarm); requesters dial either with [`BlockClient`]. Serving is
/// read-only and every block served from disk is verified before it
/// leaves (local corruption is reported to the requester, not silently
/// forwarded).
pub struct BlockServer {
    peer: String,
    wake_addr: String,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl BlockServer {
    /// Bind `listen` (e.g. `"0.0.0.0:0"` for any port) and serve
    /// `store` until [`BlockServer::stop`] / drop. `advertise_host` is
    /// the hostname workers should dial (combined with the actually
    /// bound port to form [`BlockServer::peer`]); pass `"127.0.0.1"`
    /// for single-box runs, the driver's reachable address for fleets.
    pub fn serve(
        store: Arc<BlockStore>,
        listen: &str,
        advertise_host: &str,
    ) -> Result<Self> {
        Self::serve_source(store, listen, advertise_host)
    }

    /// [`BlockServer::serve`] generalized to any [`BlockSource`] —
    /// notably a worker's [`DataPlane`] cache, which is how a worker
    /// joins the swarm as a fetch source for the data it holds.
    pub fn serve_source(
        source: Arc<dyn BlockSource>,
        listen: &str,
        advertise_host: &str,
    ) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::Engine(format!("block server bind {listen}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Engine(format!("block server local_addr: {e}")))?;
        let peer = format!("{advertise_host}:{}", local.port());
        let wake_addr = if local.ip().is_unspecified() {
            match local.ip() {
                std::net::IpAddr::V4(_) => format!("127.0.0.1:{}", local.port()),
                std::net::IpAddr::V6(_) => format!("[::1]:{}", local.port()),
            }
        } else {
            local.to_string()
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name(format!("av-simd-block-server-{}", local.port()))
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let source = source.clone();
                    // Handlers are detached: they exit when the client
                    // disconnects, and hold no listener resources.
                    let _ = std::thread::Builder::new()
                        .name("av-simd-block-conn".into())
                        .spawn(move || {
                            if let Err(e) = serve_block_conn(stream, source.as_ref()) {
                                crate::logmsg!("warn", "block server connection: {e}");
                            }
                        });
                }
            })
            .map_err(|e| Error::Engine(format!("spawn block server thread: {e}")))?;
        crate::logmsg!("info", "block server serving on {peer}");
        Ok(Self { peer, wake_addr, shutdown, accept_handle: Some(accept_handle) })
    }

    /// The `host:port` workers should dial (advertised host + bound
    /// port) — what goes into [`DataRef::Manifest`].
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// Stop accepting connections and release the port. In-flight
    /// connections finish on their own threads.
    pub fn stop(&mut self) {
        if let Some(h) = self.accept_handle.take() {
            self.shutdown.store(true, Ordering::SeqCst);
            // wake the accept loop so it observes the flag (a failed
            // dial means the loop already exited)
            let _ = TcpStream::connect(&self.wake_addr);
            let _ = h.join();
        }
    }
}

impl Drop for BlockServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One block-server connection: answer fetches until the client hangs
/// up. Manifests are cached per connection so a client streaming every
/// block of one object costs one manifest load, not N.
fn serve_block_conn(stream: TcpStream, source: &dyn BlockSource) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut manifests: HashMap<[u8; 32], Manifest> = HashMap::new();
    loop {
        match read_msg(&mut reader)? {
            None => return Ok(()),
            Some(RpcMsg::Ping) => write_msg(&mut writer, &RpcMsg::Pong)?,
            Some(RpcMsg::Hello { version: _ }) => write_msg(
                &mut writer,
                &RpcMsg::HelloOk {
                    version: RPC_VERSION,
                    worker_id: BLOCK_PEER_ID,
                    now_ns: crate::util::mono_nanos(),
                },
            )?,
            Some(RpcMsg::Shutdown) => return Ok(()),
            Some(RpcMsg::FetchManifest { id }) => {
                let reply = match source.manifest_bytes(&ManifestId(id)) {
                    Ok(bytes) => match Manifest::decode(&bytes) {
                        Ok(m) => {
                            manifests.insert(id, m);
                            RpcMsg::ManifestData(bytes)
                        }
                        Err(e) => RpcMsg::FetchErr(e.to_string()),
                    },
                    Err(e) => RpcMsg::FetchErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(RpcMsg::FetchBlock { manifest, index }) => {
                let reply = match fetch_block_reply(source, &mut manifests, manifest, index)
                {
                    Ok(bytes) => {
                        crate::metrics::Metrics::global()
                            .counter("block_bytes_served")
                            .add(bytes.len() as u64);
                        RpcMsg::BlockData(bytes)
                    }
                    Err(e) => RpcMsg::FetchErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(other) => {
                return Err(Error::Engine(format!(
                    "block server received unexpected message {other:?}"
                )))
            }
        }
    }
}

/// Resolve one `FetchBlock` request against the source (loading the
/// manifest through the per-connection cache). The decoded manifest is
/// pinned per connection, so a cache source can keep answering block
/// fetches it still holds even after its own `mf:` entry was evicted.
fn fetch_block_reply(
    source: &dyn BlockSource,
    manifests: &mut HashMap<[u8; 32], Manifest>,
    manifest_id: [u8; 32],
    index: u32,
) -> Result<Vec<u8>> {
    let id = ManifestId(manifest_id);
    let m = match manifests.entry(manifest_id) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
        std::collections::hash_map::Entry::Vacant(v) => {
            let bytes = source.manifest_bytes(&id)?;
            v.insert(Manifest::decode(&bytes)?)
        }
    };
    source.block_bytes(&id, m, index)
}

// ---------------------------------------------------------------------
// worker-side data plane
// ---------------------------------------------------------------------

/// The worker's view of the data plane: resolves [`DataRef`]s into
/// playable block stores through one LRU byte cache. The cache replaces
/// the old path-keyed bag cache and is shared by all `--slots`
/// connections of a worker process (every [`super::ops::TaskCtx`] clone
/// shares it), holding three kinds of entries:
///
/// * `path:<p>` — whole bag files read from a worker-local path;
/// * `mf:<hex>` — verified manifest bytes;
/// * `blk:<hex>` — verified blocks, keyed by content address, so two
///   manifests sharing blocks dedupe in RAM and eviction is per-block.
///
/// Resolution is zero-copy on hits: cached entries are `Arc`-shared
/// into the returned [`BlockChunkStore`] (the old path cache copied the
/// whole bag into a fresh buffer on every open).
#[derive(Clone)]
pub struct DataPlane {
    cache: BagCache,
    fetch_timeout: Duration,
    /// Per-manifest single-flight locks: concurrent first opens of the
    /// same manifest (a multi-slot worker receiving several slices of a
    /// just-published bag at once) serialize, so a cold bag crosses the
    /// wire once per worker process — the followers find every block
    /// cached. Entries are bounded by the number of distinct manifests
    /// this worker has ever resolved (tiny).
    inflight: Arc<std::sync::Mutex<HashMap<String, Arc<std::sync::Mutex<()>>>>>,
    /// Injected-failure schedule (block-read corruption); inert unless
    /// set via [`DataPlane::with_faults`].
    faults: super::fault::FaultPlan,
}

impl DataPlane {
    /// Data plane with an LRU byte budget of `capacity_bytes`. The
    /// default fetch-connect budget is short (2 s): unlike task
    /// workers, a block peer is up *before* any task referencing it is
    /// dispatched, so an unreachable peer should fail the task quickly
    /// and let the scheduler's retry policy take over.
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            cache: BagCache::new(capacity_bytes),
            fetch_timeout: Duration::from_secs(2),
            inflight: Arc::new(std::sync::Mutex::new(HashMap::new())),
            faults: super::fault::FaultPlan::none(),
        }
    }

    /// Override the per-resolution connect budget; builder-style.
    pub fn with_fetch_timeout(mut self, t: Duration) -> Self {
        self.fetch_timeout = t;
        self
    }

    /// Test-only builder: flip a byte in the next scheduled remote block
    /// fetches (per the plan's corruption budget) *before* verification,
    /// so the content-hash check and the retry path that recovers from a
    /// bad peer are exercised with real corrupt bytes.
    pub fn with_faults(mut self, faults: super::fault::FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// The underlying byte cache (stats, direct seeding in tests).
    pub fn cache(&self) -> &BagCache {
        &self.cache
    }

    /// Resolve a data ref into a playable store. `Path` refs read
    /// through the cache from the local filesystem; `Manifest` refs
    /// fetch any missing manifest/blocks from the ref's peers (in
    /// order, falling back on per-peer failure), verify them, and cache
    /// them by content address.
    pub fn open(&self, data: &DataRef) -> Result<BlockChunkStore> {
        data.validate()?;
        match data {
            DataRef::Path(p) => self.open_path(p),
            DataRef::Manifest { id, peers } => self.open_manifest(id, peers),
        }
    }

    /// Manifest ids fully resident in the cache (manifest bytes *and*
    /// every block), sorted by hex id. This is what a worker advertises
    /// to the driver as its swarm-servable set.
    pub fn resident_manifests(&self) -> Vec<ManifestId> {
        let mut out = Vec::new();
        for key in self.cache.keys_with_prefix("mf:") {
            let Ok(id) = ManifestId::parse(&key["mf:".len()..]) else { continue };
            let Some(bytes) = self.cache.get(&key) else { continue };
            let Ok(m) = Manifest::decode(&bytes) else { continue };
            if m.blocks
                .iter()
                .all(|b| self.cache.contains(&format!("blk:{}", hex32(&b.id))))
            {
                out.push(id);
            }
        }
        out
    }

    fn open_path(&self, path: &str) -> Result<BlockChunkStore> {
        // Key on the canonical path so `./drive.bag`, `drive.bag`, and
        // symlinks to the same file share one cache entry instead of
        // each holding a duplicate copy of the bytes. Canonicalization
        // failure (file not created yet, dangling link) falls back to
        // the raw string — the read below reports the real error.
        let canon = std::fs::canonicalize(path)
            .map(|p| p.to_string_lossy().into_owned())
            .unwrap_or_else(|_| path.to_string());
        let key = format!("path:{canon}");
        if let Some(bytes) = self.cache.get(&key) {
            return Ok(BlockChunkStore::from_arc(bytes));
        }
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Storage(format!("bag '{path}': {e}")))?;
        Ok(BlockChunkStore::from_arc(self.cache.put_shared(&key, bytes)))
    }

    fn open_manifest(&self, id: &ManifestId, peers: &[String]) -> Result<BlockChunkStore> {
        // single-flight per manifest: the first resolver fetches, the
        // rest wait and then hit the cache block by block (a poisoned
        // lock just means an earlier resolver panicked — proceed)
        let key = id.hex();
        let gate = {
            let mut g = self.inflight.lock().unwrap();
            g.entry(key.clone())
                .or_insert_with(|| Arc::new(std::sync::Mutex::new(())))
                .clone()
        };
        let out = {
            let _resolving = gate.lock().unwrap_or_else(|p| p.into_inner());
            super::trace::span("manifest_resolve", || self.resolve_manifest(id, peers))
        };
        // Drop the gate once nobody is waiting on it, so the map stays
        // bounded by *concurrent* resolutions instead of growing by one
        // entry per manifest ever resolved. strong_count == 2 means the
        // map's reference plus our local `gate` — any waiter holds a
        // third; checking under the map lock makes the count stable (a
        // new arrival needs this same lock to clone the gate).
        let mut g = self.inflight.lock().unwrap();
        if g.get(&key).is_some_and(|a| Arc::strong_count(a) == 2) {
            g.remove(&key);
        }
        drop(g);
        out
    }

    /// Number of live single-flight gates (test hook for the drain
    /// invariant).
    #[cfg(test)]
    fn inflight_len(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// The body of a manifest resolution, running under the manifest's
    /// single-flight gate. Fetches walk the peer list in order: any
    /// connect or fetch failure advances to the next peer (already
    /// cached blocks are kept — a mid-fetch peer death re-fetches only
    /// the block that failed, from the next peer).
    fn resolve_manifest(&self, id: &ManifestId, peers: &[String]) -> Result<BlockChunkStore> {
        // one lazily-opened connection per resolution: a fully cached
        // object never dials any peer at all
        let mut cursor = PeerCursor {
            peers,
            idx: 0,
            client: None,
            timeout: self.fetch_timeout,
        };
        let mf_key = format!("mf:{}", id.hex());
        let manifest = match self.cache.get(&mf_key) {
            Some(bytes) => Manifest::decode(&bytes)?,
            None => {
                let m = cursor.try_peers("manifest_fetch", id, |c| c.fetch_manifest(id))?;
                self.cache.put_shared(&mf_key, m.encode());
                m
            }
        };
        let mut blocks = Vec::with_capacity(manifest.blocks.len());
        for (i, b) in manifest.blocks.iter().enumerate() {
            let key = format!("blk:{}", hex32(&b.id));
            let arc = match self.cache.get(&key) {
                Some(a) => a,
                None => {
                    let mut bytes =
                        cursor.try_peers("block_fetch", id, |c| {
                            c.fetch_block(id, i as u32, &manifest)
                        })?;
                    if self.faults.take_block_corruption() && !bytes.is_empty() {
                        // injected bit rot: damage the fetched bytes so
                        // the real content-hash check produces the real
                        // mismatch error, then surface it retryably (a
                        // fresh attempt re-fetches from a healthy peer)
                        bytes[0] ^= 0xFF;
                        let e = verify_block(&bytes, b, manifest.block_offset(i))
                            .expect_err("flipped byte must fail content verification");
                        return Err(Error::Engine(format!(
                            "{}: corrupted block fetch: {e}",
                            super::fault::FAULT_TAG
                        )));
                    }
                    self.cache.put_shared(&key, bytes)
                }
            };
            blocks.push(arc);
        }
        Ok(BlockChunkStore::new(blocks))
    }
}

/// Fallback iterator over a [`DataRef::Manifest`] peer list: holds one
/// live connection to the current peer and advances (never rewinds) on
/// any connect or fetch failure. Exhausting the list surfaces the last
/// peer's error wrapped with the manifest id and how many peers were
/// tried.
struct PeerCursor<'a> {
    peers: &'a [String],
    idx: usize,
    client: Option<BlockClient>,
    timeout: Duration,
}

impl PeerCursor<'_> {
    /// Run `op` against the current peer, advancing on failure. `stage`
    /// names the trace accumulator (`manifest_fetch` / `block_fetch`);
    /// each attempt is folded per `(stage, peer)` so traced slices show
    /// time spent against each peer individually.
    fn try_peers<T>(
        &mut self,
        stage: &str,
        id: &ManifestId,
        mut op: impl FnMut(&mut BlockClient) -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<Error> = None;
        loop {
            if self.idx >= self.peers.len() {
                let e = last
                    .unwrap_or_else(|| Error::Engine("no block peers in data ref".into()));
                return Err(Error::Engine(format!(
                    "fetching manifest {}: all {} block peer(s) failed; last: {e}",
                    id.short(),
                    self.peers.len()
                )));
            }
            if self.client.is_none() {
                match BlockClient::connect(&self.peers[self.idx], self.timeout) {
                    Ok(c) => self.client = Some(c),
                    Err(e) => {
                        last = Some(e);
                        self.idx += 1;
                        continue;
                    }
                }
            }
            let peer = self.peers[self.idx].as_str();
            let client = self.client.as_mut().expect("just connected");
            match super::trace::accum_detail(stage, peer, || op(client)) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    // the connection may be dead or the peer may simply
                    // not hold this object anymore — either way, move on
                    self.client = None;
                    last = Some(e);
                    self.idx += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_data_{tag}_{}_{:x}",
            std::process::id(),
            crate::util::now_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn published_store(
        dir: &std::path::Path,
        data: &[u8],
    ) -> (Arc<BlockStore>, ManifestId) {
        let store = BlockStore::open(dir).unwrap().with_block_size(1024);
        let (id, _) = store.publish(data).unwrap();
        (Arc::new(store), id)
    }

    #[test]
    fn evicted_peer_disappears_from_every_manifest() {
        let swarm = SwarmRegistry::new();
        swarm.advertise("a:7201", &[[1u8; 32], [2u8; 32]]);
        swarm.advertise("b:7201", &[[1u8; 32]]);
        swarm.evict("a:7201");
        assert_eq!(
            swarm.peers_for(&ManifestId([1u8; 32])),
            vec!["b:7201".to_string()],
            "surviving peer keeps its ads"
        );
        assert!(
            swarm.peers_for(&ManifestId([2u8; 32])).is_empty(),
            "sole-peer manifest is dropped entirely"
        );
        assert_eq!(swarm.len(), 1, "empty entries are removed, not kept hollow");
        // idempotent on unknown peers
        swarm.evict("a:7201");
        swarm.evict("never-advertised:1");
        assert_eq!(swarm.len(), 1);
    }

    #[test]
    fn injected_block_corruption_fails_retryably_then_clears() {
        let dir = tmp_dir("corrupt");
        let data: Vec<u8> = (0..4000).map(|i| (i % 251) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let peers = vec![server.peer().to_string()];

        let faults = super::super::fault::FaultPlan::none().corrupt_block_fetches(1);
        let dp = DataPlane::new(1 << 20).with_faults(faults);
        let err = dp.open(&DataRef::Manifest { id, peers: peers.clone() }).unwrap_err();
        assert!(err.is_retryable(), "injected corruption must be retryable: {err}");
        assert!(err.to_string().contains("hash mismatch"), "{err}");

        // budget spent: the retry (same plane, cold block) succeeds
        use crate::bag::ChunkStore;
        let mut chunks = dp.open(&DataRef::Manifest { id, peers }).unwrap();
        let out = chunks.read_at(0, data.len()).unwrap();
        assert_eq!(out, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn data_ref_codec_roundtrips_and_validates() {
        let refs = [
            DataRef::path("/data/drive.bag"),
            DataRef::manifest(ManifestId([9u8; 32]), "10.0.0.1:7199"),
            DataRef::Manifest {
                id: ManifestId([3u8; 32]),
                peers: vec![
                    "worker-a:7201".into(),
                    "worker-b:7201".into(),
                    "driver:7200".into(),
                ],
            },
        ];
        for d in refs {
            let mut w = ByteWriter::new();
            d.encode_into(&mut w);
            let buf = w.into_vec();
            let mut r = ByteReader::new(&buf);
            assert_eq!(DataRef::decode(&mut r).unwrap(), d);
        }
        // invalid refs are rejected at decode time
        for bad in [
            DataRef::Path(String::new()),
            DataRef::Manifest { id: ManifestId([0; 32]), peers: vec!["noport".into()] },
            DataRef::Manifest { id: ManifestId([0; 32]), peers: vec![String::new()] },
            DataRef::Manifest { id: ManifestId([0; 32]), peers: vec![] },
            DataRef::Manifest {
                id: ManifestId([0; 32]),
                // one bad peer poisons the whole list
                peers: vec!["ok:1".into(), "noport".into()],
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
            let mut w = ByteWriter::new();
            bad.encode_into(&mut w);
            let buf = w.into_vec();
            let mut r = ByteReader::new(&buf);
            assert!(DataRef::decode(&mut r).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn path_open_is_cached_and_zero_copy() {
        let dir = tmp_dir("path");
        let path = dir.join("x.bin");
        let data: Vec<u8> = (0..5000).map(|i| (i % 253) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let dp = DataPlane::new(1 << 20);
        let p = path.to_str().unwrap();
        use crate::bag::ChunkStore;
        let mut s1 = dp.open(&DataRef::path(p)).unwrap();
        assert_eq!(s1.read_at(0, data.len()).unwrap(), data);
        let mut s2 = dp.open(&DataRef::path(p)).unwrap();
        assert_eq!(s2.read_at(100, 50).unwrap(), &data[100..150]);
        let (hits, misses, _) = dp.cache().stats();
        assert_eq!((hits, misses), (1, 1));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_open_fetches_verifies_and_caches() {
        use crate::bag::ChunkStore;
        let dir = tmp_dir("fetch");
        let data: Vec<u8> = (0..10_000).map(|i| (i % 247) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let mut server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let dref = DataRef::manifest(id, server.peer());

        let dp = DataPlane::new(1 << 20);
        let mut obj = dp.open(&dref).unwrap();
        assert_eq!(obj.len() as usize, data.len());
        assert_eq!(obj.read_at(0, data.len()).unwrap(), data);

        // second resolution: fully cached — works even with the peer gone
        server.stop();
        let mut again = dp.open(&dref).unwrap();
        assert_eq!(again.read_at(500, 600).unwrap(), &data[500..1100]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn blocks_dedupe_across_manifests_in_the_cache() {
        let dir = tmp_dir("dedupe");
        let store = BlockStore::open(&dir).unwrap().with_block_size(1024);
        // two objects sharing their first two blocks
        let mut a = vec![7u8; 2048];
        let mut b = vec![7u8; 2048];
        a.extend_from_slice(&[1u8; 512]);
        b.extend_from_slice(&[2u8; 512]);
        let (id_a, _) = store.publish(&a).unwrap();
        let (id_b, _) = store.publish(&b).unwrap();
        let server =
            BlockServer::serve(Arc::new(store), "127.0.0.1:0", "127.0.0.1").unwrap();
        let dp = DataPlane::new(1 << 20);
        dp.open(&DataRef::manifest(id_a, server.peer())).unwrap();
        let used_after_a = dp.cache().used_bytes();
        dp.open(&DataRef::manifest(id_b, server.peer())).unwrap();
        let grew = dp.cache().used_bytes() - used_after_a;
        // object b adds only its manifest + its one distinct block —
        // identical content (vec![7; 2048] is one deduped block id) rides
        // the cache
        assert!(grew < 1024 + 256, "cache grew by {grew} — blocks not deduped");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fetch_errors_name_manifest_index_and_peer() {
        let dir = tmp_dir("err");
        let (store, id) = published_store(&dir, &[5u8; 3000]);
        let server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let peer = server.peer().to_string();

        // bad index → server-side FetchErr carried back with context
        let mut c = BlockClient::connect(&peer, Duration::from_secs(5)).unwrap();
        let manifest = c.fetch_manifest(&id).unwrap();
        let fat = Manifest {
            total_len: manifest.total_len + 1024,
            blocks: {
                let mut b = manifest.blocks.clone();
                let first = b[0];
                b.push(first);
                b
            },
        };
        let err = c.fetch_block(&id, fat.blocks.len() as u32 - 1, &fat).unwrap_err();
        let msg = err.to_string();
        assert!(err.is_retryable(), "fetch errors must be retryable: {msg}");
        assert!(msg.contains(&id.short()), "manifest id lost: {msg}");
        assert!(msg.contains("block 3"), "block index lost: {msg}");
        assert!(msg.contains(&peer), "peer lost: {msg}");

        // unknown manifest → FetchErr naming the id
        let ghost = ManifestId(crate::util::sha256::digest(b"ghost"));
        let err = c.fetch_manifest(&ghost).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&ghost.short()), "{msg}");
        assert!(msg.contains(&peer), "{msg}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn lost_peer_is_a_retryable_error_naming_the_peer() {
        // reserve a port, then close it — nothing listens
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peer = listener.local_addr().unwrap().to_string();
        drop(listener);
        let id = ManifestId(crate::util::sha256::digest(b"unreachable"));
        let dp = DataPlane::new(1 << 20);
        let err = dp.open(&DataRef::manifest(id, peer.clone())).unwrap_err();
        let msg = err.to_string();
        assert!(err.is_retryable(), "lost peer must be retryable: {msg}");
        assert!(msg.contains(&peer), "peer lost from error: {msg}");
    }

    /// Satellite regression: `./x`, the plain path, and a symlink to the
    /// same file must share one cache entry, not cache three copies.
    #[test]
    fn path_aliases_share_one_cache_entry() {
        let dir = tmp_dir("alias");
        let path = dir.join("drive.bag");
        let data = vec![0xABu8; 4096];
        std::fs::write(&path, &data).unwrap();
        let link = dir.join("drive-link.bag");
        #[cfg(unix)]
        std::os::unix::fs::symlink(&path, &link).unwrap();
        #[cfg(not(unix))]
        std::fs::hard_link(&path, &link).unwrap();

        let dp = DataPlane::new(1 << 20);
        let direct = path.to_str().unwrap().to_string();
        // a dot-relative alias of the same file
        let dotted = format!(
            "{}/./{}",
            dir.to_str().unwrap(),
            path.file_name().unwrap().to_str().unwrap()
        );
        dp.open(&DataRef::path(&direct)).unwrap();
        let used_once = dp.cache().used_bytes();
        dp.open(&DataRef::path(&dotted)).unwrap();
        dp.open(&DataRef::path(link.to_str().unwrap())).unwrap();
        assert_eq!(
            dp.cache().used_bytes(),
            used_once,
            "aliased paths must not duplicate the bytes"
        );
        let (hits, misses, _) = dp.cache().stats();
        assert_eq!((hits, misses), (2, 1), "aliases must hit the first entry");
        std::fs::remove_dir_all(dir).ok();
    }

    /// Satellite regression: the single-flight map must drain after
    /// resolutions complete (success *and* failure paths) instead of
    /// leaking one gate per manifest ever resolved.
    #[test]
    fn inflight_gates_drain_after_resolution() {
        let dir = tmp_dir("drain");
        let data: Vec<u8> = (0..8000).map(|i| (i % 251) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let dp = DataPlane::new(1 << 20);
        dp.open(&DataRef::manifest(id, server.peer())).unwrap();
        assert_eq!(dp.inflight_len(), 0, "gate leaked after successful resolution");

        // concurrent resolutions of the same manifest also drain
        let mut handles = Vec::new();
        for _ in 0..4 {
            let dp2 = dp.clone();
            let dref = DataRef::manifest(id, server.peer());
            handles.push(std::thread::spawn(move || dp2.open(&dref).map(|_| ())));
        }
        for h in handles {
            h.join().unwrap().unwrap();
        }
        assert_eq!(dp.inflight_len(), 0, "gate leaked after concurrent resolutions");

        // failed resolutions must not leak either
        let ghost = ManifestId(crate::util::sha256::digest(b"never published"));
        let fast = DataPlane::new(1 << 20).with_fetch_timeout(Duration::from_millis(50));
        assert!(fast.open(&DataRef::manifest(ghost, "127.0.0.1:1")).is_err());
        assert_eq!(fast.inflight_len(), 0, "gate leaked after failed resolution");
        std::fs::remove_dir_all(dir).ok();
    }

    /// Tentpole: a dead first peer falls back to the next peer in the
    /// list, and the whole object still resolves and verifies.
    #[test]
    fn dead_first_peer_falls_back_to_next() {
        let dir = tmp_dir("fallback");
        let data: Vec<u8> = (0..6000).map(|i| (i % 249) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        // a reserved-then-closed port: connect fails fast
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap().to_string();
            drop(l);
            addr
        };
        let dp = DataPlane::new(1 << 20).with_fetch_timeout(Duration::from_millis(200));
        use crate::bag::ChunkStore;
        let mut obj = dp
            .open(&DataRef::Manifest {
                id,
                peers: vec![dead, server.peer().to_string()],
            })
            .unwrap();
        assert_eq!(obj.read_at(0, data.len()).unwrap(), data);
    }

    /// Tentpole: a peer that dies *mid-fetch* (manifest served, then
    /// connection dropped) loses only the block in flight — the
    /// requester re-fetches it from the next peer and keeps the blocks
    /// it already verified.
    #[test]
    fn mid_fetch_peer_death_falls_back_to_next_peer() {
        let dir = tmp_dir("midfetch");
        let data: Vec<u8> = (0..5000).map(|i| (i % 241) as u8).collect();
        let (store, id) = published_store(&dir, &data);

        // treacherous peer: answers the handshake and the manifest
        // fetch, serves block 0, then slams the connection shut
        let treacherous = TcpListener::bind("127.0.0.1:0").unwrap();
        let taddr = treacherous.local_addr().unwrap().to_string();
        let tstore = store.clone();
        let thandle = std::thread::spawn(move || {
            let (stream, _) = treacherous.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            let mut served_blocks = 0usize;
            loop {
                match read_msg(&mut reader) {
                    Ok(Some(RpcMsg::Hello { .. })) => write_msg(
                        &mut writer,
                        &RpcMsg::HelloOk {
                            version: RPC_VERSION,
                            worker_id: BLOCK_PEER_ID,
                            now_ns: 0,
                        },
                    )
                    .unwrap(),
                    Ok(Some(RpcMsg::FetchManifest { id })) => {
                        let m = tstore.manifest(&ManifestId(id)).unwrap();
                        write_msg(&mut writer, &RpcMsg::ManifestData(m.encode())).unwrap();
                    }
                    Ok(Some(RpcMsg::FetchBlock { manifest, index })) => {
                        if served_blocks >= 1 {
                            return; // die mid-fetch: request read, no reply
                        }
                        served_blocks += 1;
                        let m = tstore.manifest(&ManifestId(manifest)).unwrap();
                        let bytes = tstore
                            .read_block(
                                &m.blocks[index as usize],
                                m.block_offset(index as usize),
                            )
                            .unwrap();
                        write_msg(&mut writer, &RpcMsg::BlockData(bytes)).unwrap();
                    }
                    _ => return,
                }
            }
        });

        let healthy = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let dp = DataPlane::new(1 << 20).with_fetch_timeout(Duration::from_secs(2));
        use crate::bag::ChunkStore;
        let mut obj = dp
            .open(&DataRef::Manifest {
                id,
                peers: vec![taddr, healthy.peer().to_string()],
            })
            .unwrap();
        assert_eq!(obj.read_at(0, data.len()).unwrap(), data);
        thandle.join().unwrap();
        std::fs::remove_dir_all(dir).ok();
    }

    /// Tentpole: a warm worker's `DataPlane` cache serves the swarm —
    /// and keeps serving correctly (via `FetchErr` + fallback) while
    /// its LRU evicts blocks under it.
    #[test]
    fn cache_backed_serving_survives_lru_eviction_races() {
        use crate::bag::ChunkStore;
        let dir = tmp_dir("swarmserve");
        let data: Vec<u8> = (0..20_000).map(|i| (i % 239) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let driver = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();

        // warm worker: resolves from the driver, then serves its cache
        let warm = Arc::new(DataPlane::new(1 << 20));
        warm.open(&DataRef::manifest(id, driver.peer())).unwrap();
        assert_eq!(warm.resident_manifests(), vec![id], "warm cache must advertise");
        let warm_srv: Arc<dyn BlockSource> = warm.clone();
        let warm_server =
            BlockServer::serve_source(warm_srv, "127.0.0.1:0", "127.0.0.1").unwrap();

        // cold worker fetches from the warm sibling first, driver last,
        // while a churn thread thrashes the warm worker's LRU
        let churn_stop = Arc::new(AtomicBool::new(false));
        let churner = {
            let warm = warm.clone();
            let stop = churn_stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    // oversized junk entries force evictions
                    warm.cache().put_shared(&format!("junk:{i}"), vec![0u8; 900 << 10]);
                    i += 1;
                }
            })
        };
        for round in 0..4 {
            let cold = DataPlane::new(1 << 20);
            let mut obj = cold
                .open(&DataRef::Manifest {
                    id,
                    peers: vec![warm_server.peer().to_string(), driver.peer().to_string()],
                })
                .unwrap_or_else(|e| panic!("round {round}: {e}"));
            assert_eq!(obj.read_at(0, data.len()).unwrap(), data, "round {round}");
        }
        churn_stop.store(true, Ordering::SeqCst);
        churner.join().unwrap();
        std::fs::remove_dir_all(dir).ok();
    }
}
