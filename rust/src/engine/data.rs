//! The engine's data plane: content-addressed task inputs shipped over
//! RPC instead of resolved from worker-local paths.
//!
//! The paper's platform distributes simulation data *to* the compute
//! nodes (Spark + an HDFS-like storage tier); nothing assumes a shared
//! filesystem. This module closes that gap for our engine: a task names
//! its bag input with a [`DataRef`] — either a worker-local `Path`
//! (back-compat; single box or genuinely shared storage) or a
//! `Manifest` (a `storage::ManifestId` plus the `host:port` of a *block
//! peer* that serves the bytes). Workers resolve manifests through
//! their [`DataPlane`]: an LRU byte cache (shared across all `--slots`
//! connections of a worker process) backed by [`BlockClient`] fetches
//! of individual content-addressed blocks over the
//! [`super::rpc`] framing. Every transfer is verified: the manifest
//! must hash to its id, and every block must hash to its address — a
//! lying or corrupted peer is detected at fetch time, never replayed.
//!
//! The serving side is [`BlockServer`]: the driver publishes a bag into
//! a `storage::BlockStore` (`publish_bag` → manifest id) and serves
//! `FetchManifest`/`FetchBlock` requests from it, so a standalone fleet
//! on other hosts needs zero shared state — the bag travels through the
//! engine, exactly once per block per worker (cache hits after that).

use crate::bag::BagCache;
use crate::engine::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use crate::error::{Error, Result};
use crate::storage::{
    hex32, verify_block, BlockChunkStore, BlockStore, Manifest, ManifestId,
};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Where a task's bag bytes come from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataRef {
    /// A filesystem path resolvable on the executing worker (the
    /// original model: single box, or storage genuinely mounted
    /// everywhere).
    Path(String),
    /// A content-addressed object: fetch the manifest and its blocks
    /// from `peer` and verify everything against `id`. The bytes are
    /// identical on every worker by construction.
    Manifest {
        /// Content address of the published object.
        id: ManifestId,
        /// `host:port` of the block peer serving it (normally the
        /// driver's [`BlockServer`]).
        peer: String,
    },
}

impl DataRef {
    /// Convenience constructor for the back-compat path form.
    pub fn path(p: impl Into<String>) -> Self {
        DataRef::Path(p.into())
    }

    /// Plan-time validation: malformed refs fail when the task is
    /// built/decoded, not deep inside a worker's bag open.
    pub fn validate(&self) -> Result<()> {
        match self {
            DataRef::Path(p) if p.is_empty() => {
                Err(Error::Engine("data ref: empty bag path".into()))
            }
            DataRef::Manifest { peer, .. }
                if peer.is_empty() || !peer.contains(':') =>
            {
                Err(Error::Engine(format!(
                    "data ref: block peer '{peer}' is not host:port"
                )))
            }
            _ => Ok(()),
        }
    }

    /// Short description for logs / `Source::describe`.
    pub fn describe(&self) -> String {
        match self {
            DataRef::Path(p) => p.clone(),
            DataRef::Manifest { id, peer } => format!("mf:{}@{peer}", id.short()),
        }
    }

    /// Serialize into a task-spec payload.
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            DataRef::Path(p) => {
                w.put_u8(0);
                w.put_str(p);
            }
            DataRef::Manifest { id, peer } => {
                w.put_u8(1);
                w.put_raw(&id.0);
                w.put_str(peer);
            }
        }
    }

    /// Decode a [`DataRef::encode_into`] payload (validated).
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        let d = match r.get_u8()? {
            0 => DataRef::Path(r.get_str()?),
            1 => {
                let id: [u8; 32] = r.get_raw(32)?.try_into().unwrap();
                DataRef::Manifest { id: ManifestId(id), peer: r.get_str()? }
            }
            other => {
                return Err(Error::Engine(format!("unknown data ref tag {other}")))
            }
        };
        d.validate()?;
        Ok(d)
    }
}

// ---------------------------------------------------------------------
// client
// ---------------------------------------------------------------------

/// RPC client for a block peer: fetches manifests and blocks with
/// end-to-end hash verification. Every error names the peer's
/// `host:port` and — for block fetches — the manifest id and block
/// index, mirroring the deploy layer's connect-error convention. All
/// fetch failures are `Error::Engine` (retryable): a worker that loses
/// its block peer mid-slice fails the *task*, which the scheduler may
/// re-run elsewhere.
pub struct BlockClient {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    /// The `host:port` this client dialed.
    pub peer: String,
}

impl BlockClient {
    /// Connect to a block peer, retrying with capped backoff until
    /// `timeout`, then verify the RPC version via the `Hello`
    /// handshake. Errors name the peer and the attempt count.
    pub fn connect(peer: &str, timeout: Duration) -> Result<Self> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = Duration::from_millis(1);
        let mut attempts = 0usize;
        let stream = loop {
            attempts += 1;
            match TcpStream::connect(peer) {
                Ok(s) => break s,
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::Engine(format!(
                            "block peer {peer} not reachable after {attempts} \
                             connect attempt(s) over {timeout:?}: {e}"
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(50));
                }
            }
        };
        stream.set_nodelay(true).ok();
        // Bound the handshake by the remaining budget (a wedged peer
        // must not hang the fetch forever).
        let remaining = deadline
            .saturating_duration_since(std::time::Instant::now())
            .max(Duration::from_millis(1));
        stream.set_read_timeout(Some(remaining)).ok();
        let mut c = Self {
            reader: std::io::BufReader::new(stream.try_clone()?),
            writer: std::io::BufWriter::new(stream),
            peer: peer.to_string(),
        };
        write_msg(&mut c.writer, &RpcMsg::Hello { version: RPC_VERSION })
            .map_err(|e| c.ctx_err("handshake", &e))?;
        match read_msg(&mut c.reader).map_err(|e| c.ctx_err("handshake", &e))? {
            Some(RpcMsg::HelloOk { version, .. }) if version == RPC_VERSION => {}
            Some(RpcMsg::HelloOk { version, .. }) => {
                return Err(Error::Engine(format!(
                    "block peer {peer} speaks rpc v{version} but this build needs \
                     v{RPC_VERSION} — redeploy"
                )));
            }
            other => {
                return Err(Error::Engine(format!(
                    "block peer {peer} answered handshake with {other:?}"
                )))
            }
        }
        // After the handshake, reads keep a *generous* cap instead of
        // none at all: a loaded peer may be slow, but a peer that stalls
        // mid-fetch (paused process, silent partition) must surface as a
        // retryable task error, not hang the worker's task thread
        // forever — the module's failure contract only holds if every
        // read eventually returns.
        c.reader
            .get_ref()
            .set_read_timeout(Some(BLOCK_READ_TIMEOUT))
            .ok();
        Ok(c)
    }

    fn ctx_err(&self, what: &str, e: &dyn std::fmt::Display) -> Error {
        Error::Engine(format!("{what} from block peer {}: {e}", self.peer))
    }

    /// Fetch and verify the manifest for `id`: the returned manifest's
    /// encoded bytes hash to `id`, so every block length and address in
    /// it is authenticated.
    pub fn fetch_manifest(&mut self, id: &ManifestId) -> Result<Manifest> {
        let what = format!("manifest {}", id.short());
        write_msg(&mut self.writer, &RpcMsg::FetchManifest { id: id.0 })
            .map_err(|e| self.ctx_err(&what, &e))?;
        let bytes = match read_msg(&mut self.reader).map_err(|e| self.ctx_err(&what, &e))? {
            Some(RpcMsg::ManifestData(b)) => b,
            Some(RpcMsg::FetchErr(m)) => return Err(self.ctx_err(&what, &m)),
            None => return Err(self.ctx_err(&what, &"peer hung up mid-fetch")),
            other => {
                return Err(self.ctx_err(&what, &format!("unexpected reply {other:?}")))
            }
        };
        if crate::util::sha256::digest(&bytes) != id.0 {
            return Err(self.ctx_err(
                &what,
                &"manifest bytes do not hash to the requested id",
            ));
        }
        Manifest::decode(&bytes).map_err(|e| self.ctx_err(&what, &e))
    }

    /// Fetch block `index` of `manifest` (whose id is `id`) and verify
    /// it against the manifest's `BlockRef`. Failures name the manifest
    /// id, block index, and this peer's `host:port`.
    pub fn fetch_block(
        &mut self,
        id: &ManifestId,
        index: u32,
        manifest: &Manifest,
    ) -> Result<Vec<u8>> {
        let what = format!("block {index} of manifest {}", id.short());
        let bref = manifest.blocks.get(index as usize).ok_or_else(|| {
            self.ctx_err(
                &what,
                &format!("manifest has only {} block(s)", manifest.blocks.len()),
            )
        })?;
        write_msg(
            &mut self.writer,
            &RpcMsg::FetchBlock { manifest: id.0, index },
        )
        .map_err(|e| self.ctx_err(&what, &e))?;
        let bytes = match read_msg(&mut self.reader).map_err(|e| self.ctx_err(&what, &e))? {
            Some(RpcMsg::BlockData(b)) => b,
            Some(RpcMsg::FetchErr(m)) => return Err(self.ctx_err(&what, &m)),
            None => return Err(self.ctx_err(&what, &"peer hung up mid-fetch")),
            other => {
                return Err(self.ctx_err(&what, &format!("unexpected reply {other:?}")))
            }
        };
        verify_block(&bytes, bref, manifest.block_offset(index as usize))
            .map_err(|e| self.ctx_err(&what, &e))?;
        Ok(bytes)
    }
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// Per-read socket cap on block fetches after the connect handshake
/// (ample for a 4 MiB block on any sane link; a peer that cannot move
/// one block in this long is treated as lost and the task retried).
const BLOCK_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Worker id a [`BlockServer`] reports in its `HelloOk` (distinguishes
/// block peers from task workers in probes and logs).
pub const BLOCK_PEER_ID: u64 = u64::MAX;

/// A block peer: serves `FetchManifest`/`FetchBlock` requests from a
/// [`BlockStore`] over the engine's RPC framing. The driver runs one
/// next to each job that ships data by manifest; workers dial it with
/// [`BlockClient`]. Serving is read-only and every block is verified
/// before it leaves (local disk corruption is reported to the
/// requester, not silently forwarded).
pub struct BlockServer {
    peer: String,
    wake_addr: String,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl BlockServer {
    /// Bind `listen` (e.g. `"0.0.0.0:0"` for any port) and serve
    /// `store` until [`BlockServer::stop`] / drop. `advertise_host` is
    /// the hostname workers should dial (combined with the actually
    /// bound port to form [`BlockServer::peer`]); pass `"127.0.0.1"`
    /// for single-box runs, the driver's reachable address for fleets.
    pub fn serve(
        store: Arc<BlockStore>,
        listen: &str,
        advertise_host: &str,
    ) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::Engine(format!("block server bind {listen}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Engine(format!("block server local_addr: {e}")))?;
        let peer = format!("{advertise_host}:{}", local.port());
        let wake_addr = if local.ip().is_unspecified() {
            match local.ip() {
                std::net::IpAddr::V4(_) => format!("127.0.0.1:{}", local.port()),
                std::net::IpAddr::V6(_) => format!("[::1]:{}", local.port()),
            }
        } else {
            local.to_string()
        };
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name(format!("av-simd-block-server-{}", local.port()))
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let store = store.clone();
                    // Handlers are detached: they exit when the client
                    // disconnects, and hold no listener resources.
                    let _ = std::thread::Builder::new()
                        .name("av-simd-block-conn".into())
                        .spawn(move || {
                            if let Err(e) = serve_block_conn(stream, &store) {
                                crate::logmsg!("warn", "block server connection: {e}");
                            }
                        });
                }
            })
            .map_err(|e| Error::Engine(format!("spawn block server thread: {e}")))?;
        crate::logmsg!("info", "block server serving on {peer}");
        Ok(Self { peer, wake_addr, shutdown, accept_handle: Some(accept_handle) })
    }

    /// The `host:port` workers should dial (advertised host + bound
    /// port) — what goes into [`DataRef::Manifest`].
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// Stop accepting connections and release the port. In-flight
    /// connections finish on their own threads.
    pub fn stop(&mut self) {
        if let Some(h) = self.accept_handle.take() {
            self.shutdown.store(true, Ordering::SeqCst);
            // wake the accept loop so it observes the flag (a failed
            // dial means the loop already exited)
            let _ = TcpStream::connect(&self.wake_addr);
            let _ = h.join();
        }
    }
}

impl Drop for BlockServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One block-server connection: answer fetches until the client hangs
/// up. Manifests are cached per connection so a client streaming every
/// block of one object costs one manifest load, not N.
fn serve_block_conn(stream: TcpStream, store: &BlockStore) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    let mut manifests: HashMap<[u8; 32], Manifest> = HashMap::new();
    loop {
        match read_msg(&mut reader)? {
            None => return Ok(()),
            Some(RpcMsg::Ping) => write_msg(&mut writer, &RpcMsg::Pong)?,
            Some(RpcMsg::Hello { version: _ }) => write_msg(
                &mut writer,
                &RpcMsg::HelloOk { version: RPC_VERSION, worker_id: BLOCK_PEER_ID },
            )?,
            Some(RpcMsg::Shutdown) => return Ok(()),
            Some(RpcMsg::FetchManifest { id }) => {
                let reply = match store.manifest(&ManifestId(id)) {
                    Ok(m) => {
                        let bytes = m.encode();
                        manifests.insert(id, m);
                        RpcMsg::ManifestData(bytes)
                    }
                    Err(e) => RpcMsg::FetchErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(RpcMsg::FetchBlock { manifest, index }) => {
                let reply = match fetch_block_reply(store, &mut manifests, manifest, index)
                {
                    Ok(bytes) => RpcMsg::BlockData(bytes),
                    Err(e) => RpcMsg::FetchErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(other) => {
                return Err(Error::Engine(format!(
                    "block server received unexpected message {other:?}"
                )))
            }
        }
    }
}

/// Resolve one `FetchBlock` request against the store (loading the
/// manifest through the per-connection cache) and verify the block
/// before serving it.
fn fetch_block_reply(
    store: &BlockStore,
    manifests: &mut HashMap<[u8; 32], Manifest>,
    manifest_id: [u8; 32],
    index: u32,
) -> Result<Vec<u8>> {
    let m = match manifests.entry(manifest_id) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
        std::collections::hash_map::Entry::Vacant(v) => {
            v.insert(store.manifest(&ManifestId(manifest_id))?)
        }
    };
    let bref = m.blocks.get(index as usize).ok_or_else(|| {
        Error::Storage(format!(
            "manifest {} has {} block(s), index {index} out of range",
            ManifestId(manifest_id).short(),
            m.blocks.len()
        ))
    })?;
    store.read_block(bref, m.block_offset(index as usize))
}

// ---------------------------------------------------------------------
// worker-side data plane
// ---------------------------------------------------------------------

/// The worker's view of the data plane: resolves [`DataRef`]s into
/// playable block stores through one LRU byte cache. The cache replaces
/// the old path-keyed bag cache and is shared by all `--slots`
/// connections of a worker process (every [`super::ops::TaskCtx`] clone
/// shares it), holding three kinds of entries:
///
/// * `path:<p>` — whole bag files read from a worker-local path;
/// * `mf:<hex>` — verified manifest bytes;
/// * `blk:<hex>` — verified blocks, keyed by content address, so two
///   manifests sharing blocks dedupe in RAM and eviction is per-block.
///
/// Resolution is zero-copy on hits: cached entries are `Arc`-shared
/// into the returned [`BlockChunkStore`] (the old path cache copied the
/// whole bag into a fresh buffer on every open).
#[derive(Clone)]
pub struct DataPlane {
    cache: BagCache,
    fetch_timeout: Duration,
    /// Per-manifest single-flight locks: concurrent first opens of the
    /// same manifest (a multi-slot worker receiving several slices of a
    /// just-published bag at once) serialize, so a cold bag crosses the
    /// wire once per worker process — the followers find every block
    /// cached. Entries are bounded by the number of distinct manifests
    /// this worker has ever resolved (tiny).
    inflight: Arc<std::sync::Mutex<HashMap<String, Arc<std::sync::Mutex<()>>>>>,
}

impl DataPlane {
    /// Data plane with an LRU byte budget of `capacity_bytes`. The
    /// default fetch-connect budget is short (2 s): unlike task
    /// workers, a block peer is up *before* any task referencing it is
    /// dispatched, so an unreachable peer should fail the task quickly
    /// and let the scheduler's retry policy take over.
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            cache: BagCache::new(capacity_bytes),
            fetch_timeout: Duration::from_secs(2),
            inflight: Arc::new(std::sync::Mutex::new(HashMap::new())),
        }
    }

    /// Override the per-resolution connect budget; builder-style.
    pub fn with_fetch_timeout(mut self, t: Duration) -> Self {
        self.fetch_timeout = t;
        self
    }

    /// The underlying byte cache (stats, direct seeding in tests).
    pub fn cache(&self) -> &BagCache {
        &self.cache
    }

    /// Resolve a data ref into a playable store. `Path` refs read
    /// through the cache from the local filesystem; `Manifest` refs
    /// fetch any missing manifest/blocks from the ref's peer, verify
    /// them, and cache them by content address.
    pub fn open(&self, data: &DataRef) -> Result<BlockChunkStore> {
        data.validate()?;
        match data {
            DataRef::Path(p) => self.open_path(p),
            DataRef::Manifest { id, peer } => self.open_manifest(id, peer),
        }
    }

    fn open_path(&self, path: &str) -> Result<BlockChunkStore> {
        let key = format!("path:{path}");
        if let Some(bytes) = self.cache.get(&key) {
            return Ok(BlockChunkStore::from_arc(bytes));
        }
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Storage(format!("bag '{path}': {e}")))?;
        Ok(BlockChunkStore::from_arc(self.cache.put_shared(&key, bytes)))
    }

    fn open_manifest(&self, id: &ManifestId, peer: &str) -> Result<BlockChunkStore> {
        // single-flight per manifest: the first resolver fetches, the
        // rest wait and then hit the cache block by block (a poisoned
        // lock just means an earlier resolver panicked — proceed)
        let gate = {
            let mut g = self.inflight.lock().unwrap();
            g.entry(id.hex())
                .or_insert_with(|| Arc::new(std::sync::Mutex::new(())))
                .clone()
        };
        let _resolving = gate.lock().unwrap_or_else(|p| p.into_inner());
        // one lazily-opened connection per resolution: a fully cached
        // object never dials the peer at all
        let mut client: Option<BlockClient> = None;
        let mf_key = format!("mf:{}", id.hex());
        let manifest = match self.cache.get(&mf_key) {
            Some(bytes) => Manifest::decode(&bytes)?,
            None => {
                let m = self.client(&mut client, peer, id)?.fetch_manifest(id)?;
                self.cache.put_shared(&mf_key, m.encode());
                m
            }
        };
        let mut blocks = Vec::with_capacity(manifest.blocks.len());
        for (i, b) in manifest.blocks.iter().enumerate() {
            let key = format!("blk:{}", hex32(&b.id));
            let arc = match self.cache.get(&key) {
                Some(a) => a,
                None => {
                    let bytes = self
                        .client(&mut client, peer, id)?
                        .fetch_block(id, i as u32, &manifest)?;
                    self.cache.put_shared(&key, bytes)
                }
            };
            blocks.push(arc);
        }
        Ok(BlockChunkStore::new(blocks))
    }

    /// Lazily connect the per-resolution client; a connect failure is
    /// wrapped with the manifest being resolved, so even "peer
    /// unreachable" errors name what the worker was trying to fetch.
    fn client<'a>(
        &self,
        slot: &'a mut Option<BlockClient>,
        peer: &str,
        id: &ManifestId,
    ) -> Result<&'a mut BlockClient> {
        if slot.is_none() {
            *slot = Some(
                BlockClient::connect(peer, self.fetch_timeout).map_err(|e| {
                    Error::Engine(format!("fetching manifest {}: {e}", id.short()))
                })?,
            );
        }
        Ok(slot.as_mut().expect("just filled"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_data_{tag}_{}_{:x}",
            std::process::id(),
            crate::util::now_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn published_store(
        dir: &std::path::Path,
        data: &[u8],
    ) -> (Arc<BlockStore>, ManifestId) {
        let store = BlockStore::open(dir).unwrap().with_block_size(1024);
        let (id, _) = store.publish(data).unwrap();
        (Arc::new(store), id)
    }

    #[test]
    fn data_ref_codec_roundtrips_and_validates() {
        let refs = [
            DataRef::path("/data/drive.bag"),
            DataRef::Manifest {
                id: ManifestId([9u8; 32]),
                peer: "10.0.0.1:7199".into(),
            },
        ];
        for d in refs {
            let mut w = ByteWriter::new();
            d.encode_into(&mut w);
            let buf = w.into_vec();
            let mut r = ByteReader::new(&buf);
            assert_eq!(DataRef::decode(&mut r).unwrap(), d);
        }
        // invalid refs are rejected at decode time
        for bad in [
            DataRef::Path(String::new()),
            DataRef::Manifest { id: ManifestId([0; 32]), peer: "noport".into() },
            DataRef::Manifest { id: ManifestId([0; 32]), peer: String::new() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
            let mut w = ByteWriter::new();
            bad.encode_into(&mut w);
            let buf = w.into_vec();
            let mut r = ByteReader::new(&buf);
            assert!(DataRef::decode(&mut r).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn path_open_is_cached_and_zero_copy() {
        let dir = tmp_dir("path");
        let path = dir.join("x.bin");
        let data: Vec<u8> = (0..5000).map(|i| (i % 253) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let dp = DataPlane::new(1 << 20);
        let p = path.to_str().unwrap();
        use crate::bag::ChunkStore;
        let mut s1 = dp.open(&DataRef::path(p)).unwrap();
        assert_eq!(s1.read_at(0, data.len()).unwrap(), data);
        let mut s2 = dp.open(&DataRef::path(p)).unwrap();
        assert_eq!(s2.read_at(100, 50).unwrap(), &data[100..150]);
        let (hits, misses, _) = dp.cache().stats();
        assert_eq!((hits, misses), (1, 1));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_open_fetches_verifies_and_caches() {
        use crate::bag::ChunkStore;
        let dir = tmp_dir("fetch");
        let data: Vec<u8> = (0..10_000).map(|i| (i % 247) as u8).collect();
        let (store, id) = published_store(&dir, &data);
        let mut server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let dref = DataRef::Manifest { id, peer: server.peer().to_string() };

        let dp = DataPlane::new(1 << 20);
        let mut obj = dp.open(&dref).unwrap();
        assert_eq!(obj.len() as usize, data.len());
        assert_eq!(obj.read_at(0, data.len()).unwrap(), data);

        // second resolution: fully cached — works even with the peer gone
        server.stop();
        let mut again = dp.open(&dref).unwrap();
        assert_eq!(again.read_at(500, 600).unwrap(), &data[500..1100]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn blocks_dedupe_across_manifests_in_the_cache() {
        let dir = tmp_dir("dedupe");
        let store = BlockStore::open(&dir).unwrap().with_block_size(1024);
        // two objects sharing their first two blocks
        let mut a = vec![7u8; 2048];
        let mut b = vec![7u8; 2048];
        a.extend_from_slice(&[1u8; 512]);
        b.extend_from_slice(&[2u8; 512]);
        let (id_a, _) = store.publish(&a).unwrap();
        let (id_b, _) = store.publish(&b).unwrap();
        let server =
            BlockServer::serve(Arc::new(store), "127.0.0.1:0", "127.0.0.1").unwrap();
        let dp = DataPlane::new(1 << 20);
        dp.open(&DataRef::Manifest { id: id_a, peer: server.peer().to_string() })
            .unwrap();
        let used_after_a = dp.cache().used_bytes();
        dp.open(&DataRef::Manifest { id: id_b, peer: server.peer().to_string() })
            .unwrap();
        let grew = dp.cache().used_bytes() - used_after_a;
        // object b adds only its manifest + its one distinct block —
        // identical content (vec![7; 2048] is one deduped block id) rides
        // the cache
        assert!(grew < 1024 + 256, "cache grew by {grew} — blocks not deduped");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fetch_errors_name_manifest_index_and_peer() {
        let dir = tmp_dir("err");
        let (store, id) = published_store(&dir, &[5u8; 3000]);
        let server = BlockServer::serve(store, "127.0.0.1:0", "127.0.0.1").unwrap();
        let peer = server.peer().to_string();

        // bad index → server-side FetchErr carried back with context
        let mut c = BlockClient::connect(&peer, Duration::from_secs(5)).unwrap();
        let manifest = c.fetch_manifest(&id).unwrap();
        let fat = Manifest {
            total_len: manifest.total_len + 1024,
            blocks: {
                let mut b = manifest.blocks.clone();
                let first = b[0];
                b.push(first);
                b
            },
        };
        let err = c.fetch_block(&id, fat.blocks.len() as u32 - 1, &fat).unwrap_err();
        let msg = err.to_string();
        assert!(err.is_retryable(), "fetch errors must be retryable: {msg}");
        assert!(msg.contains(&id.short()), "manifest id lost: {msg}");
        assert!(msg.contains("block 3"), "block index lost: {msg}");
        assert!(msg.contains(&peer), "peer lost: {msg}");

        // unknown manifest → FetchErr naming the id
        let ghost = ManifestId(crate::util::sha256::digest(b"ghost"));
        let err = c.fetch_manifest(&ghost).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&ghost.short()), "{msg}");
        assert!(msg.contains(&peer), "{msg}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn lost_peer_is_a_retryable_error_naming_the_peer() {
        // reserve a port, then close it — nothing listens
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peer = listener.local_addr().unwrap().to_string();
        drop(listener);
        let id = ManifestId(crate::util::sha256::digest(b"unreachable"));
        let dp = DataPlane::new(1 << 20);
        let err = dp
            .open(&DataRef::Manifest { id, peer: peer.clone() })
            .unwrap_err();
        let msg = err.to_string();
        assert!(err.is_retryable(), "lost peer must be retryable: {msg}");
        assert!(msg.contains(&peer), "peer lost from error: {msg}");
    }
}
