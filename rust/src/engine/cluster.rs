//! Cluster abstraction + the local (thread-pool) implementation.
//!
//! The paper's platform runs on a Spark cluster; ours runs on either
//! worker threads in-process ([`LocalCluster`], the default and the unit
//! under test for scalability benches) or spawned worker processes over
//! TCP ([`super::remote::StandaloneCluster`]). Both present the same
//! [`Cluster`] trait: open a [`TaskStream`], feed tasks through it as
//! capacity frees up, read completions back in finish order. The batch
//! API ([`Cluster::run_tasks`]) is a thin convenience wrapper over the
//! stream.

use super::data::SwarmRegistry;
use super::executor;
use super::fault::{FaultPlan, FAULT_TAG};
use super::ops::{OpRegistry, TaskCtx};
use super::plan::{TaskOutput, TaskSpec};
use super::stream::TaskStream;
use super::trace;
use crate::error::{Error, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A set of workers that can execute tasks.
pub trait Cluster: Send + Sync {
    /// Number of workers.
    fn workers(&self) -> usize;

    /// Open a streaming session: tasks submitted through the returned
    /// [`TaskStream`] flow to idle workers immediately; completions come
    /// back in finish order. The caller must `close()` the stream when
    /// no more tasks will be submitted.
    fn open_stream(&self) -> Arc<TaskStream>;

    /// Batch convenience: execute all tasks, returning results in task
    /// order. Individual task failures are returned as `Err` entries
    /// (the scheduler retries); runs on the streaming path.
    fn run_tasks(&self, tasks: &[TaskSpec]) -> Vec<Result<TaskOutput>> {
        let stream = self.open_stream();
        let _close = stream.clone().close_on_drop();
        for (i, t) in tasks.iter().enumerate() {
            stream.submit(i as u64, t.clone());
        }
        let mut out: Vec<Option<Result<TaskOutput>>> =
            (0..tasks.len()).map(|_| None).collect();
        for _ in 0..tasks.len() {
            match stream.next_completion() {
                Some(c) => out[c.seq as usize] = Some(c.result),
                None => break,
            }
        }
        stream.close();
        out.into_iter()
            .map(|o| {
                o.unwrap_or_else(|| Err(Error::Engine("task never ran: stream ended early".into())))
            })
            .collect()
    }

    /// Graceful shutdown (no-op for local).
    fn shutdown(&self) {}

    /// The cluster's swarm registry — which workers' block caches hold
    /// which manifests — when the backend tracks one. Local clusters
    /// share one process (and one page cache) with the driver, so there
    /// is no swarm to consult and the default `None` stands.
    fn swarm(&self) -> Option<SwarmRegistry> {
        None
    }

    /// Backend name for logs/benches.
    fn backend(&self) -> &'static str;
}

/// Shared state between a [`LocalCluster`] handle and its pool threads.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Signalled when any active stream gains work (or on quit).
    work_ready: Condvar,
}

struct PoolState {
    /// Streams with tasks potentially outstanding; drained streams are
    /// pruned lazily by the workers.
    streams: Vec<Arc<TaskStream>>,
    quit: bool,
}

/// Thread-pool cluster: N *persistent* worker threads, each with its own
/// [`TaskCtx`] / bag cache (mirroring per-executor memory state in
/// Spark). Workers outlive individual jobs — there is no per-batch
/// thread spawn — and multiplex every stream opened on the cluster, so
/// back-to-back jobs reuse warm caches. Worker panics are caught and
/// surfaced as task errors carrying the panic payload.
pub struct LocalCluster {
    registry: OpRegistry,
    pool: Arc<PoolShared>,
    workers: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl LocalCluster {
    /// Build a pool of `workers` persistent threads sharing `registry`,
    /// each with its own [`TaskCtx`] rooted at `artifact_dir`.
    pub fn new(workers: usize, registry: OpRegistry, artifact_dir: &str) -> Self {
        Self::with_faults(workers, registry, artifact_dir, FaultPlan::none())
    }

    /// Test-only flavor of [`LocalCluster::new`]: each pool worker
    /// consults `faults` before executing a pulled task; a scheduled
    /// kill fails that task with a transport error and retires the
    /// thread for good — the in-process equivalent of a worker process
    /// dying mid-task. The pool does not track population, so a plan
    /// must leave at least one worker alive or pending tasks hang.
    pub fn with_faults(
        workers: usize,
        registry: OpRegistry,
        artifact_dir: &str,
        faults: FaultPlan,
    ) -> Self {
        assert!(workers >= 1, "need at least one worker");
        let pool = Arc::new(PoolShared {
            state: Mutex::new(PoolState { streams: Vec::new(), quit: false }),
            work_ready: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let pool = pool.clone();
            let registry = registry.clone();
            let ctx = TaskCtx::new(i, artifact_dir);
            let faults = faults.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("av-simd-worker-{i}"))
                    .spawn(move || pool_worker(pool, registry, ctx, faults))
                    .expect("spawn local worker thread"),
            );
        }
        Self { registry, pool, workers, handles: Mutex::new(handles) }
    }

    /// The operator registry this cluster's workers execute from.
    pub fn registry(&self) -> &OpRegistry {
        &self.registry
    }
}

impl Cluster for LocalCluster {
    fn workers(&self) -> usize {
        self.workers
    }

    fn open_stream(&self) -> Arc<TaskStream> {
        let stream = TaskStream::new();
        let pool = self.pool.clone();
        stream.set_waker(move || {
            // Lock-then-notify so a worker mid-scan cannot miss the wake:
            // it either sees the new task in its scan or is already
            // parked in wait() when the notify lands.
            let _g = pool.state.lock().unwrap();
            pool.work_ready.notify_all();
        });
        let mut st = self.pool.state.lock().unwrap();
        st.streams.push(stream.clone());
        drop(st);
        self.pool.work_ready.notify_all();
        stream
    }

    fn backend(&self) -> &'static str {
        "local"
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        {
            let mut st = self.pool.state.lock().unwrap();
            st.quit = true;
        }
        self.pool.work_ready.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Render a panic payload for the task error (satisfying the scheduler's
/// retry classifier with a real cause instead of a generic failure).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Persistent pool worker: scan active streams for work, run one task,
/// repeat; park on the pool condvar when every stream is idle.
fn pool_worker(pool: Arc<PoolShared>, registry: OpRegistry, ctx: TaskCtx, faults: FaultPlan) {
    loop {
        let work = {
            let mut st = pool.state.lock().unwrap();
            loop {
                if st.quit {
                    return;
                }
                st.streams.retain(|s| !s.drained());
                let found = st
                    .streams
                    .iter()
                    .find_map(|s| s.try_pop().map(|t| (s.clone(), t)));
                match found {
                    Some(w) => break w,
                    None => st = pool.work_ready.wait(st).unwrap(),
                }
            }
        };
        let (stream, (seq, spec, queue_wait)) = work;
        if faults.worker_should_die(ctx.worker_id) {
            // injected worker death: the held task dies with it (a
            // retryable transport error) and the thread never returns
            // to the pool, exactly like a crashed worker process
            stream.complete(
                seq,
                spec,
                Err(Error::Transport(format!(
                    "{FAULT_TAG}: worker {} killed", ctx.worker_id
                ))),
                queue_wait,
                Duration::ZERO,
            );
            return;
        }
        let started = Instant::now();
        // Bracket execution with the thread-local span collector when a
        // trace sink is installed. Local workers share the driver's
        // monotonic clock, so batches merge with offset 0.
        let traced = trace::enabled();
        let t0 = crate::util::mono_nanos();
        if traced {
            trace::begin_task(
                ctx.worker_id as u64,
                trace::TraceCtx {
                    job_id: spec.job_id,
                    task_id: spec.task_id,
                    attempt: spec.attempt,
                },
            );
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            executor::run_task(&ctx, &registry, &spec)
        }))
        .unwrap_or_else(|payload| {
            Err(Error::Engine(format!(
                "task {} worker {} panicked: {}",
                spec.task_id,
                ctx.worker_id,
                panic_message(payload.as_ref())
            )))
        });
        if traced {
            trace::record("task", "", t0, crate::util::mono_nanos().saturating_sub(t0));
            if let Some(batch) = trace::end_task() {
                if let Some(log) = trace::active() {
                    log.absorb(&batch, 0);
                }
            }
        }
        stream.complete(seq, spec, result, queue_wait, started.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    fn count_task(id: u32, n: u64) -> TaskSpec {
        TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops: vec![],
            action: Action::Count,
        }
    }

    #[test]
    fn runs_all_tasks_in_order() {
        let c = LocalCluster::new(4, OpRegistry::with_builtins(), "artifacts");
        let tasks: Vec<TaskSpec> = (0..16).map(|i| count_task(i, (i as u64 + 1) * 10)).collect();
        let results = c.run_tasks(&tasks);
        assert_eq!(results.len(), 16);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), TaskOutput::Count((i as u64 + 1) * 10));
        }
    }

    #[test]
    fn failures_are_per_task() {
        let reg = OpRegistry::with_builtins();
        reg.register("fail_if_small", |_c, _p, records| {
            if records.len() < 5 {
                Err(Error::Engine("too small".into()))
            } else {
                Ok(records)
            }
        });
        let c = LocalCluster::new(2, reg, "artifacts");
        let mk = |id: u32, n: u64| TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops: vec![super::super::plan::OpCall::new("fail_if_small", vec![])],
            action: Action::Count,
        };
        let results = c.run_tasks(&[mk(0, 2), mk(1, 10)]);
        assert!(results[0].is_err());
        assert_eq!(*results[1].as_ref().unwrap(), TaskOutput::Count(10));
    }

    #[test]
    fn single_worker_works() {
        let c = LocalCluster::new(1, OpRegistry::with_builtins(), "artifacts");
        let results = c.run_tasks(&[count_task(0, 5)]);
        assert_eq!(*results[0].as_ref().unwrap(), TaskOutput::Count(5));
    }

    #[test]
    fn worker_panic_is_surfaced_with_payload() {
        let reg = OpRegistry::with_builtins();
        reg.register("blow_up", |_c, _p, _records| -> Result<Vec<Vec<u8>>> {
            panic!("index out of range in op body");
        });
        let c = LocalCluster::new(2, reg, "artifacts");
        let mut t = count_task(7, 3);
        t.ops.push(super::super::plan::OpCall::new("blow_up", vec![]));
        let results = c.run_tasks(std::slice::from_ref(&t));
        let err = results[0].as_ref().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("index out of range in op body"), "payload lost: {msg}");
        assert!(msg.contains("task 7"), "{msg}");
        assert!(err.is_retryable(), "panics must be retry-classifiable");
        // the pool must survive the panic and keep serving tasks
        let again = c.run_tasks(&[count_task(0, 9)]);
        assert_eq!(*again[0].as_ref().unwrap(), TaskOutput::Count(9));
    }

    #[test]
    fn injected_worker_kill_is_retryable_and_job_completes() {
        use super::super::scheduler::run_job;
        let reg = OpRegistry::with_builtins();
        reg.register("sleepy", |_c, _p, records| {
            std::thread::sleep(Duration::from_millis(5));
            Ok(records)
        });
        // worker 0 dies on its very first pull; worker 1 finishes the job
        let faults = FaultPlan::none().kill_worker(0, 0);
        let c = LocalCluster::with_faults(2, reg, "artifacts", faults);
        let mk = |id: u32| TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: 10 },
            ops: vec![super::super::plan::OpCall::new("sleepy", vec![])],
            action: Action::Count,
        };
        let tasks: Vec<TaskSpec> = (0..8).map(mk).collect();
        let (outs, report) = run_job(&c, tasks, 2).unwrap();
        assert_eq!(outs.len(), 8);
        assert!(outs.iter().all(|o| *o == TaskOutput::Count(10)));
        assert!(report.retries >= 1, "the killed worker's task must be retried");
    }

    #[test]
    fn pool_survives_many_sequential_batches() {
        // no per-batch thread spawn: the same pool serves every batch
        let c = LocalCluster::new(3, OpRegistry::with_builtins(), "artifacts");
        for round in 0..10u64 {
            let tasks: Vec<TaskSpec> =
                (0..6).map(|i| count_task(i, round + 1)).collect();
            let results = c.run_tasks(&tasks);
            assert!(results
                .iter()
                .all(|r| *r.as_ref().unwrap() == TaskOutput::Count(round + 1)));
        }
    }

    #[test]
    fn concurrent_streams_share_the_pool() {
        let c = Arc::new(LocalCluster::new(4, OpRegistry::with_builtins(), "artifacts"));
        let mut joins = Vec::new();
        for j in 0..4u64 {
            let c = c.clone();
            joins.push(std::thread::spawn(move || {
                let tasks: Vec<TaskSpec> =
                    (0..8).map(|i| count_task(i, j * 100 + 1)).collect();
                let results = c.run_tasks(&tasks);
                assert!(results
                    .iter()
                    .all(|r| *r.as_ref().unwrap() == TaskOutput::Count(j * 100 + 1)));
            }));
        }
        for h in joins {
            h.join().unwrap();
        }
    }
}
