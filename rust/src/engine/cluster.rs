//! Cluster abstraction + the local (thread-pool) implementation.
//!
//! The paper's platform runs on a Spark cluster; ours runs on either
//! worker threads in-process ([`LocalCluster`], the default and the unit
//! under test for scalability benches) or spawned worker processes over
//! TCP ([`super::remote::StandaloneCluster`]). Both present the same
//! [`Cluster`] trait: submit a batch of tasks, get per-task results back
//! in order.

use super::executor;
use super::ops::{OpRegistry, TaskCtx};
use super::plan::{TaskOutput, TaskSpec};
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::Mutex;

/// A set of workers that can execute task batches.
pub trait Cluster: Send + Sync {
    /// Number of workers.
    fn workers(&self) -> usize;

    /// Execute all tasks, returning results in task order. Individual
    /// task failures are returned as `Err` entries (the scheduler
    /// retries); infrastructure failures may fail the whole batch.
    fn run_tasks(&self, tasks: &[TaskSpec]) -> Vec<Result<TaskOutput>>;

    /// Graceful shutdown (no-op for local).
    fn shutdown(&self) {}

    /// Backend name for logs/benches.
    fn backend(&self) -> &'static str;
}

/// Thread-pool cluster: N persistent worker contexts, each with its own
/// bag cache (mirroring per-executor memory state in Spark).
pub struct LocalCluster {
    registry: OpRegistry,
    ctxs: Vec<TaskCtx>,
}

impl LocalCluster {
    pub fn new(workers: usize, registry: OpRegistry, artifact_dir: &str) -> Self {
        assert!(workers >= 1, "need at least one worker");
        let ctxs = (0..workers).map(|i| TaskCtx::new(i, artifact_dir)).collect();
        Self { registry, ctxs }
    }

    pub fn registry(&self) -> &OpRegistry {
        &self.registry
    }
}

impl Cluster for LocalCluster {
    fn workers(&self) -> usize {
        self.ctxs.len()
    }

    fn run_tasks(&self, tasks: &[TaskSpec]) -> Vec<Result<TaskOutput>> {
        let queue: Mutex<VecDeque<usize>> = Mutex::new((0..tasks.len()).collect());
        let results: Vec<Mutex<Option<Result<TaskOutput>>>> =
            (0..tasks.len()).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|scope| {
            for ctx in &self.ctxs {
                scope.spawn(|| loop {
                    let idx = match queue.lock().unwrap().pop_front() {
                        Some(i) => i,
                        None => break,
                    };
                    let res = executor::run_task(ctx, &self.registry, &tasks[idx]);
                    *results[idx].lock().unwrap() = Some(res);
                });
            }
        });

        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .unwrap_or_else(|| Err(Error::Engine("task never ran".into())))
            })
            .collect()
    }

    fn backend(&self) -> &'static str {
        "local"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    fn count_task(id: u32, n: u64) -> TaskSpec {
        TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops: vec![],
            action: Action::Count,
        }
    }

    #[test]
    fn runs_all_tasks_in_order() {
        let c = LocalCluster::new(4, OpRegistry::with_builtins(), "artifacts");
        let tasks: Vec<TaskSpec> = (0..16).map(|i| count_task(i, (i as u64 + 1) * 10)).collect();
        let results = c.run_tasks(&tasks);
        assert_eq!(results.len(), 16);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), TaskOutput::Count((i as u64 + 1) * 10));
        }
    }

    #[test]
    fn failures_are_per_task() {
        let reg = OpRegistry::with_builtins();
        reg.register("fail_if_small", |_c, _p, records| {
            if records.len() < 5 {
                Err(Error::Engine("too small".into()))
            } else {
                Ok(records)
            }
        });
        let c = LocalCluster::new(2, reg, "artifacts");
        let mk = |id: u32, n: u64| TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops: vec![super::super::plan::OpCall::new("fail_if_small", vec![])],
            action: Action::Count,
        };
        let results = c.run_tasks(&[mk(0, 2), mk(1, 10)]);
        assert!(results[0].is_err());
        assert_eq!(*results[1].as_ref().unwrap(), TaskOutput::Count(10));
    }

    #[test]
    fn single_worker_works() {
        let c = LocalCluster::new(1, OpRegistry::with_builtins(), "artifacts");
        let results = c.run_tasks(&[count_task(0, 5)]);
        assert_eq!(*results[0].as_ref().unwrap(), TaskOutput::Count(5));
    }
}
