//! Distributed task tracing: per-stage [`Span`]s recorded on workers,
//! shipped back piggybacked on task replies (the `BlockAd` pattern),
//! merged with driver-side scheduling events into a [`TraceLog`], and
//! exported as a Chrome `trace_event` JSON timeline plus a per-stage
//! summary in `JobReport`.
//!
//! ## Recording model
//!
//! A task executes on exactly one thread, so the recorder is a
//! thread-local [`SpanBatch`] collector bracketed by
//! [`begin_task`]/[`end_task`]. Instrumentation points call
//! [`span`]/[`span_detail`] (one span per call — task-level stages) or
//! [`accum`]/[`accum_detail`] (per-frame hot stages like the perception
//! phases and per-peer block fetches, folded into one span per
//! `(name, detail)` with a `count`), all of which are no-ops costing a
//! TLS load and a branch when no collector is installed.
//!
//! ## Clocks
//!
//! Span timestamps are `util::mono_nanos()` — monotonic nanoseconds
//! since *that process's* start, immune to wall-clock steps. Each
//! driver→worker connection estimates a clock offset from the `Hello`
//! round trip (the worker's `HelloOk` carries its `mono_nanos`; the
//! driver brackets the exchange with its own reads and takes the
//! midpoint), and [`TraceLog::absorb`] shifts worker spans onto the
//! driver's timeline with it. Local (in-process) clusters share the
//! driver's clock, so their offset is zero.
//!
//! ## Enabling
//!
//! Tracing is off unless a [`TraceLog`] is installed as the process's
//! active sink ([`install`], returning a guard that uninstalls on
//! drop). While installed, feeders dispatch `RunTaskTraced` frames
//! instead of `RunTask` and local pool workers bracket execution with
//! the collector; either way span batches land in the same log. The
//! trace is *observability only*: report payload bytes are identical
//! with tracing on or off.

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::mono_nanos;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Wire/version tag for [`SpanBatch::encode`].
pub const SPAN_BATCH_VERSION: u8 = 1;

/// Identity of a dispatched task attempt, stamped on every span batch
/// and driver event so merged timelines stay attributable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceCtx {
    /// Job the task belongs to.
    pub job_id: u64,
    /// Task sequence id within the job.
    pub task_id: u32,
    /// Attempt number (0 = first execution).
    pub attempt: u32,
}

/// One named, timed stage of task execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Stage name (`queue_wait`, `block_fetch`, `icp`, …).
    pub name: String,
    /// Optional qualifier (peer address, op name); empty when unused.
    pub detail: String,
    /// Recorder-clock start (`mono_nanos` of the recording process).
    pub start_ns: u64,
    /// Duration in nanoseconds (summed across calls for accumulated
    /// spans).
    pub dur_ns: u64,
    /// Number of folded observations (1 for plain spans).
    pub count: u64,
}

/// Every span one task attempt recorded, plus the identity needed to
/// merge it: the payload of the `TaskTrace` RPC frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanBatch {
    /// Recording worker's id (`u64::MAX` when unknown).
    pub worker_id: u64,
    /// The task attempt these spans belong to.
    pub ctx: TraceCtx,
    /// Recorded spans in completion order.
    pub spans: Vec<Span>,
}

impl SpanBatch {
    /// Serialize to the versioned `TaskTrace` wire form.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(SPAN_BATCH_VERSION);
        w.put_varint(self.worker_id);
        w.put_varint(self.ctx.job_id);
        w.put_varint(self.ctx.task_id as u64);
        w.put_varint(self.ctx.attempt as u64);
        w.put_varint(self.spans.len() as u64);
        for s in &self.spans {
            w.put_str(&s.name);
            w.put_str(&s.detail);
            w.put_varint(s.start_ns);
            w.put_varint(s.dur_ns);
            w.put_varint(s.count);
        }
        w.into_vec()
    }

    /// Decode a `TaskTrace` payload; rejects unknown versions and any
    /// truncated or trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        let ver = r.get_u8()?;
        if ver != SPAN_BATCH_VERSION {
            return Err(Error::Engine(format!(
                "span batch version {ver} unsupported (want {SPAN_BATCH_VERSION})"
            )));
        }
        let worker_id = r.get_varint()?;
        let ctx = TraceCtx {
            job_id: r.get_varint()?,
            task_id: u32::try_from(r.get_varint()?)
                .map_err(|_| Error::Engine("span batch task_id overflows u32".into()))?,
            attempt: u32::try_from(r.get_varint()?)
                .map_err(|_| Error::Engine("span batch attempt overflows u32".into()))?,
        };
        let n = r.get_varint()? as usize;
        let mut spans = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            spans.push(Span {
                name: r.get_str()?,
                detail: r.get_str()?,
                start_ns: r.get_varint()?,
                dur_ns: r.get_varint()?,
                count: r.get_varint()?,
            });
        }
        if !r.is_empty() {
            return Err(Error::Engine(format!(
                "span batch has {} trailing bytes",
                r.remaining()
            )));
        }
        Ok(SpanBatch { worker_id, ctx, spans })
    }
}

// ------------------------------------------------------- task recorder

struct Collector {
    worker_id: u64,
    ctx: TraceCtx,
    spans: Vec<Span>,
    // (name, detail) → (first start, total dur, count)
    agg: BTreeMap<(String, String), (u64, u64, u64)>,
}

thread_local! {
    static COLLECTOR: RefCell<Option<Collector>> = const { RefCell::new(None) };
}

/// Install the thread-local span collector for one task attempt. Must
/// be paired with [`end_task`]; a second `begin_task` on the same
/// thread replaces the first (a stale collector from a panicked task
/// must not leak spans into the next one).
pub fn begin_task(worker_id: u64, ctx: TraceCtx) {
    COLLECTOR.with(|c| {
        *c.borrow_mut() = Some(Collector {
            worker_id,
            ctx,
            spans: Vec::new(),
            agg: BTreeMap::new(),
        });
    });
}

/// Tear down the thread-local collector, folding accumulated stages
/// into spans, and return the batch. `None` when no collector was
/// installed.
pub fn end_task() -> Option<SpanBatch> {
    COLLECTOR.with(|c| c.borrow_mut().take()).map(|col| {
        let mut spans = col.spans;
        for ((name, detail), (start, dur, count)) in col.agg {
            spans.push(Span { name, detail, start_ns: start, dur_ns: dur, count });
        }
        spans.sort_by_key(|s| s.start_ns);
        SpanBatch { worker_id: col.worker_id, ctx: col.ctx, spans }
    })
}

/// True when the current thread is recording a task (instrumentation's
/// fast-path check).
pub fn task_active() -> bool {
    COLLECTOR.with(|c| c.borrow().is_some())
}

/// Push an already-measured span onto the current collector (used where
/// start/end are measured outside a closure). No-op when not recording.
pub fn record(name: &str, detail: &str, start_ns: u64, dur_ns: u64) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            col.spans.push(Span {
                name: name.to_string(),
                detail: detail.to_string(),
                start_ns,
                dur_ns,
                count: 1,
            });
        }
    });
}

/// Time `f` as one named span on the current task. Zero-allocation
/// pass-through when not recording.
pub fn span<T>(name: &str, f: impl FnOnce() -> T) -> T {
    span_detail(name, "", f)
}

/// [`span`] with a qualifier (peer address, op name, …).
pub fn span_detail<T>(name: &str, detail: &str, f: impl FnOnce() -> T) -> T {
    if !task_active() {
        return f();
    }
    let t0 = mono_nanos();
    let out = f();
    record(name, detail, t0, mono_nanos().saturating_sub(t0));
    out
}

/// Time `f` into the per-`(name, detail)` accumulator — for stages that
/// run once per frame/block, folded into a single span with a `count`
/// so batches stay small no matter how many frames a slice replays.
pub fn accum<T>(name: &str, f: impl FnOnce() -> T) -> T {
    accum_detail(name, "", f)
}

/// [`accum`] with a qualifier.
pub fn accum_detail<T>(name: &str, detail: &str, f: impl FnOnce() -> T) -> T {
    if !task_active() {
        return f();
    }
    let t0 = mono_nanos();
    let out = f();
    let dur = mono_nanos().saturating_sub(t0);
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            let entry = col
                .agg
                .entry((name.to_string(), detail.to_string()))
                .or_insert((t0, 0, 0));
            entry.1 = entry.1.saturating_add(dur);
            entry.2 += 1;
        }
    });
    out
}

// ----------------------------------------------------------- TraceLog

/// One merged timeline entry: a worker span (aligned onto the driver's
/// clock) or a driver-side scheduling event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Recording worker id; `None` for driver-side events.
    pub worker: Option<u64>,
    /// Stage/event name.
    pub name: String,
    /// Optional qualifier.
    pub detail: String,
    /// The task attempt (zeroed for job-level events).
    pub ctx: TraceCtx,
    /// Driver-clock start in nanoseconds (`util::mono_nanos`).
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Folded observation count (1 for plain spans and events).
    pub count: u64,
}

/// Aggregate time spent in one stage across a whole job — the
/// per-stage summary surfaced in `JobReport`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageStat {
    /// Stage name.
    pub name: String,
    /// Total recorded duration across all tasks.
    pub total: Duration,
    /// Total folded observation count.
    pub count: u64,
}

/// Render a stage summary as indented text lines (biggest stage
/// first) — what `--trace` prints under the job report.
pub fn render_stages(stages: &[StageStat]) -> String {
    let mut out = String::new();
    for s in stages {
        out.push_str(&format!(
            "  {:<22} {:>10.3}ms  x{}\n",
            s.name,
            s.total.as_secs_f64() * 1e3,
            s.count
        ));
    }
    out
}

/// Driver-side merged trace: worker span batches (clock-aligned) plus
/// driver scheduling events, exportable as Chrome `trace_event` JSON.
#[derive(Default)]
pub struct TraceLog {
    events: Mutex<Vec<TraceEvent>>,
}

impl TraceLog {
    /// An empty log behind an `Arc`, ready to [`install`].
    pub fn new() -> Arc<TraceLog> {
        Arc::new(TraceLog::default())
    }

    /// Record a driver-side event (submit, queue_wait, task_wall,
    /// retry, speculate) on the driver's own clock.
    pub fn driver_event(&self, name: &str, ctx: TraceCtx, start_ns: u64, dur_ns: u64) {
        self.events.lock().unwrap().push(TraceEvent {
            worker: None,
            name: name.to_string(),
            detail: String::new(),
            ctx,
            start_ns,
            dur_ns,
            count: 1,
        });
    }

    /// Merge a worker span batch, shifting its recorder-clock
    /// timestamps onto the driver's clock by `offset_ns` (the
    /// handshake round-trip estimate; 0 for in-process workers).
    pub fn absorb(&self, batch: &SpanBatch, offset_ns: i64) {
        let mut events = self.events.lock().unwrap();
        for s in &batch.spans {
            let start = (s.start_ns as i64).saturating_add(offset_ns).max(0) as u64;
            events.push(TraceEvent {
                worker: Some(batch.worker_id),
                name: s.name.clone(),
                detail: s.detail.clone(),
                ctx: batch.ctx,
                start_ns: start,
                dur_ns: s.dur_ns,
                count: s.count,
            });
        }
    }

    /// Snapshot of every merged event (unordered).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Number of merged events so far.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-stage totals for `job_id` (or every job when `None`),
    /// biggest stage first — the `JobReport` summary.
    pub fn stage_totals(&self, job_id: Option<u64>) -> Vec<StageStat> {
        let mut agg: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for e in self.events.lock().unwrap().iter() {
            if job_id.is_some_and(|j| e.ctx.job_id != j) {
                continue;
            }
            let entry = agg.entry(e.name.clone()).or_insert((0, 0));
            entry.0 = entry.0.saturating_add(e.dur_ns);
            entry.1 += e.count;
        }
        let mut stages: Vec<StageStat> = agg
            .into_iter()
            .map(|(name, (ns, count))| StageStat {
                name,
                total: Duration::from_nanos(ns),
                count,
            })
            .collect();
        stages.sort_by(|a, b| b.total.cmp(&a.total).then(a.name.cmp(&b.name)));
        stages
    }

    /// Render the merged timeline as Chrome `trace_event` JSON
    /// (load via `chrome://tracing` or <https://ui.perfetto.dev>).
    /// Events are complete (`"ph":"X"`) with microsecond timestamps;
    /// `pid` is the job id and `tid` lanes are workers (driver = 0).
    pub fn chrome_json(&self) -> String {
        let mut events = self.events();
        events.sort_by_key(|e| (e.start_ns, e.dur_ns));
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
        for (i, e) in events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            let tid = e.worker.map(|w| w.saturating_add(1)).unwrap_or(0);
            let cat = if e.worker.is_some() { "worker" } else { "driver" };
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
                 \"pid\":{},\"tid\":{},\"args\":{{\"task\":{},\"attempt\":{},\"count\":{}\
                 {}{}}}}}",
                json_escape(&e.name),
                cat,
                e.start_ns as f64 / 1e3,
                e.dur_ns as f64 / 1e3,
                e.ctx.job_id,
                tid,
                e.ctx.task_id,
                e.ctx.attempt,
                e.count,
                if e.detail.is_empty() { "" } else { ",\"detail\":\"" },
                if e.detail.is_empty() {
                    String::new()
                } else {
                    format!("{}\"", json_escape(&e.detail))
                },
            ));
        }
        out.push_str("\n]}\n");
        out
    }

    /// Write the Chrome JSON to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.chrome_json()).map_err(|e| {
            Error::Engine(format!("write trace {}: {e}", path.display()))
        })
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// -------------------------------------------------------- active sink

fn active_slot() -> &'static Mutex<Option<Arc<TraceLog>>> {
    static ACTIVE: OnceLock<Mutex<Option<Arc<TraceLog>>>> = OnceLock::new();
    ACTIVE.get_or_init(|| Mutex::new(None))
}

/// Uninstalls the active [`TraceLog`] when dropped (see [`install`]).
pub struct TraceGuard(());

impl Drop for TraceGuard {
    fn drop(&mut self) {
        *active_slot().lock().unwrap() = None;
    }
}

/// Install `log` as the process's active trace sink: feeders start
/// dispatching traced tasks and schedulers start recording driver
/// events into it. Returns a guard that uninstalls on drop. Installing
/// while another log is active replaces it (last caller wins) — runs
/// that trace concurrently should share one log.
pub fn install(log: Arc<TraceLog>) -> TraceGuard {
    *active_slot().lock().unwrap() = Some(log);
    TraceGuard(())
}

/// The active sink, if tracing is on.
pub fn active() -> Option<Arc<TraceLog>> {
    active_slot().lock().unwrap().clone()
}

/// True when a trace sink is installed (the dispatch-path check).
pub fn enabled() -> bool {
    active_slot().lock().unwrap().is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_batch() -> SpanBatch {
        SpanBatch {
            worker_id: 3,
            ctx: TraceCtx { job_id: 0xBA95, task_id: 17, attempt: 1 },
            spans: vec![
                Span {
                    name: "block_fetch".into(),
                    detail: "127.0.0.1:7200".into(),
                    start_ns: 1_000,
                    dur_ns: 250,
                    count: 4,
                },
                Span {
                    name: "icp".into(),
                    detail: String::new(),
                    start_ns: 2_000,
                    dur_ns: 9_999,
                    count: 12,
                },
            ],
        }
    }

    #[test]
    fn span_batch_roundtrips() {
        let b = sample_batch();
        assert_eq!(SpanBatch::decode(&b.encode()).unwrap(), b);
        let empty = SpanBatch {
            worker_id: u64::MAX,
            ctx: TraceCtx::default(),
            spans: vec![],
        };
        assert_eq!(SpanBatch::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn span_batch_decode_rejects_bad_inputs() {
        let full = sample_batch().encode();
        for cut in 1..full.len() {
            assert!(
                SpanBatch::decode(&full[..cut]).is_err(),
                "decode accepted truncation at {cut}/{}",
                full.len()
            );
        }
        let mut wrong = full.clone();
        wrong[0] = SPAN_BATCH_VERSION + 1;
        assert!(SpanBatch::decode(&wrong).is_err());
        let mut trailing = full;
        trailing.push(0);
        assert!(SpanBatch::decode(&trailing).is_err());
    }

    #[test]
    fn collector_records_spans_and_accumulates() {
        let ctx = TraceCtx { job_id: 9, task_id: 2, attempt: 0 };
        begin_task(7, ctx);
        assert!(task_active());
        span("decode", || std::thread::sleep(Duration::from_millis(1)));
        for _ in 0..5 {
            accum("classify", || {});
        }
        accum_detail("block_fetch", "peer-a", || {});
        accum_detail("block_fetch", "peer-b", || {});
        let batch = end_task().expect("batch");
        assert!(!task_active());
        assert_eq!(batch.worker_id, 7);
        assert_eq!(batch.ctx, ctx);
        let find = |n: &str, d: &str| {
            batch
                .spans
                .iter()
                .find(|s| s.name == n && s.detail == d)
                .unwrap_or_else(|| panic!("missing span {n}/{d}: {:?}", batch.spans))
                .clone()
        };
        assert!(find("decode", "").dur_ns >= 1_000_000);
        assert_eq!(find("classify", "").count, 5);
        assert_eq!(find("block_fetch", "peer-a").count, 1);
        assert_eq!(find("block_fetch", "peer-b").count, 1);
        // second end_task is a no-op
        assert!(end_task().is_none());
        // spans outside a task are dropped, not panicking
        span("orphan", || {});
    }

    #[test]
    fn trace_log_merges_aligns_and_summarizes() {
        let log = TraceLog::new();
        let ctx = TraceCtx { job_id: 5, task_id: 0, attempt: 0 };
        log.driver_event("queue_wait", ctx, 100, 50);
        let batch = SpanBatch {
            worker_id: 1,
            ctx,
            spans: vec![Span {
                name: "icp".into(),
                detail: String::new(),
                start_ns: 1_000,
                dur_ns: 300,
                count: 3,
            }],
        };
        // worker clock runs 1000ns behind the driver: offset +1000
        log.absorb(&batch, 1_000);
        // an unrelated job the summary must filter out
        log.driver_event("queue_wait", TraceCtx { job_id: 6, ..ctx }, 0, 999_999);
        let events = log.events();
        let icp = events.iter().find(|e| e.name == "icp").unwrap();
        assert_eq!(icp.start_ns, 2_000, "offset must shift worker spans");
        assert_eq!(icp.worker, Some(1));
        let stages = log.stage_totals(Some(5));
        assert_eq!(stages.len(), 2);
        let icp_stage = stages.iter().find(|s| s.name == "icp").unwrap();
        assert_eq!(icp_stage.total, Duration::from_nanos(300));
        assert_eq!(icp_stage.count, 3);
        assert!(!render_stages(&stages).is_empty());
        // negative offsets clamp at zero instead of wrapping
        let log2 = TraceLog::new();
        log2.absorb(&batch, -2_000_000);
        assert_eq!(log2.events()[0].start_ns, 0);
    }

    #[test]
    fn chrome_json_is_well_formed() {
        let log = TraceLog::new();
        let ctx = TraceCtx { job_id: 1, task_id: 3, attempt: 0 };
        log.driver_event("submit", ctx, 10, 0);
        log.absorb(
            &SpanBatch {
                worker_id: 0,
                ctx,
                spans: vec![Span {
                    name: "op:\"quoted\"".into(),
                    detail: "a\\b".into(),
                    start_ns: 500,
                    dur_ns: 100,
                    count: 1,
                }],
            },
            0,
        );
        let json = log.chrome_json();
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("op:\\\"quoted\\\""), "escaping: {json}");
        assert!(json.contains("a\\\\b"), "escaping: {json}");
        // driver lane 0, worker 0 lane 1
        assert!(json.contains("\"tid\":0"));
        assert!(json.contains("\"tid\":1"));
        // balanced braces/brackets — cheap well-formedness proxy
        let (mut braces, mut brackets, mut in_str, mut esc) = (0i64, 0i64, false, false);
        for ch in json.chars() {
            if esc {
                esc = false;
                continue;
            }
            match ch {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' if !in_str => braces += 1,
                '}' if !in_str => braces -= 1,
                '[' if !in_str => brackets += 1,
                ']' if !in_str => brackets -= 1,
                _ => {}
            }
        }
        assert_eq!((braces, brackets, in_str), (0, 0, false), "unbalanced JSON");
    }

    #[test]
    fn install_guard_scopes_the_active_sink() {
        // serialized with other sink users via the global-lock pattern:
        // this test is the only unit test here touching the global slot
        let log = TraceLog::new();
        {
            let _guard = install(Arc::clone(&log));
            assert!(enabled());
            assert!(active().is_some());
        }
        assert!(!enabled(), "guard drop must uninstall");
        assert!(active().is_none());
    }
}
