//! Durable aggregation checkpoints for crash-resumable jobs.
//!
//! A long job's driver is a single point of total loss: workers can die
//! and be retried, but if the *driver* process crashes every resolved
//! slice is thrown away. This module fixes that by periodically folding
//! resolved task outputs into a [`CheckpointRecord`] — a versioned,
//! CRC-guarded snapshot written atomically into a [`BlockStore`] under a
//! deterministic name — so a restarted driver can load the record,
//! cross-check it against its freshly recomputed plan, pre-fill the
//! already-resolved slots, and resubmit only the remainder.
//!
//! The record keys entries by **slot**, a plan-stable identifier chosen
//! by the job driver (e.g. a replay slice index or a sweep case offset),
//! *not* by scheduler sequence number: sequence numbers restart from 0
//! on resume, slots don't. Entry payloads are raw
//! [`TaskOutput::encode`] bytes, so the checkpoint layer never needs to
//! understand job-specific verdict formats.
//!
//! Wire format (single buffer, see ARCHITECTURE.md):
//!
//! ```text
//! u8 version (=1) ‖ u64 job_id ‖ [u8; 32] fingerprint ‖ bytes meta
//!   ‖ varint n ‖ n × (varint slot ‖ bytes payload) ‖ u32 crc32(body)
//! ```

use std::collections::BTreeMap;

use crate::engine::plan::TaskOutput;
use crate::error::{Error, Result};
use crate::storage::BlockStore;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::crc32;

/// Current checkpoint record wire version.
pub const CHECKPOINT_VERSION: u8 = 1;

/// A versioned, CRC-guarded snapshot of a job's resolved task outputs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckpointRecord {
    /// Deterministic job id (e.g. `REPLAY_JOB_ID`); cross-checked on
    /// resume so a sweep checkpoint can't be fed to a replay driver.
    pub job_id: u64,
    /// Plan fingerprint — a sha256 over everything that determines the
    /// slot layout (spec bytes, input identity, slice boundaries). A
    /// resumed driver recomputes it and refuses a mismatched record.
    pub fingerprint: [u8; 32],
    /// Opaque driver-owned metadata (free-form, may be empty).
    pub meta: Vec<u8>,
    /// Resolved outputs keyed by plan-stable slot.
    pub entries: BTreeMap<u64, Vec<u8>>,
}

impl CheckpointRecord {
    /// Serialize to the CRC-guarded wire form.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(CHECKPOINT_VERSION);
        w.put_u64(self.job_id);
        w.put_raw(&self.fingerprint);
        w.put_bytes(&self.meta);
        w.put_varint(self.entries.len() as u64);
        for (slot, payload) in &self.entries {
            w.put_varint(*slot);
            w.put_bytes(payload);
        }
        let crc = crc32::hash(w.as_slice());
        w.put_u32(crc);
        w.into_vec()
    }

    /// Decode and verify a [`CheckpointRecord::encode`] buffer.
    ///
    /// Truncation, trailing garbage, a CRC mismatch, or an unknown
    /// version all fail with [`Error::Corrupt`] — a damaged checkpoint
    /// is reported, never silently treated as partial progress.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        if buf.len() < 4 {
            return Err(Error::Corrupt(format!(
                "checkpoint record truncated: {} byte(s), need at least 4",
                buf.len()
            )));
        }
        let (body, tail) = buf.split_at(buf.len() - 4);
        let stored = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let actual = crc32::hash(body);
        if stored != actual {
            return Err(Error::Corrupt(format!(
                "checkpoint record CRC mismatch: stored {stored:#010x}, computed {actual:#010x}"
            )));
        }
        let mut r = ByteReader::new(body);
        let version = r.get_u8()?;
        if version != CHECKPOINT_VERSION {
            return Err(Error::Corrupt(format!(
                "unsupported checkpoint record version {version} (expected {CHECKPOINT_VERSION})"
            )));
        }
        let job_id = r.get_u64()?;
        let mut fingerprint = [0u8; 32];
        fingerprint.copy_from_slice(r.get_raw(32)?);
        let meta = r.get_bytes_vec()?;
        let n = r.get_varint()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let slot = r.get_varint()?;
            let payload = r.get_bytes_vec()?;
            if entries.insert(slot, payload).is_some() {
                return Err(Error::Corrupt(format!(
                    "checkpoint record repeats slot {slot}"
                )));
            }
        }
        if !r.is_empty() {
            return Err(Error::Corrupt(format!(
                "checkpoint record has {} trailing byte(s)",
                r.remaining()
            )));
        }
        Ok(Self { job_id, fingerprint, meta, entries })
    }
}

/// Checkpointing configuration for a job driver (the `--checkpoint`
/// flag / ClusterSpec `[checkpoint]` section).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointConfig {
    /// Block-store root directory the record is written into.
    pub root: String,
    /// Flush cadence: persist after this many newly resolved outputs
    /// (1 = flush on every completion).
    pub every: usize,
    /// Load an existing record and resume instead of starting fresh.
    pub resume: bool,
}

impl CheckpointConfig {
    /// Checkpoint into `root`, flushing every completion, not resuming.
    pub fn new(root: impl Into<String>) -> Self {
        Self { root: root.into(), every: 1, resume: false }
    }
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        Self::new("checkpoints")
    }
}

/// Deterministic store name for a job's checkpoint record: the job id
/// plus a fingerprint prefix, so re-running the same plan against the
/// same store finds its own record and distinct plans never collide.
pub fn checkpoint_name(job_id: u64, fingerprint: &[u8; 32]) -> String {
    let mut prefix = String::with_capacity(16);
    for b in &fingerprint[..8] {
        prefix.push_str(&format!("{b:02x}"));
    }
    format!("ckpt_{job_id:x}_{prefix}")
}

/// Incrementally folds resolved task outputs into a durable
/// [`CheckpointRecord`].
///
/// The scheduler calls [`Checkpointer::observe`] once per resolved
/// output (before the provider consumes it); every `every` new entries
/// the record is re-encoded and written atomically to the store under
/// its deterministic [`checkpoint_name`]. Because the store's named
/// `put` is temp-file + rename, a crash mid-flush leaves the previous
/// record intact — the checkpoint is always a consistent prefix of the
/// job's progress, never a torn write.
#[derive(Debug)]
pub struct Checkpointer {
    store: BlockStore,
    name: String,
    record: CheckpointRecord,
    every: usize,
    unflushed: usize,
}

impl Checkpointer {
    /// Open (or create) the checkpoint for `(job_id, fingerprint)` in
    /// `cfg.root`.
    ///
    /// With `cfg.resume` set and a record present under the
    /// deterministic name, the record is loaded and cross-checked: a
    /// job-id or fingerprint mismatch (a record written by a different
    /// plan) is an error, not a silent restart. Without `resume`, any
    /// existing record is ignored and will be overwritten on first
    /// flush.
    pub fn open(cfg: &CheckpointConfig, job_id: u64, fingerprint: [u8; 32]) -> Result<Self> {
        let store = BlockStore::open(&cfg.root)?;
        let name = checkpoint_name(job_id, &fingerprint);
        let record = if cfg.resume && store.exists(&name) {
            let rec = CheckpointRecord::decode(&store.get(&name)?)?;
            if rec.job_id != job_id {
                return Err(Error::Engine(format!(
                    "checkpoint '{name}' belongs to job {:#x}, not {job_id:#x}",
                    rec.job_id
                )));
            }
            if rec.fingerprint != fingerprint {
                return Err(Error::Engine(format!(
                    "checkpoint '{name}' was written for a different plan \
                     (spec, input, or slice layout changed); refusing to resume"
                )));
            }
            rec
        } else {
            CheckpointRecord { job_id, fingerprint, ..Default::default() }
        };
        Ok(Self { store, name, record, every: cfg.every.max(1), unflushed: 0 })
    }

    /// Store name the record is persisted under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Resolved entries loaded or observed so far, keyed by slot.
    pub fn resolved(&self) -> &BTreeMap<u64, Vec<u8>> {
        &self.record.entries
    }

    /// Number of resolved entries.
    pub fn len(&self) -> usize {
        self.record.entries.len()
    }

    /// True when no entries have been resolved yet.
    pub fn is_empty(&self) -> bool {
        self.record.entries.is_empty()
    }

    /// True when `slot` already has a resolved output.
    pub fn contains(&self, slot: u64) -> bool {
        self.record.entries.contains_key(&slot)
    }

    /// Record a pre-encoded payload without triggering a cadence flush
    /// (used to seed e.g. a calibration output; call
    /// [`Checkpointer::flush`] explicitly afterwards).
    pub fn insert(&mut self, slot: u64, payload: Vec<u8>) {
        self.record.entries.insert(slot, payload);
        self.unflushed += 1;
    }

    /// Fold one resolved task output into the record, flushing to the
    /// store when the cadence is due.
    pub fn observe(&mut self, slot: u64, out: &TaskOutput) -> Result<()> {
        self.insert(slot, out.encode());
        if self.unflushed >= self.every {
            self.flush()?;
        }
        Ok(())
    }

    /// Persist the record now (atomic temp-file + rename via the store).
    pub fn flush(&mut self) -> Result<()> {
        self.store.put(&self.name, &self.record.encode())?;
        self.unflushed = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen};

    fn sample_record(job_id: u64) -> CheckpointRecord {
        let mut entries = BTreeMap::new();
        entries.insert(0, TaskOutput::Count(7).encode());
        entries.insert(3, TaskOutput::Records(vec![vec![1, 2, 3]]).encode());
        CheckpointRecord { job_id, fingerprint: [0xAB; 32], meta: b"m".to_vec(), entries }
    }

    #[test]
    fn roundtrip_and_payloads_survive() {
        let rec = sample_record(42);
        let back = CheckpointRecord::decode(&rec.encode()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(
            TaskOutput::decode(&back.entries[&0]).unwrap(),
            TaskOutput::Count(7)
        );
    }

    #[test]
    fn prop_roundtrip() {
        check(
            "checkpoint record roundtrips",
            |rng| {
                let mut entries = BTreeMap::new();
                for _ in 0..rng.below(16) {
                    entries.insert(rng.below(1 << 20), gen::bytes(rng, 64));
                }
                let mut fp = [0u8; 32];
                rng.fill_bytes(&mut fp);
                CheckpointRecord {
                    job_id: rng.below(u64::MAX),
                    fingerprint: fp,
                    meta: gen::bytes(rng, 32),
                    entries,
                }
            },
            |rec| CheckpointRecord::decode(&rec.encode()).as_ref() == Ok(rec),
        );
    }

    #[test]
    fn prop_truncation_rejected() {
        check(
            "any strict prefix of a checkpoint record is rejected",
            |rng| {
                let rec = sample_record(rng.below(1 << 32));
                let buf = rec.encode();
                let cut = rng.below(buf.len() as u64) as usize;
                (buf, cut)
            },
            |(buf, cut)| {
                matches!(CheckpointRecord::decode(&buf[..*cut]), Err(Error::Corrupt(_)))
            },
        );
    }

    #[test]
    fn prop_bitflip_rejected() {
        check(
            "a single flipped bit fails the CRC (or the version check)",
            |rng| {
                let buf = sample_record(9).encode();
                let byte = rng.below(buf.len() as u64) as usize;
                let bit = rng.below(8) as u8;
                (buf, byte, bit)
            },
            |(buf, byte, bit)| {
                let mut damaged = buf.clone();
                damaged[*byte] ^= 1 << bit;
                CheckpointRecord::decode(&damaged).is_err()
            },
        );
    }

    #[test]
    fn trailing_bytes_rejected() {
        // Valid CRC over a body with junk appended before re-CRCing:
        // build body + junk, recompute CRC so only structure is wrong.
        let rec = sample_record(1);
        let buf = rec.encode();
        let mut body = buf[..buf.len() - 4].to_vec();
        body.push(0xEE);
        let crc = crc32::hash(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        assert!(matches!(CheckpointRecord::decode(&body), Err(Error::Corrupt(_))));
    }

    #[test]
    fn checkpointer_persists_and_resumes() {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_ckpt_test_{}_{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let cfg = CheckpointConfig::new(dir.to_str().unwrap().to_string());
        let fp = [7u8; 32];

        let mut ck = Checkpointer::open(&cfg, 0xC0FFEE, fp).unwrap();
        ck.observe(2, &TaskOutput::Count(11)).unwrap();
        ck.observe(5, &TaskOutput::Count(22)).unwrap();

        // Resume path sees both entries.
        let resume = CheckpointConfig { resume: true, ..cfg.clone() };
        let ck2 = Checkpointer::open(&resume, 0xC0FFEE, fp).unwrap();
        assert_eq!(ck2.len(), 2);
        assert!(ck2.contains(2) && ck2.contains(5) && !ck2.contains(0));

        // Wrong fingerprint refuses to resume.
        let err = Checkpointer::open(&resume, 0xC0FFEE, [8u8; 32]).unwrap_err();
        assert!(err.to_string().contains("different plan"), "{err}");

        // Fresh (non-resume) open ignores the record.
        let ck3 = Checkpointer::open(&cfg, 0xC0FFEE, fp).unwrap();
        assert!(ck3.is_empty());

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cadence_batches_flushes() {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_ckpt_cadence_{}_{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let cfg = CheckpointConfig {
            every: 3,
            ..CheckpointConfig::new(dir.to_str().unwrap().to_string())
        };
        let fp = [1u8; 32];
        let mut ck = Checkpointer::open(&cfg, 5, fp).unwrap();
        ck.observe(0, &TaskOutput::Count(0)).unwrap();
        ck.observe(1, &TaskOutput::Count(1)).unwrap();
        // Two observations < cadence: nothing on disk yet.
        assert!(!ck.store.exists(ck.name()));
        ck.observe(2, &TaskOutput::Count(2)).unwrap();
        assert!(ck.store.exists(ck.name()));
        // A final explicit flush is idempotent.
        ck.flush().unwrap();
        let resume = CheckpointConfig { resume: true, ..cfg };
        assert_eq!(Checkpointer::open(&resume, 5, fp).unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
