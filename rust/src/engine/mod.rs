//! The Spark-like distributed compute engine (paper §3).
//!
//! * [`plan`] — serializable task descriptions (sources, op chains,
//!   actions) — the closure-serialization substitute.
//! * [`ops`] — the operator registry shared by driver and workers.
//! * [`executor`] — task execution (source → ops → action).
//! * [`cluster`] / [`remote`] — thread-pool and worker-process clusters.
//! * [`stream`] — the streaming work-stealing pipeline between the
//!   scheduler and a cluster's workers.
//! * [`scheduler`] — streaming dispatch with immediate bounded retries
//!   (plus the old round-based model as a bench baseline).
//! * [`context`] — the driver API: [`SimContext`] + [`Rdd`].
//! * [`rpc`] / [`worker`] — the standalone-mode TCP protocol.

pub mod cluster;
pub mod context;
pub mod executor;
pub mod ops;
pub mod plan;
pub mod remote;
pub mod rpc;
pub mod scheduler;
pub mod stream;
pub mod worker;

pub use cluster::{Cluster, LocalCluster};
pub use context::{Rdd, SimContext};
pub use ops::{OpRegistry, TaskCtx};
pub use plan::{Action, OpCall, PlayedRecord, Record, Source, TaskOutput, TaskSpec};
pub use remote::StandaloneCluster;
pub use scheduler::{run_job, run_job_rounds, JobReport};
pub use stream::{Completion, TaskStream};
