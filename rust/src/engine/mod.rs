//! The Spark-like distributed compute engine (paper §3).
//!
//! * [`plan`] — serializable task descriptions (sources, op chains,
//!   actions) — the closure-serialization substitute.
//! * [`data`] — the content-addressed data plane: [`data::DataRef`]
//!   task inputs, the worker-side [`data::DataPlane`] block cache, and
//!   the [`data::BlockServer`]/[`data::BlockClient`] fetch RPC.
//! * [`ops`] — the operator registry shared by driver and workers.
//! * [`executor`] — task execution (source → ops → action).
//! * [`cluster`] / [`remote`] — thread-pool and worker-process clusters.
//! * [`deploy`] — [`deploy::ClusterSpec`] manifests for multi-host
//!   fleets: parse (TOML/JSON), health-probe, launch local workers.
//! * [`stream`] — the streaming work-stealing pipeline between the
//!   scheduler and a cluster's workers.
//! * [`scheduler`] — provider-driven streaming dispatch with immediate
//!   bounded retries ([`scheduler::TaskProvider`] / [`run_job`]; plus
//!   the old round-based model as a bench baseline).
//! * [`checkpoint`] — durable, CRC-guarded aggregation checkpoints so
//!   a restarted driver resumes instead of rerunning from scratch.
//! * [`fault`] — deterministic fault injection ([`fault::FaultPlan`])
//!   exercising the recovery paths in tests and CI.
//! * [`context`] — the driver API: [`SimContext`] + [`Rdd`].
//! * [`rpc`] / [`worker`] — the standalone-mode TCP protocol.
//! * [`trace`] — distributed task tracing: worker-side per-stage
//!   spans piggybacked on task replies, merged driver-side into a
//!   [`trace::TraceLog`] (Chrome `trace_event` export + per-stage
//!   `JobReport` summary).
//!
//! Quick taste — a four-worker in-process cluster counting a range:
//!
//! ```
//! use av_simd::engine::{run_job, Action, LocalCluster, OpRegistry, Source, TaskOutput, TaskSpec};
//!
//! let cluster = LocalCluster::new(4, OpRegistry::with_builtins(), "artifacts");
//! let tasks: Vec<TaskSpec> = (0..8)
//!     .map(|i| TaskSpec {
//!         job_id: 1,
//!         task_id: i,
//!         attempt: 0,
//!         source: Source::Range { start: 0, end: 100 },
//!         ops: vec![],
//!         action: Action::Count,
//!     })
//!     .collect();
//! let (outputs, report) = run_job(&cluster, tasks, 2).unwrap();
//! assert_eq!(outputs.len(), 8);
//! assert!(outputs.iter().all(|o| *o == TaskOutput::Count(100)));
//! assert_eq!(report.retries, 0);
//! ```

pub mod checkpoint;
pub mod cluster;
pub mod context;
pub mod data;
pub mod fault;
pub mod deploy;
pub mod executor;
pub mod ops;
pub mod plan;
pub mod remote;
pub mod rpc;
pub mod scheduler;
pub mod stream;
pub mod trace;
pub mod worker;

pub use checkpoint::{CheckpointConfig, CheckpointRecord, Checkpointer};
pub use cluster::{Cluster, LocalCluster};
pub use context::{Rdd, SimContext};
pub use data::{BlockClient, BlockServer, BlockSource, DataPlane, DataRef, SwarmRegistry};
pub use deploy::{ClusterSpec, WorkerEndpoint, WorkerHealth};
pub use fault::FaultPlan;
pub use ops::{OpRegistry, TaskCtx};
pub use plan::{Action, OpCall, PlayedRecord, Record, Source, TaskOutput, TaskSpec};
pub use remote::StandaloneCluster;
pub use scheduler::{
    round_window, run_job, run_job_rounds, run_job_with, run_provider, run_provider_hooked,
    run_provider_with, JobReport, RetryBackoff, RunHooks, Speculation, TaskProvider,
};
pub use stream::{Completion, CompletionWait, TaskStream};
pub use trace::{SpanBatch, StageStat, TraceCtx, TraceLog};
