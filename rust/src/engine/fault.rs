//! Deterministic fault injection for recovery-path testing.
//!
//! Recovery code (retry re-entry, speculation, swarm fallback, and now
//! checkpoint resume) is exactly the code that never runs in a happy
//! test suite. A [`FaultPlan`] is a small, seeded schedule of failures
//! — worker kills, connection drops, block-read corruption, and a
//! driver abort after N completions — threaded through the cluster
//! backends behind test-only constructors
//! (`LocalCluster::with_faults`, `StandaloneCluster::connect_with_faults`,
//! `worker::serve_with_faults`, `DataPlane::with_faults`), so those
//! paths are exercised reproducibly instead of by sleeps and luck.
//!
//! The plan is `Clone`-shared (an `Arc` of atomic countdowns): every
//! component holding a clone draws from the *same* budget, so "corrupt
//! the first two block fetches" means two fetches process-wide, not two
//! per worker.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::prng::Prng;

/// Message prefix used by every injected failure, so tests (and humans
/// reading logs) can tell scheduled faults from real ones.
pub const FAULT_TAG: &str = "fault injection";

#[derive(Debug)]
struct Inner {
    /// Driver aborts once this many outputs have been resolved
    /// (-1 = disabled).
    abort_after: AtomicI64,
    /// Per-worker countdown of tasks to execute before dying.
    kills: Mutex<HashMap<usize, u64>>,
    /// Countdown of task replies before a serving connection drops
    /// (-1 = disabled; the drop fires once).
    conn_drop: AtomicI64,
    /// Number of remaining block fetches to corrupt.
    corruptions: AtomicI64,
}

impl Default for Inner {
    fn default() -> Self {
        // the countdowns must start *disarmed*: 0 would mean "abort at
        // the first completion" for `abort_after`
        Self {
            abort_after: AtomicI64::new(-1),
            kills: Mutex::new(HashMap::new()),
            conn_drop: AtomicI64::new(-1),
            corruptions: AtomicI64::new(0),
        }
    }
}

/// A seeded, shareable schedule of injected failures (see module docs).
///
/// The default plan injects nothing; builders arm individual faults.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    inner: Arc<Inner>,
}

impl FaultPlan {
    /// A plan that injects nothing (same as `FaultPlan::default()`).
    pub fn none() -> Self {
        Self::default()
    }

    /// Derive a small mixed schedule from `seed` (one worker kill, one
    /// connection drop, one or two block corruptions) — a convenience
    /// for chaos sweeps where only reproducibility matters.
    pub fn seeded(seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        Self::none()
            .kill_worker(rng.below(4) as usize, rng.below(3))
            .drop_connection_after(1 + rng.below(3))
            .corrupt_block_fetches(1 + rng.below(2))
    }

    /// Abort the driver (fail the run) once `n` task outputs have been
    /// resolved; the checkpoint is flushed first, so a resumed driver
    /// sees exactly `n` entries.
    pub fn abort_driver_after(self, n: u64) -> Self {
        self.inner.abort_after.store(n as i64, Ordering::SeqCst);
        self
    }

    /// Kill `worker` (simulated process death) after it has executed
    /// `after_tasks` further tasks; the task it is holding at death
    /// completes with a transport error.
    pub fn kill_worker(self, worker: usize, after_tasks: u64) -> Self {
        self.inner.kills.lock().unwrap().insert(worker, after_tasks);
        self
    }

    /// Drop a serving connection after `replies` task replies.
    pub fn drop_connection_after(self, replies: u64) -> Self {
        self.inner.conn_drop.store(replies as i64, Ordering::SeqCst);
        self
    }

    /// Corrupt the next `n` remote block fetches (one flipped byte,
    /// caught by content verification → a retryable engine error).
    pub fn corrupt_block_fetches(self, n: u64) -> Self {
        self.inner.corruptions.store(n as i64, Ordering::SeqCst);
        self
    }

    /// Driver-side query: should the run abort now, given `completed`
    /// resolved outputs?
    pub fn driver_abort_due(&self, completed: u64) -> bool {
        let n = self.inner.abort_after.load(Ordering::SeqCst);
        n >= 0 && completed >= n as u64
    }

    /// Worker-side query, called once per task pulled: decrements the
    /// worker's kill countdown and returns true when it expires.
    pub fn worker_should_die(&self, worker: usize) -> bool {
        let mut kills = self.inner.kills.lock().unwrap();
        match kills.get_mut(&worker) {
            Some(0) => true,
            Some(n) => {
                *n -= 1;
                false
            }
            None => false,
        }
    }

    /// Connection-side query, called once per task reply: true exactly
    /// once, when the armed countdown expires.
    pub fn connection_should_drop(&self) -> bool {
        self.inner.conn_drop.fetch_sub(1, Ordering::SeqCst) == 1
    }

    /// Data-plane query, called once per remote block fetch: true while
    /// the corruption budget lasts, consuming one unit per call.
    pub fn take_block_corruption(&self) -> bool {
        self.inner.corruptions.fetch_sub(1, Ordering::SeqCst) > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(!plan.driver_abort_due(u64::MAX));
        assert!(!plan.worker_should_die(0));
        assert!(!plan.connection_should_drop());
        assert!(!plan.take_block_corruption());
    }

    #[test]
    fn worker_kill_counts_down_per_worker() {
        let plan = FaultPlan::none().kill_worker(1, 2);
        // Worker 0 is never scheduled to die.
        assert!(!plan.worker_should_die(0));
        // Worker 1 survives two pulls, dies on the third.
        assert!(!plan.worker_should_die(1));
        assert!(!plan.worker_should_die(1));
        assert!(plan.worker_should_die(1));
        assert!(plan.worker_should_die(1));
    }

    #[test]
    fn clones_share_one_budget() {
        let plan = FaultPlan::none().corrupt_block_fetches(2);
        let other = plan.clone();
        assert!(plan.take_block_corruption());
        assert!(other.take_block_corruption());
        assert!(!plan.take_block_corruption());
    }

    #[test]
    fn connection_drop_fires_once() {
        let plan = FaultPlan::none().drop_connection_after(2);
        assert!(!plan.connection_should_drop());
        assert!(plan.connection_should_drop());
        assert!(!plan.connection_should_drop());
    }

    #[test]
    fn abort_threshold() {
        let plan = FaultPlan::none().abort_driver_after(3);
        assert!(!plan.driver_abort_due(2));
        assert!(plan.driver_abort_due(3));
    }
}
