//! Standalone worker: a separate OS process serving tasks over TCP.
//!
//! Launched as `av-simd worker --listen <addr> --id <n> [--slots N]`;
//! the driver's [`super::remote::StandaloneCluster`] connects and
//! drives it with [`super::rpc`] frames. Each connection executes its
//! tasks serially, but the process accepts up to `slots` connections
//! *concurrently* — one multi-slot worker saturates a multi-core box
//! without the `host:port*N` one-process-per-core workaround in
//! `ClusterSpec` manifests (drivers open one connection per slot via
//! the `host:port+N` spec syntax). All connections share one
//! [`super::data::DataPlane`] — the per-worker LRU cache holding bags
//! read by path *and* content-addressed blocks fetched from a block
//! peer — so data any slot resolved replays from RAM for every other
//! slot, and a manifest-named bag crosses the wire at most once per
//! worker process.

use super::data::{BlockServer, BlockSource};
use super::executor;
use super::fault::{FaultPlan, FAULT_TAG};
use super::ops::{OpRegistry, TaskCtx};
use super::plan::{TaskOutput, TaskSpec};
use super::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use super::trace::{self, SpanBatch, TraceCtx};
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::util::mono_nanos;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Serve tasks forever with one task slot (until `Shutdown` or driver
/// disconnect after at least one session). Returns after a clean
/// shutdown. See [`serve_with_slots`] for the multi-slot form.
pub fn serve(addr: &str, worker_id: usize, registry: OpRegistry, artifact_dir: &str) -> Result<()> {
    serve_with_slots(addr, worker_id, registry, artifact_dir, 1)
}

/// Serve tasks with up to `slots` concurrent connections, each running
/// tasks serially on its own thread. Connections beyond the bound wait
/// in the accept queue until a slot frees. A `Shutdown` on any
/// connection stops the whole process (after in-flight connections
/// finish). All slots share the worker's [`TaskCtx`] bag cache.
pub fn serve_with_slots(
    addr: &str,
    worker_id: usize,
    registry: OpRegistry,
    artifact_dir: &str,
    slots: usize,
) -> Result<()> {
    serve_with_faults(addr, worker_id, registry, artifact_dir, slots, FaultPlan::none())
}

/// Test-only flavor of [`serve_with_slots`]: the [`FaultPlan`] is
/// consulted on every task received — a scheduled connection drop cuts
/// the socket *before* the reply is written, so the driver observes a
/// real mid-task hang-up (the in-flight attempt is lost and must be
/// retried elsewhere). The worker process itself stays up and
/// re-accepts, like a worker behind a flaky switch.
pub fn serve_with_faults(
    addr: &str,
    worker_id: usize,
    registry: OpRegistry,
    artifact_dir: &str,
    slots: usize,
    faults: FaultPlan,
) -> Result<()> {
    let slots = slots.max(1);
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Engine(format!("worker {worker_id} bind {addr}: {e}")))?;
    // Self-dial target for waking the accept loop on shutdown: the
    // actual bound address — except an unspecified bind (0.0.0.0/::),
    // which is not dialable itself but is reachable via loopback.
    let local = listener
        .local_addr()
        .map_err(|e| Error::Engine(format!("worker {worker_id} local_addr: {e}")))?;
    let wake_addr = if local.ip().is_unspecified() {
        // family-matched loopback: a v6-only [::] socket is not
        // reachable via 127.0.0.1
        match local.ip() {
            std::net::IpAddr::V4(_) => format!("127.0.0.1:{}", local.port()),
            std::net::IpAddr::V6(_) => format!("[::1]:{}", local.port()),
        }
    } else {
        local.to_string()
    };
    crate::logmsg!("info", "worker {worker_id} listening on {addr} ({slots} slot(s))");
    let ctx = TaskCtx::new(worker_id, artifact_dir);
    // Swarm serving: expose this worker's block cache as a block peer on
    // an ephemeral port next to the task port, and advertise it to the
    // driver via BlockAd frames. Losing the bind is not fatal — the
    // worker still runs tasks, it just never joins the swarm.
    let block_peer_host = match local.ip() {
        ip if ip.is_unspecified() => match ip {
            std::net::IpAddr::V4(_) => "127.0.0.1".to_string(),
            std::net::IpAddr::V6(_) => "[::1]".to_string(),
        },
        std::net::IpAddr::V6(ip) => format!("[{ip}]"),
        ip => ip.to_string(),
    };
    let cache_source: Arc<dyn BlockSource> = Arc::new(ctx.data.clone());
    let block_server = match BlockServer::serve_source(
        cache_source,
        &format!("{block_peer_host}:0"),
        &block_peer_host,
    ) {
        Ok(s) => Some(s),
        Err(e) => {
            crate::logmsg!("warn", "worker {worker_id} swarm block server: {e}");
            None
        }
    };
    let block_peer = block_server.as_ref().map(|s| s.peer().to_string());
    let shutdown = Arc::new(AtomicBool::new(false));
    // counting gate bounding concurrent connections at `slots`
    struct Gate {
        active: Mutex<usize>,
        freed: Condvar,
    }
    let gate = Arc::new(Gate { active: Mutex::new(0), freed: Condvar::new() });
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // telemetry: slot occupancy for `av-simd top`
    Metrics::global().gauge("worker_slots_total").set(slots as u64);

    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break; // a handler saw Shutdown (this accept was its wake-up)
        }
        let stream = conn.map_err(Error::Io)?;
        // take a slot (blocks the acceptor while all slots are busy —
        // pending connections queue in the kernel backlog)
        {
            let mut active = gate.active.lock().unwrap();
            while *active >= slots {
                active = gate.freed.wait(active).unwrap();
            }
            *active += 1;
            Metrics::global().gauge("worker_slots_busy").set(*active as u64);
        }
        let ctx = ctx.clone();
        let registry = registry.clone();
        let gate = gate.clone();
        let shutdown = shutdown.clone();
        let wake = wake_addr.clone();
        let block_peer = block_peer.clone();
        let faults = faults.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("av-simd-worker-{worker_id}-slot"))
                .spawn(move || {
                    let result =
                        serve_connection(stream, &ctx, &registry, block_peer.as_deref(), &faults);
                    // free the slot before any shutdown wake, so the
                    // acceptor is never left parked on a full gate
                    {
                        let mut active = gate.active.lock().unwrap();
                        *active -= 1;
                        Metrics::global().gauge("worker_slots_busy").set(*active as u64);
                    }
                    gate.freed.notify_one();
                    match result {
                        Ok(ShutdownKind::Graceful) => {
                            shutdown.store(true, Ordering::SeqCst);
                            // unblock the accept loop
                            if let Err(e) = TcpStream::connect(&wake) {
                                crate::logmsg!(
                                    "warn",
                                    "worker {worker_id} shutdown wake dial {wake}: {e}"
                                );
                            }
                        }
                        Ok(ShutdownKind::Disconnect) => {} // driver may reconnect
                        Err(e) => {
                            crate::logmsg!(
                                "warn",
                                "worker {worker_id} connection error: {e}"
                            );
                        }
                    }
                })
                .expect("spawn worker slot thread"),
        );
        // reap finished handlers so the vec stays bounded on long runs
        handles.retain(|h| !h.is_finished());
    }
    for h in handles {
        let _ = h.join();
    }
    drop(block_server); // stop the swarm block server with the worker
    Ok(())
}

enum ShutdownKind {
    Graceful,
    Disconnect,
}

fn serve_connection(
    stream: TcpStream,
    ctx: &TaskCtx,
    registry: &OpRegistry,
    block_peer: Option<&str>,
    faults: &FaultPlan,
) -> Result<ShutdownKind> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    // last swarm advertisement sent on *this* connection; an ad goes out
    // ahead of a task reply only when the resident set changed
    let mut last_ad: Vec<[u8; 32]> = Vec::new();
    loop {
        match read_msg(&mut reader)? {
            None => return Ok(ShutdownKind::Disconnect),
            Some(RpcMsg::Ping) => write_msg(&mut writer, &RpcMsg::Pong)?,
            Some(RpcMsg::Hello { version: _ }) => {
                // The worker always reports its own version; rejecting a
                // mismatch is the driver's call (it owns the fleet). The
                // monotonic clock sample is the trace-alignment anchor.
                write_msg(
                    &mut writer,
                    &RpcMsg::HelloOk {
                        version: RPC_VERSION,
                        worker_id: ctx.worker_id as u64,
                        now_ns: mono_nanos(),
                    },
                )?
            }
            Some(RpcMsg::Shutdown) => return Ok(ShutdownKind::Graceful),
            Some(RpcMsg::FetchStats) => {
                // telemetry snapshot: refresh the data-plane gauges from
                // this worker's shared cache, then ship the registry
                let m = Metrics::global();
                let (hits, misses, _) = ctx.data.cache().stats();
                m.gauge("worker_cache_hits").set(hits);
                m.gauge("worker_cache_misses").set(misses);
                m.gauge("worker_cache_bytes").set(ctx.data.cache().used_bytes());
                write_msg(&mut writer, &RpcMsg::StatsData(m.snapshot().encode()))?;
            }
            Some(msg @ (RpcMsg::RunTask(_) | RpcMsg::RunTaskTraced(_))) => {
                let traced = matches!(msg, RpcMsg::RunTaskTraced(_));
                let spec_bytes = match msg {
                    RpcMsg::RunTask(b) | RpcMsg::RunTaskTraced(b) => b,
                    _ => unreachable!(),
                };
                let t0 = mono_nanos();
                let decoded = TaskSpec::decode(&spec_bytes);
                if traced {
                    if let Ok(spec) = &decoded {
                        trace::begin_task(
                            ctx.worker_id as u64,
                            TraceCtx {
                                job_id: spec.job_id,
                                task_id: spec.task_id,
                                attempt: spec.attempt,
                            },
                        );
                    }
                }
                let reply = match decoded.and_then(|spec| executor::run_task(ctx, registry, &spec))
                {
                    Ok(out) => {
                        Metrics::global().counter("worker_tasks_done").inc();
                        RpcMsg::TaskOk(trace::span("reply_serialize", || out.encode()))
                    }
                    Err(e) => {
                        Metrics::global().counter("worker_tasks_failed").inc();
                        RpcMsg::TaskErr(e.to_string())
                    }
                };
                let batch = if traced {
                    // the top-level span: everything from spec decode
                    // through reply serialization on this worker
                    trace::record("task", "", t0, mono_nanos().saturating_sub(t0));
                    trace::end_task()
                } else {
                    None
                };
                if faults.connection_should_drop() {
                    // injected wire cut: the computed reply is never
                    // written, so the driver sees a mid-task hang-up
                    crate::logmsg!(
                        "warn",
                        "{FAULT_TAG}: worker {} dropping connection before reply",
                        ctx.worker_id
                    );
                    return Ok(ShutdownKind::Disconnect);
                }
                if let Some(batch) = batch {
                    // span batch rides ahead of the reply, exactly like a
                    // BlockAd — the driver stashes it while matching FIFO
                    write_msg(&mut writer, &RpcMsg::TaskTrace(batch.encode()))?;
                }
                if let Some(peer) = block_peer {
                    let resident: Vec<[u8; 32]> =
                        ctx.data.resident_manifests().iter().map(|m| m.0).collect();
                    if resident != last_ad && !resident.is_empty() {
                        write_msg(
                            &mut writer,
                            &RpcMsg::BlockAd {
                                peer: peer.to_string(),
                                manifests: resident.clone(),
                            },
                        )?;
                        last_ad = resident;
                    }
                }
                write_msg(&mut writer, &reply)?;
            }
            Some(other) => {
                return Err(Error::Engine(format!(
                    "worker received unexpected message {other:?}"
                )))
            }
        }
    }
}

/// Driver-side client handle to one worker connection.
pub struct WorkerClient {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    /// The `host:port` this client dialed.
    pub addr: String,
    /// The worker's self-reported id, learned during the connect
    /// handshake (diagnostic: maps endpoints back to launch manifests).
    pub worker_id: u64,
    /// Swarm cache advertisements the worker piggybacked on task
    /// replies, pending pickup via [`WorkerClient::take_advertisements`].
    ads: Vec<(String, Vec<[u8; 32]>)>,
    /// Span batches the worker piggybacked on traced task replies,
    /// pending pickup via [`WorkerClient::take_trace_batches`].
    traces: Vec<SpanBatch>,
    /// Estimated offset (ns) that shifts this worker's monotonic clock
    /// onto the driver's: `driver_mono ≈ worker_mono + offset`.
    /// Estimated from the `Hello` round trip (midpoint method) at
    /// connect; 0 until a handshake has completed.
    pub clock_offset_ns: i64,
}

impl WorkerClient {
    /// Connect, retrying with exponential backoff until the worker
    /// process is up (bounded wait): quick first probes catch an
    /// already-listening worker in a millisecond or two, the capped
    /// backoff keeps a slow-starting worker from being hammered. Once a
    /// TCP connection lands, the [`RpcMsg::Hello`] handshake verifies
    /// liveness *and* protocol version; a version mismatch is a hard
    /// error (never retried — the binary won't change underneath us).
    /// On backoff exhaustion the error names the `host:port` and the
    /// number of connect attempts made.
    pub fn connect(addr: &str, timeout: std::time::Duration) -> Result<Self> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = std::time::Duration::from_millis(1);
        let mut attempts = 0usize;
        loop {
            attempts += 1;
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    // Bound the handshake read by the remaining budget:
                    // without this, an endpoint that accepts TCP but
                    // never answers (a wedged worker, or some unrelated
                    // service on the port) would hang the driver forever.
                    let remaining = deadline
                        .saturating_duration_since(std::time::Instant::now())
                        .max(std::time::Duration::from_millis(1));
                    stream.set_read_timeout(Some(remaining)).ok();
                    let mut c = Self {
                        reader: std::io::BufReader::new(stream.try_clone()?),
                        writer: std::io::BufWriter::new(stream),
                        addr: addr.to_string(),
                        worker_id: 0,
                        ads: Vec::new(),
                        traces: Vec::new(),
                        clock_offset_ns: 0,
                    };
                    // verify liveness + protocol version
                    c.worker_id = c.handshake().map_err(|e| match e {
                        Error::Io(io) => Error::Engine(format!(
                            "worker at {addr} did not complete the handshake \
                             within {remaining:?}: {io}"
                        )),
                        other => other,
                    })?;
                    // task replies may legitimately take arbitrarily long —
                    // the deadline only governs connection establishment
                    c.reader.get_ref().set_read_timeout(None).ok();
                    return Ok(c);
                }
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::Engine(format!(
                            "worker at {addr} not reachable after {attempts} connect \
                             attempt(s) over {timeout:?}: {e}"
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(std::time::Duration::from_millis(50));
                }
            }
        }
    }

    /// Liveness probe (no version check — see [`WorkerClient::handshake`]).
    pub fn ping(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Ping)?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::Pong) => Ok(()),
            other => Err(Error::Engine(format!("expected Pong, got {other:?}"))),
        }
    }

    /// Version handshake: send [`RpcMsg::Hello`], require a matching
    /// [`RpcMsg::HelloOk`]. Returns the worker's reported id. This is
    /// the deploy layer's health check — a worker that answers with a
    /// different [`RPC_VERSION`] is rejected with an error naming the
    /// endpoint and both versions. As a side effect the round trip
    /// estimates [`WorkerClient::clock_offset_ns`]: the worker's
    /// `now_ns` is assumed to have been read at the midpoint of the
    /// driver-observed exchange, the classic NTP-style estimate (good
    /// to half the round-trip time, microseconds on a LAN).
    pub fn handshake(&mut self) -> Result<u64> {
        let t0 = mono_nanos();
        write_msg(&mut self.writer, &RpcMsg::Hello { version: RPC_VERSION })?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::HelloOk { version, worker_id, now_ns }) => {
                let t1 = mono_nanos();
                if version != RPC_VERSION {
                    return Err(Error::Engine(format!(
                        "worker at {} speaks rpc v{version} but this driver needs \
                         v{RPC_VERSION} — redeploy the worker binary",
                        self.addr
                    )));
                }
                let midpoint = t0 + (t1.saturating_sub(t0)) / 2;
                self.clock_offset_ns = midpoint as i64 - now_ns as i64;
                Ok(worker_id)
            }
            None => Err(Error::Engine(format!(
                "worker at {} hung up during handshake — likely a worker binary \
                 that predates the rpc version handshake; redeploy the worker",
                self.addr
            ))),
            other => Err(Error::Engine(format!(
                "worker at {} answered handshake with {other:?}",
                self.addr
            ))),
        }
    }

    /// Dispatch a task without waiting for its reply. The worker answers
    /// requests strictly in order, so callers may pipeline several
    /// `send_task`s and collect replies FIFO with
    /// [`WorkerClient::recv_reply`].
    pub fn send_task(&mut self, spec: &TaskSpec) -> Result<()> {
        self.send_task_encoded(spec.encode())
    }

    /// [`WorkerClient::send_task`] with a pre-encoded spec (callers that
    /// size-check the frame before dispatch avoid encoding twice).
    pub fn send_task_encoded(&mut self, encoded_spec: Vec<u8>) -> Result<()> {
        self.send_task_encoded_traced(encoded_spec, false)
    }

    /// [`WorkerClient::send_task_encoded`], optionally requesting
    /// per-stage tracing: when `traced` the task rides in a
    /// [`RpcMsg::RunTaskTraced`] frame and the worker piggybacks a
    /// [`RpcMsg::TaskTrace`] span batch ahead of the reply (drained via
    /// [`WorkerClient::take_trace_batches`]).
    pub fn send_task_encoded_traced(&mut self, encoded_spec: Vec<u8>, traced: bool) -> Result<()> {
        let msg = if traced {
            RpcMsg::RunTaskTraced(encoded_spec)
        } else {
            RpcMsg::RunTask(encoded_spec)
        };
        write_msg(&mut self.writer, &msg)
    }

    /// Receive the reply for the oldest outstanding [`WorkerClient::send_task`].
    /// `task_id` is only used to label errors. Swarm [`RpcMsg::BlockAd`]
    /// and [`RpcMsg::TaskTrace`] frames interleaved ahead of the reply
    /// are stashed for [`WorkerClient::take_advertisements`] /
    /// [`WorkerClient::take_trace_batches`], not surfaced as errors.
    pub fn recv_reply(&mut self, task_id: u32) -> Result<TaskOutput> {
        loop {
            match read_msg(&mut self.reader)? {
                Some(RpcMsg::TaskOk(out)) => return TaskOutput::decode(&out),
                Some(RpcMsg::TaskErr(msg)) => {
                    return Err(Error::Engine(format!(
                        "remote task {task_id} failed: {msg}"
                    )))
                }
                Some(RpcMsg::BlockAd { peer, manifests }) => {
                    self.ads.push((peer, manifests));
                }
                Some(RpcMsg::TaskTrace(bytes)) => match SpanBatch::decode(&bytes) {
                    Ok(batch) => self.traces.push(batch),
                    Err(e) => crate::logmsg!("warn", "dropping undecodable span batch: {e}"),
                },
                None => return Err(Error::Transport("worker hung up mid-task".into())),
                other => return Err(Error::Engine(format!("unexpected reply {other:?}"))),
            }
        }
    }

    /// Drain cache advertisements received since the last call: pairs of
    /// (block-peer `host:port`, manifest ids resident in that worker's
    /// cache). Feeders forward these to the cluster's swarm registry.
    pub fn take_advertisements(&mut self) -> Vec<(String, Vec<[u8; 32]>)> {
        std::mem::take(&mut self.ads)
    }

    /// Drain span batches received since the last call. Timestamps are
    /// still on the worker's monotonic clock — shift by
    /// [`WorkerClient::clock_offset_ns`] when merging into a driver-side
    /// [`super::trace::TraceLog`].
    pub fn take_trace_batches(&mut self) -> Vec<SpanBatch> {
        std::mem::take(&mut self.traces)
    }

    /// Fetch the worker's live metrics snapshot (the `av-simd top` /
    /// `deploy --probe --stats` data source). Must not be interleaved
    /// with outstanding pipelined tasks — replies are strictly FIFO.
    pub fn fetch_stats(&mut self) -> Result<MetricsSnapshot> {
        write_msg(&mut self.writer, &RpcMsg::FetchStats)?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::StatsData(bytes)) => MetricsSnapshot::decode(&bytes),
            None => Err(Error::Transport("worker hung up during stats fetch".into())),
            other => Err(Error::Engine(format!("expected StatsData, got {other:?}"))),
        }
    }

    /// Run one task to completion on this worker (send + wait).
    pub fn run_task(&mut self, spec: &TaskSpec) -> Result<TaskOutput> {
        self.send_task(spec)?;
        self.recv_reply(spec.task_id)
    }

    /// Ask the worker process to exit gracefully.
    pub fn shutdown(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Shutdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    /// In-process worker serve thread + client, exercising the full RPC
    /// path without spawning a process.
    #[test]
    fn serve_and_run_tasks_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for serve() to rebind
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 0, OpRegistry::with_builtins(), "artifacts").unwrap();
        });

        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        client.ping().unwrap();
        assert_eq!(client.handshake().unwrap(), 0, "worker id 0 reported");

        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Range { start: 0, end: 100 },
            ops: vec![],
            action: Action::Count,
        };
        assert_eq!(client.run_task(&spec).unwrap(), TaskOutput::Count(100));

        // second task on the same connection
        let spec2 = TaskSpec {
            source: Source::Inline { records: vec![vec![1], vec![2]] },
            action: Action::Collect,
            ..spec
        };
        match client.run_task(&spec2).unwrap() {
            TaskOutput::Records(rs) => assert_eq!(rs.len(), 2),
            other => panic!("unexpected {other:?}"),
        }

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn remote_task_error_is_surfaced() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 1, OpRegistry::with_builtins(), "artifacts").unwrap();
        });
        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 9,
            attempt: 0,
            source: Source::Range { start: 0, end: 1 },
            ops: vec![super::super::plan::OpCall::new("no_such_op", vec![])],
            action: Action::Count,
        };
        let err = client.run_task(&spec).unwrap_err();
        assert!(err.to_string().contains("no_such_op"), "{err}");
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn connect_to_dead_worker_times_out() {
        let err = match WorkerClient::connect(
            "127.0.0.1:1", // reserved port, nothing listens
            std::time::Duration::from_millis(100),
        ) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = err.to_string();
        assert!(msg.contains("not reachable"), "{msg}");
        // the satellite fix: backoff exhaustion must keep the endpoint
        // and report how many connect attempts were made
        assert!(msg.contains("127.0.0.1:1"), "address lost: {msg}");
        assert!(msg.contains("attempt"), "attempt count lost: {msg}");
    }

    /// Register an op that blocks until `need` concurrent invocations
    /// rendezvous (5 s timeout → error). Proves slots really run
    /// concurrently — a serial worker would deadlock, not just be slow.
    fn rendezvous_op(reg: &OpRegistry, need: usize) {
        use std::sync::{Condvar, Mutex};
        let state = std::sync::Arc::new((Mutex::new(0usize), Condvar::new()));
        reg.register("rendezvous", move |_c, _p, records| {
            let (lock, cv) = &*state;
            let mut inside = lock.lock().unwrap();
            *inside += 1;
            cv.notify_all();
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
            while *inside < need {
                let left = deadline.saturating_duration_since(std::time::Instant::now());
                if left.is_zero() {
                    return Err(Error::Engine(format!(
                        "rendezvous timed out with {} of {need} tasks inside",
                        *inside
                    )));
                }
                let (g, timeout) = cv.wait_timeout(inside, left).unwrap();
                inside = g;
                if timeout.timed_out() && *inside < need {
                    return Err(Error::Engine(format!(
                        "rendezvous timed out with {} of {need} tasks inside",
                        *inside
                    )));
                }
            }
            Ok(records)
        });
    }

    #[test]
    fn multi_slot_worker_runs_connections_concurrently() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let reg = OpRegistry::with_builtins();
        rendezvous_op(&reg, 2);
        let addr2 = addr.clone();
        let serve_handle = std::thread::spawn(move || {
            super::serve_with_slots(&addr2, 0, reg, "artifacts", 2).unwrap();
        });

        let spec = |id: u32| TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: 3 },
            ops: vec![super::super::plan::OpCall::new("rendezvous", vec![])],
            action: Action::Count,
        };
        // two clients, each sends one task; the tasks only complete if
        // both connections are served at the same time
        let mut a = WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        let mut b = WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        a.send_task(&spec(0)).unwrap();
        b.send_task(&spec(1)).unwrap();
        assert_eq!(a.recv_reply(0).unwrap(), TaskOutput::Count(3));
        assert_eq!(b.recv_reply(1).unwrap(), TaskOutput::Count(3));

        // one Shutdown stops the whole process once connections close
        a.shutdown().unwrap();
        drop(b);
        serve_handle.join().unwrap();
    }

    #[test]
    fn version_mismatch_is_rejected_at_connect() {
        use super::super::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
        // a fake worker that answers the handshake with a wrong version
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            match read_msg(&mut reader).unwrap() {
                Some(RpcMsg::Hello { .. }) => write_msg(
                    &mut writer,
                    &RpcMsg::HelloOk { version: RPC_VERSION + 1, worker_id: 9, now_ns: 0 },
                )
                .unwrap(),
                other => panic!("expected Hello, got {other:?}"),
            }
        });
        let err = match WorkerClient::connect(&addr, std::time::Duration::from_secs(5)) {
            Err(e) => e,
            Ok(_) => panic!("mismatched worker must be rejected"),
        };
        let msg = err.to_string();
        assert!(msg.contains(&addr), "endpoint lost: {msg}");
        assert!(msg.contains("rpc v"), "{msg}");
        handle.join().unwrap();
    }
}
