//! Standalone worker: a separate OS process serving tasks over TCP.
//!
//! Launched as `av-simd worker --listen <addr> --id <n>`; the driver's
//! [`super::remote::StandaloneCluster`] connects and drives it with
//! [`super::rpc`] frames. One connection at a time, tasks executed
//! serially (one task slot per worker process, matching the paper's
//! one-ROS-node-per-Spark-worker layout).

use super::executor;
use super::ops::{OpRegistry, TaskCtx};
use super::plan::{TaskOutput, TaskSpec};
use super::rpc::{read_msg, write_msg, RpcMsg};
use crate::error::{Error, Result};
use std::net::{TcpListener, TcpStream};

/// Serve tasks forever (until `Shutdown` or driver disconnect after at
/// least one session). Returns after a clean shutdown.
pub fn serve(addr: &str, worker_id: usize, registry: OpRegistry, artifact_dir: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Engine(format!("worker {worker_id} bind {addr}: {e}")))?;
    crate::logmsg!("info", "worker {worker_id} listening on {addr}");
    let ctx = TaskCtx::new(worker_id, artifact_dir);
    for conn in listener.incoming() {
        let stream = conn.map_err(Error::Io)?;
        match serve_connection(stream, &ctx, &registry) {
            Ok(ShutdownKind::Graceful) => return Ok(()),
            Ok(ShutdownKind::Disconnect) => continue, // driver may reconnect
            Err(e) => {
                crate::logmsg!("warn", "worker {worker_id} connection error: {e}");
                continue;
            }
        }
    }
    Ok(())
}

enum ShutdownKind {
    Graceful,
    Disconnect,
}

fn serve_connection(
    stream: TcpStream,
    ctx: &TaskCtx,
    registry: &OpRegistry,
) -> Result<ShutdownKind> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        match read_msg(&mut reader)? {
            None => return Ok(ShutdownKind::Disconnect),
            Some(RpcMsg::Ping) => write_msg(&mut writer, &RpcMsg::Pong)?,
            Some(RpcMsg::Shutdown) => return Ok(ShutdownKind::Graceful),
            Some(RpcMsg::RunTask(spec_bytes)) => {
                let reply = match TaskSpec::decode(&spec_bytes)
                    .and_then(|spec| executor::run_task(ctx, registry, &spec))
                {
                    Ok(out) => RpcMsg::TaskOk(out.encode()),
                    Err(e) => RpcMsg::TaskErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(other) => {
                return Err(Error::Engine(format!(
                    "worker received unexpected message {other:?}"
                )))
            }
        }
    }
}

/// Driver-side client handle to one worker connection.
pub struct WorkerClient {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    pub addr: String,
}

impl WorkerClient {
    /// Connect, retrying with exponential backoff until the worker
    /// process is up (bounded wait): quick first probes catch an
    /// already-listening worker in a millisecond or two, the capped
    /// backoff keeps a slow-starting worker from being hammered.
    pub fn connect(addr: &str, timeout: std::time::Duration) -> Result<Self> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = std::time::Duration::from_millis(1);
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    let mut c = Self {
                        reader: std::io::BufReader::new(stream.try_clone()?),
                        writer: std::io::BufWriter::new(stream),
                        addr: addr.to_string(),
                    };
                    // verify liveness
                    c.ping()?;
                    return Ok(c);
                }
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::Engine(format!(
                            "worker at {addr} not reachable: {e}"
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(std::time::Duration::from_millis(50));
                }
            }
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Ping)?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::Pong) => Ok(()),
            other => Err(Error::Engine(format!("expected Pong, got {other:?}"))),
        }
    }

    /// Dispatch a task without waiting for its reply. The worker answers
    /// requests strictly in order, so callers may pipeline several
    /// `send_task`s and collect replies FIFO with
    /// [`WorkerClient::recv_reply`].
    pub fn send_task(&mut self, spec: &TaskSpec) -> Result<()> {
        self.send_task_encoded(spec.encode())
    }

    /// [`WorkerClient::send_task`] with a pre-encoded spec (callers that
    /// size-check the frame before dispatch avoid encoding twice).
    pub fn send_task_encoded(&mut self, encoded_spec: Vec<u8>) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::RunTask(encoded_spec))
    }

    /// Receive the reply for the oldest outstanding [`WorkerClient::send_task`].
    /// `task_id` is only used to label errors.
    pub fn recv_reply(&mut self, task_id: u32) -> Result<TaskOutput> {
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::TaskOk(out)) => TaskOutput::decode(&out),
            Some(RpcMsg::TaskErr(msg)) => Err(Error::Engine(format!(
                "remote task {task_id} failed: {msg}"
            ))),
            None => Err(Error::Engine("worker hung up mid-task".into())),
            other => Err(Error::Engine(format!("unexpected reply {other:?}"))),
        }
    }

    /// Run one task to completion on this worker (send + wait).
    pub fn run_task(&mut self, spec: &TaskSpec) -> Result<TaskOutput> {
        self.send_task(spec)?;
        self.recv_reply(spec.task_id)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Shutdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    /// In-process worker serve thread + client, exercising the full RPC
    /// path without spawning a process.
    #[test]
    fn serve_and_run_tasks_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for serve() to rebind
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 0, OpRegistry::with_builtins(), "artifacts").unwrap();
        });

        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        client.ping().unwrap();

        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Range { start: 0, end: 100 },
            ops: vec![],
            action: Action::Count,
        };
        assert_eq!(client.run_task(&spec).unwrap(), TaskOutput::Count(100));

        // second task on the same connection
        let spec2 = TaskSpec {
            source: Source::Inline { records: vec![vec![1], vec![2]] },
            action: Action::Collect,
            ..spec
        };
        match client.run_task(&spec2).unwrap() {
            TaskOutput::Records(rs) => assert_eq!(rs.len(), 2),
            other => panic!("unexpected {other:?}"),
        }

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn remote_task_error_is_surfaced() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 1, OpRegistry::with_builtins(), "artifacts").unwrap();
        });
        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 9,
            attempt: 0,
            source: Source::Range { start: 0, end: 1 },
            ops: vec![super::super::plan::OpCall::new("no_such_op", vec![])],
            action: Action::Count,
        };
        let err = client.run_task(&spec).unwrap_err();
        assert!(err.to_string().contains("no_such_op"), "{err}");
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn connect_to_dead_worker_times_out() {
        let err = match WorkerClient::connect(
            "127.0.0.1:1", // reserved port, nothing listens
            std::time::Duration::from_millis(100),
        ) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("not reachable"));
    }
}
