//! Standalone worker: a separate OS process serving tasks over TCP.
//!
//! Launched as `av-simd worker --listen <addr> --id <n>`; the driver's
//! [`super::remote::StandaloneCluster`] connects and drives it with
//! [`super::rpc`] frames. One connection at a time, tasks executed
//! serially (one task slot per worker process, matching the paper's
//! one-ROS-node-per-Spark-worker layout).

use super::executor;
use super::ops::{OpRegistry, TaskCtx};
use super::plan::{TaskOutput, TaskSpec};
use super::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
use crate::error::{Error, Result};
use std::net::{TcpListener, TcpStream};

/// Serve tasks forever (until `Shutdown` or driver disconnect after at
/// least one session). Returns after a clean shutdown.
pub fn serve(addr: &str, worker_id: usize, registry: OpRegistry, artifact_dir: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Engine(format!("worker {worker_id} bind {addr}: {e}")))?;
    crate::logmsg!("info", "worker {worker_id} listening on {addr}");
    let ctx = TaskCtx::new(worker_id, artifact_dir);
    for conn in listener.incoming() {
        let stream = conn.map_err(Error::Io)?;
        match serve_connection(stream, &ctx, &registry) {
            Ok(ShutdownKind::Graceful) => return Ok(()),
            Ok(ShutdownKind::Disconnect) => continue, // driver may reconnect
            Err(e) => {
                crate::logmsg!("warn", "worker {worker_id} connection error: {e}");
                continue;
            }
        }
    }
    Ok(())
}

enum ShutdownKind {
    Graceful,
    Disconnect,
}

fn serve_connection(
    stream: TcpStream,
    ctx: &TaskCtx,
    registry: &OpRegistry,
) -> Result<ShutdownKind> {
    stream.set_nodelay(true).ok();
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        match read_msg(&mut reader)? {
            None => return Ok(ShutdownKind::Disconnect),
            Some(RpcMsg::Ping) => write_msg(&mut writer, &RpcMsg::Pong)?,
            Some(RpcMsg::Hello { version: _ }) => {
                // The worker always reports its own version; rejecting a
                // mismatch is the driver's call (it owns the fleet).
                write_msg(
                    &mut writer,
                    &RpcMsg::HelloOk {
                        version: RPC_VERSION,
                        worker_id: ctx.worker_id as u64,
                    },
                )?
            }
            Some(RpcMsg::Shutdown) => return Ok(ShutdownKind::Graceful),
            Some(RpcMsg::RunTask(spec_bytes)) => {
                let reply = match TaskSpec::decode(&spec_bytes)
                    .and_then(|spec| executor::run_task(ctx, registry, &spec))
                {
                    Ok(out) => RpcMsg::TaskOk(out.encode()),
                    Err(e) => RpcMsg::TaskErr(e.to_string()),
                };
                write_msg(&mut writer, &reply)?;
            }
            Some(other) => {
                return Err(Error::Engine(format!(
                    "worker received unexpected message {other:?}"
                )))
            }
        }
    }
}

/// Driver-side client handle to one worker connection.
pub struct WorkerClient {
    reader: std::io::BufReader<TcpStream>,
    writer: std::io::BufWriter<TcpStream>,
    /// The `host:port` this client dialed.
    pub addr: String,
    /// The worker's self-reported id, learned during the connect
    /// handshake (diagnostic: maps endpoints back to launch manifests).
    pub worker_id: u64,
}

impl WorkerClient {
    /// Connect, retrying with exponential backoff until the worker
    /// process is up (bounded wait): quick first probes catch an
    /// already-listening worker in a millisecond or two, the capped
    /// backoff keeps a slow-starting worker from being hammered. Once a
    /// TCP connection lands, the [`RpcMsg::Hello`] handshake verifies
    /// liveness *and* protocol version; a version mismatch is a hard
    /// error (never retried — the binary won't change underneath us).
    /// On backoff exhaustion the error names the `host:port` and the
    /// number of connect attempts made.
    pub fn connect(addr: &str, timeout: std::time::Duration) -> Result<Self> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = std::time::Duration::from_millis(1);
        let mut attempts = 0usize;
        loop {
            attempts += 1;
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    // Bound the handshake read by the remaining budget:
                    // without this, an endpoint that accepts TCP but
                    // never answers (a wedged worker, or some unrelated
                    // service on the port) would hang the driver forever.
                    let remaining = deadline
                        .saturating_duration_since(std::time::Instant::now())
                        .max(std::time::Duration::from_millis(1));
                    stream.set_read_timeout(Some(remaining)).ok();
                    let mut c = Self {
                        reader: std::io::BufReader::new(stream.try_clone()?),
                        writer: std::io::BufWriter::new(stream),
                        addr: addr.to_string(),
                        worker_id: 0,
                    };
                    // verify liveness + protocol version
                    c.worker_id = c.handshake().map_err(|e| match e {
                        Error::Io(io) => Error::Engine(format!(
                            "worker at {addr} did not complete the handshake \
                             within {remaining:?}: {io}"
                        )),
                        other => other,
                    })?;
                    // task replies may legitimately take arbitrarily long —
                    // the deadline only governs connection establishment
                    c.reader.get_ref().set_read_timeout(None).ok();
                    return Ok(c);
                }
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::Engine(format!(
                            "worker at {addr} not reachable after {attempts} connect \
                             attempt(s) over {timeout:?}: {e}"
                        )));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(std::time::Duration::from_millis(50));
                }
            }
        }
    }

    /// Liveness probe (no version check — see [`WorkerClient::handshake`]).
    pub fn ping(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Ping)?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::Pong) => Ok(()),
            other => Err(Error::Engine(format!("expected Pong, got {other:?}"))),
        }
    }

    /// Version handshake: send [`RpcMsg::Hello`], require a matching
    /// [`RpcMsg::HelloOk`]. Returns the worker's reported id. This is
    /// the deploy layer's health check — a worker that answers with a
    /// different [`RPC_VERSION`] is rejected with an error naming the
    /// endpoint and both versions.
    pub fn handshake(&mut self) -> Result<u64> {
        write_msg(&mut self.writer, &RpcMsg::Hello { version: RPC_VERSION })?;
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::HelloOk { version, worker_id }) => {
                if version != RPC_VERSION {
                    return Err(Error::Engine(format!(
                        "worker at {} speaks rpc v{version} but this driver needs \
                         v{RPC_VERSION} — redeploy the worker binary",
                        self.addr
                    )));
                }
                Ok(worker_id)
            }
            None => Err(Error::Engine(format!(
                "worker at {} hung up during handshake — likely a worker binary \
                 that predates the rpc version handshake; redeploy the worker",
                self.addr
            ))),
            other => Err(Error::Engine(format!(
                "worker at {} answered handshake with {other:?}",
                self.addr
            ))),
        }
    }

    /// Dispatch a task without waiting for its reply. The worker answers
    /// requests strictly in order, so callers may pipeline several
    /// `send_task`s and collect replies FIFO with
    /// [`WorkerClient::recv_reply`].
    pub fn send_task(&mut self, spec: &TaskSpec) -> Result<()> {
        self.send_task_encoded(spec.encode())
    }

    /// [`WorkerClient::send_task`] with a pre-encoded spec (callers that
    /// size-check the frame before dispatch avoid encoding twice).
    pub fn send_task_encoded(&mut self, encoded_spec: Vec<u8>) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::RunTask(encoded_spec))
    }

    /// Receive the reply for the oldest outstanding [`WorkerClient::send_task`].
    /// `task_id` is only used to label errors.
    pub fn recv_reply(&mut self, task_id: u32) -> Result<TaskOutput> {
        match read_msg(&mut self.reader)? {
            Some(RpcMsg::TaskOk(out)) => TaskOutput::decode(&out),
            Some(RpcMsg::TaskErr(msg)) => Err(Error::Engine(format!(
                "remote task {task_id} failed: {msg}"
            ))),
            None => Err(Error::Engine("worker hung up mid-task".into())),
            other => Err(Error::Engine(format!("unexpected reply {other:?}"))),
        }
    }

    /// Run one task to completion on this worker (send + wait).
    pub fn run_task(&mut self, spec: &TaskSpec) -> Result<TaskOutput> {
        self.send_task(spec)?;
        self.recv_reply(spec.task_id)
    }

    /// Ask the worker process to exit gracefully.
    pub fn shutdown(&mut self) -> Result<()> {
        write_msg(&mut self.writer, &RpcMsg::Shutdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    /// In-process worker serve thread + client, exercising the full RPC
    /// path without spawning a process.
    #[test]
    fn serve_and_run_tasks_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for serve() to rebind
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 0, OpRegistry::with_builtins(), "artifacts").unwrap();
        });

        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        client.ping().unwrap();
        assert_eq!(client.handshake().unwrap(), 0, "worker id 0 reported");

        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Range { start: 0, end: 100 },
            ops: vec![],
            action: Action::Count,
        };
        assert_eq!(client.run_task(&spec).unwrap(), TaskOutput::Count(100));

        // second task on the same connection
        let spec2 = TaskSpec {
            source: Source::Inline { records: vec![vec![1], vec![2]] },
            action: Action::Collect,
            ..spec
        };
        match client.run_task(&spec2).unwrap() {
            TaskOutput::Records(rs) => assert_eq!(rs.len(), 2),
            other => panic!("unexpected {other:?}"),
        }

        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn remote_task_error_is_surfaced() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let addr2 = addr.clone();
        let handle = std::thread::spawn(move || {
            serve(&addr2, 1, OpRegistry::with_builtins(), "artifacts").unwrap();
        });
        let mut client =
            WorkerClient::connect(&addr, std::time::Duration::from_secs(5)).unwrap();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 9,
            attempt: 0,
            source: Source::Range { start: 0, end: 1 },
            ops: vec![super::super::plan::OpCall::new("no_such_op", vec![])],
            action: Action::Count,
        };
        let err = client.run_task(&spec).unwrap_err();
        assert!(err.to_string().contains("no_such_op"), "{err}");
        client.shutdown().unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn connect_to_dead_worker_times_out() {
        let err = match WorkerClient::connect(
            "127.0.0.1:1", // reserved port, nothing listens
            std::time::Duration::from_millis(100),
        ) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = err.to_string();
        assert!(msg.contains("not reachable"), "{msg}");
        // the satellite fix: backoff exhaustion must keep the endpoint
        // and report how many connect attempts were made
        assert!(msg.contains("127.0.0.1:1"), "address lost: {msg}");
        assert!(msg.contains("attempt"), "attempt count lost: {msg}");
    }

    #[test]
    fn version_mismatch_is_rejected_at_connect() {
        use super::super::rpc::{read_msg, write_msg, RpcMsg, RPC_VERSION};
        // a fake worker that answers the handshake with a wrong version
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            match read_msg(&mut reader).unwrap() {
                Some(RpcMsg::Hello { .. }) => write_msg(
                    &mut writer,
                    &RpcMsg::HelloOk { version: RPC_VERSION + 1, worker_id: 9 },
                )
                .unwrap(),
                other => panic!("expected Hello, got {other:?}"),
            }
        });
        let err = match WorkerClient::connect(&addr, std::time::Duration::from_secs(5)) {
            Err(e) => e,
            Ok(_) => panic!("mismatched worker must be rejected"),
        };
        let msg = err.to_string();
        assert!(msg.contains(&addr), "endpoint lost: {msg}");
        assert!(msg.contains("rpc v"), "{msg}");
        handle.join().unwrap();
    }
}
