//! Driver ⇄ worker RPC: length-prefixed binary frames over TCP.
//!
//! ```text
//! frame := len:u32 (type:u8 payload)   -- len covers type+payload
//! ```
//! The protocol is a simple request/response per connection: the driver
//! opens with `Hello` and checks the worker's `HelloOk` (protocol
//! version + worker id — the deployment health check), then sends
//! `RunTask` frames which the worker answers with `TaskOk`/`TaskErr`.
//! `Ping`/`Pong` is the liveness probe used while waiting for worker
//! startup.
//!
//! The same framing carries the *data plane* (see `engine::data`): a
//! worker resolving a `DataRef::Manifest` task input dials the block
//! peer named in the ref and issues `FetchManifest`/`FetchBlock`
//! requests, answered with `ManifestData`/`BlockData` (or `FetchErr`).
//! Transfers are hash-verified by the requester — a block that does not
//! hash to its content address is rejected no matter who served it.
//! See `docs/ARCHITECTURE.md` for the full wire-format spec.

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// Maximum frame size (guards against protocol desync).
pub const MAX_FRAME: u32 = 1 << 30;

/// Protocol version spoken by this build. Bumped on any incompatible
/// frame or payload change; the driver refuses workers that answer
/// [`RpcMsg::Hello`] with a different version, so a mixed-version fleet
/// fails loudly at connect time instead of corrupting task payloads.
///
/// v2: the data-plane frames ([`RpcMsg::FetchManifest`] /
/// [`RpcMsg::FetchBlock`] and replies) plus `DataRef`-carrying task
/// sources — v1 workers cannot decode v2 `TaskSpec` payloads.
///
/// v3: the swarm — [`RpcMsg::BlockAd`] cache advertisements and the
/// ordered *peer list* in `DataRef::Manifest` task payloads (v2 workers
/// expect a single peer string and cannot decode v3 `TaskSpec`s).
///
/// v4: observability — [`RpcMsg::HelloOk`] carries the worker's
/// monotonic clock (`now_ns`, the trace clock-alignment sample),
/// [`RpcMsg::RunTaskTraced`] requests per-stage span recording,
/// [`RpcMsg::TaskTrace`] piggybacks the span batch ahead of the task
/// reply (the `BlockAd` pattern), and [`RpcMsg::FetchStats`] /
/// [`RpcMsg::StatsData`] serve live `Metrics` snapshots to `av-simd
/// top`. v3 drivers cannot decode the 20-byte v4 `HelloOk`.
pub const RPC_VERSION: u32 = 4;

/// RPC message.
#[derive(Debug, Clone, PartialEq)]
pub enum RpcMsg {
    /// Driver → worker: run this encoded [`super::plan::TaskSpec`].
    RunTask(Vec<u8>),
    /// Worker → driver: encoded [`super::plan::TaskOutput`].
    TaskOk(Vec<u8>),
    /// Worker → driver: task failed with message.
    TaskErr(String),
    /// Driver → worker: liveness probe.
    Ping,
    /// Worker → driver: liveness reply.
    Pong,
    /// Driver → worker: exit gracefully.
    Shutdown,
    /// Driver → worker: handshake carrying the driver's
    /// [`RPC_VERSION`]. First frame on every deploy-layer connection.
    Hello {
        /// The driver's protocol version.
        version: u32,
    },
    /// Worker → driver: handshake reply. The driver rejects the
    /// connection when `version` differs from its own.
    HelloOk {
        /// The worker's protocol version.
        version: u32,
        /// The worker's `--id` (diagnostic: lets a deploy probe map
        /// endpoints back to launch manifests). Block-peer servers
        /// answer with `u64::MAX`.
        worker_id: u64,
        /// The worker's monotonic clock (`util::mono_nanos`) read while
        /// building this reply. Combined with the driver's send/receive
        /// timestamps around the handshake round trip, this estimates a
        /// per-connection clock offset that aligns worker trace spans
        /// onto the driver's timeline.
        now_ns: u64,
    },
    /// Requester → block peer: send the manifest bytes for this
    /// 32-byte manifest id (see `storage::ManifestId`).
    FetchManifest {
        /// SHA-256 content address of the manifest.
        id: [u8; 32],
    },
    /// Block peer → requester: the encoded `storage::Manifest`. The
    /// requester verifies the bytes hash to the requested id.
    ManifestData(Vec<u8>),
    /// Requester → block peer: send block `index` of manifest
    /// `manifest`. Indexing by (manifest, position) rather than bare
    /// block id keeps the server lookup O(1) against a manifest it has
    /// already loaded and lets fetch errors name the object they broke.
    FetchBlock {
        /// Manifest the block belongs to.
        manifest: [u8; 32],
        /// 0-based block position within the manifest.
        index: u32,
    },
    /// Block peer → requester: the raw block bytes. The requester
    /// verifies length and SHA-256 against the manifest's `BlockRef`.
    BlockData(Vec<u8>),
    /// Block peer → requester: a fetch failed (missing manifest, bad
    /// index, corrupt block on the serving side).
    FetchErr(String),
    /// Worker → driver: swarm cache advertisement, piggybacked on the
    /// task connection ahead of a task reply whenever the worker's set
    /// of cache-resident manifests has changed. The driver records
    /// `peer` (the worker's dialable block-server `host:port`) as a
    /// fetch source for each advertised manifest.
    BlockAd {
        /// The advertising worker's block-server endpoint.
        peer: String,
        /// Manifest ids fully resident in the worker's cache.
        manifests: Vec<[u8; 32]>,
    },
    /// Driver → worker: like [`RpcMsg::RunTask`] (same encoded
    /// `TaskSpec` payload) but the worker records per-stage trace
    /// [`Span`](super::trace::Span)s while executing and ships them
    /// back as a [`RpcMsg::TaskTrace`] frame ahead of the reply.
    RunTaskTraced(Vec<u8>),
    /// Worker → driver: an encoded span batch
    /// (`engine::trace::SpanBatch`), piggybacked on the task connection
    /// ahead of a `TaskOk`/`TaskErr` — the same pattern as
    /// [`RpcMsg::BlockAd`].
    TaskTrace(Vec<u8>),
    /// Anyone → worker: request a versioned snapshot of the worker's
    /// `Metrics` registry (the `av-simd top` poll).
    FetchStats,
    /// Worker → requester: the encoded `metrics::MetricsSnapshot`.
    StatsData(Vec<u8>),
}

impl RpcMsg {
    fn type_byte(&self) -> u8 {
        match self {
            RpcMsg::RunTask(_) => 1,
            RpcMsg::TaskOk(_) => 2,
            RpcMsg::TaskErr(_) => 3,
            RpcMsg::Ping => 4,
            RpcMsg::Pong => 5,
            RpcMsg::Shutdown => 6,
            RpcMsg::Hello { .. } => 7,
            RpcMsg::HelloOk { .. } => 8,
            RpcMsg::FetchManifest { .. } => 9,
            RpcMsg::ManifestData(_) => 10,
            RpcMsg::FetchBlock { .. } => 11,
            RpcMsg::BlockData(_) => 12,
            RpcMsg::FetchErr(_) => 13,
            RpcMsg::BlockAd { .. } => 14,
            RpcMsg::RunTaskTraced(_) => 15,
            RpcMsg::TaskTrace(_) => 16,
            RpcMsg::FetchStats => 17,
            RpcMsg::StatsData(_) => 18,
        }
    }
}

/// Write one frame.
pub fn write_msg<W: Write>(w: &mut W, msg: &RpcMsg) -> Result<()> {
    let mut scratch = [0u8; 36];
    let mut dynbuf = Vec::new();
    let payload: &[u8] = match msg {
        RpcMsg::RunTask(b) | RpcMsg::RunTaskTraced(b) | RpcMsg::TaskOk(b) => b,
        RpcMsg::ManifestData(b) | RpcMsg::BlockData(b) => b,
        RpcMsg::TaskTrace(b) | RpcMsg::StatsData(b) => b,
        RpcMsg::TaskErr(s) | RpcMsg::FetchErr(s) => s.as_bytes(),
        RpcMsg::Hello { version } => {
            scratch[..4].copy_from_slice(&version.to_le_bytes());
            &scratch[..4]
        }
        RpcMsg::HelloOk { version, worker_id, now_ns } => {
            scratch[..4].copy_from_slice(&version.to_le_bytes());
            scratch[4..12].copy_from_slice(&worker_id.to_le_bytes());
            scratch[12..20].copy_from_slice(&now_ns.to_le_bytes());
            &scratch[..20]
        }
        RpcMsg::FetchManifest { id } => {
            scratch[..32].copy_from_slice(id);
            &scratch[..32]
        }
        RpcMsg::FetchBlock { manifest, index } => {
            scratch[..32].copy_from_slice(manifest);
            scratch[32..36].copy_from_slice(&index.to_le_bytes());
            &scratch[..36]
        }
        RpcMsg::BlockAd { peer, manifests } => {
            // peer_len:u16 ‖ peer ‖ count:u32 ‖ count × id[32]
            let peer_len = u16::try_from(peer.len())
                .map_err(|_| Error::Engine(format!("BlockAd peer too long: {}", peer.len())))?;
            dynbuf.extend_from_slice(&peer_len.to_le_bytes());
            dynbuf.extend_from_slice(peer.as_bytes());
            dynbuf.extend_from_slice(&(manifests.len() as u32).to_le_bytes());
            for id in manifests {
                dynbuf.extend_from_slice(id);
            }
            &dynbuf
        }
        _ => &[],
    };
    let len = (payload.len() + 1) as u32;
    if len > MAX_FRAME {
        return Err(Error::Engine(format!("frame too large: {len}")));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[msg.type_byte()])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. `Ok(None)` on clean EOF before any bytes.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<RpcMsg>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(Error::Io(e)),
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME {
        return Err(Error::Engine(format!("bad frame length {len}")));
    }
    // Read the type byte, then the payload straight into its own Vec via
    // `Read::take` — no zero-fill (`vec![0; len]`) and no re-copy of a
    // combined buffer; task payloads run to megabytes of scenario/bag
    // bytes on the RPC hot path.
    let mut ty_buf = [0u8; 1];
    r.read_exact(&mut ty_buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Transport("connection died mid-frame".into())
        } else {
            Error::Io(e)
        }
    })?;
    let ty = ty_buf[0];
    let payload_len = (len - 1) as usize;
    let mut payload = Vec::with_capacity(payload_len);
    r.take(payload_len as u64)
        .read_to_end(&mut payload)
        .map_err(Error::Io)?;
    if payload.len() < payload_len {
        return Err(Error::Transport("connection died mid-frame".into()));
    }
    let msg = match ty {
        1 => RpcMsg::RunTask(payload),
        2 => RpcMsg::TaskOk(payload),
        3 => RpcMsg::TaskErr(
            String::from_utf8(payload)
                .map_err(|_| Error::Engine("TaskErr not utf-8".into()))?,
        ),
        4 => RpcMsg::Ping,
        5 => RpcMsg::Pong,
        6 => RpcMsg::Shutdown,
        7 => {
            if payload.len() != 4 {
                return Err(Error::Engine(format!(
                    "bad Hello payload length {}",
                    payload.len()
                )));
            }
            RpcMsg::Hello {
                version: u32::from_le_bytes(payload[..4].try_into().unwrap()),
            }
        }
        8 => {
            if payload.len() != 20 {
                return Err(Error::Engine(format!(
                    "bad HelloOk payload length {}",
                    payload.len()
                )));
            }
            RpcMsg::HelloOk {
                version: u32::from_le_bytes(payload[..4].try_into().unwrap()),
                worker_id: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
                now_ns: u64::from_le_bytes(payload[12..20].try_into().unwrap()),
            }
        }
        9 => {
            if payload.len() != 32 {
                return Err(Error::Engine(format!(
                    "bad FetchManifest payload length {}",
                    payload.len()
                )));
            }
            RpcMsg::FetchManifest { id: payload[..32].try_into().unwrap() }
        }
        10 => RpcMsg::ManifestData(payload),
        11 => {
            if payload.len() != 36 {
                return Err(Error::Engine(format!(
                    "bad FetchBlock payload length {}",
                    payload.len()
                )));
            }
            RpcMsg::FetchBlock {
                manifest: payload[..32].try_into().unwrap(),
                index: u32::from_le_bytes(payload[32..36].try_into().unwrap()),
            }
        }
        12 => RpcMsg::BlockData(payload),
        13 => RpcMsg::FetchErr(
            String::from_utf8(payload)
                .map_err(|_| Error::Engine("FetchErr not utf-8".into()))?,
        ),
        14 => {
            let bad = |what: &str| {
                Error::Engine(format!("bad BlockAd payload ({what}, {} bytes)", payload.len()))
            };
            if payload.len() < 2 {
                return Err(bad("missing peer length"));
            }
            let peer_len = u16::from_le_bytes(payload[..2].try_into().unwrap()) as usize;
            if payload.len() < 2 + peer_len + 4 {
                return Err(bad("truncated peer or count"));
            }
            let peer = std::str::from_utf8(&payload[2..2 + peer_len])
                .map_err(|_| bad("peer not utf-8"))?
                .to_string();
            let count = u32::from_le_bytes(
                payload[2 + peer_len..2 + peer_len + 4].try_into().unwrap(),
            ) as usize;
            let ids = &payload[2 + peer_len + 4..];
            if ids.len() != count * 32 {
                return Err(bad("manifest id list length mismatch"));
            }
            let manifests = ids.chunks_exact(32).map(|c| c.try_into().unwrap()).collect();
            RpcMsg::BlockAd { peer, manifests }
        }
        15 => RpcMsg::RunTaskTraced(payload),
        16 => RpcMsg::TaskTrace(payload),
        17 => {
            if !payload.is_empty() {
                return Err(Error::Engine(format!(
                    "bad FetchStats payload length {}",
                    payload.len()
                )));
            }
            RpcMsg::FetchStats
        }
        18 => RpcMsg::StatsData(payload),
        other => return Err(Error::Engine(format!("unknown rpc type {other}"))),
    };
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: RpcMsg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let mut cur = &buf[..];
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), msg);
        assert!(cur.is_empty());
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(RpcMsg::RunTask(vec![1, 2, 3]));
        roundtrip(RpcMsg::TaskOk(vec![]));
        roundtrip(RpcMsg::TaskErr("boom".into()));
        roundtrip(RpcMsg::Ping);
        roundtrip(RpcMsg::Pong);
        roundtrip(RpcMsg::Shutdown);
        roundtrip(RpcMsg::Hello { version: RPC_VERSION });
        roundtrip(RpcMsg::HelloOk { version: RPC_VERSION, worker_id: 42, now_ns: 123 });
        roundtrip(RpcMsg::Hello { version: u32::MAX });
        roundtrip(RpcMsg::HelloOk { version: 0, worker_id: u64::MAX, now_ns: u64::MAX });
        roundtrip(RpcMsg::FetchManifest { id: [7u8; 32] });
        roundtrip(RpcMsg::ManifestData(vec![1, 2, 3]));
        roundtrip(RpcMsg::FetchBlock { manifest: [0xAB; 32], index: u32::MAX });
        roundtrip(RpcMsg::BlockData(vec![0; 100]));
        roundtrip(RpcMsg::FetchErr("no such block".into()));
        roundtrip(RpcMsg::BlockAd { peer: "10.0.0.9:7200".into(), manifests: vec![] });
        roundtrip(RpcMsg::BlockAd {
            peer: "worker-3.fleet:7200".into(),
            manifests: vec![[0u8; 32], [0xFF; 32], [7; 32]],
        });
        roundtrip(RpcMsg::RunTaskTraced(vec![9, 8, 7]));
        roundtrip(RpcMsg::TaskTrace(vec![]));
        roundtrip(RpcMsg::TaskTrace(vec![0xAA; 64]));
        roundtrip(RpcMsg::FetchStats);
        roundtrip(RpcMsg::StatsData(vec![1]));
    }

    #[test]
    fn oversized_handshake_payloads_rejected() {
        // a v3 HelloOk (12 bytes, no now_ns) must not parse as v4 —
        // and neither must a padded 21-byte one or a FetchStats with a
        // stray payload byte
        for (ty, len) in [(8u8, 12usize), (8, 21), (17, 1)] {
            let mut buf = Vec::new();
            buf.extend_from_slice(&((len + 1) as u32).to_le_bytes());
            buf.push(ty);
            buf.extend_from_slice(&vec![0u8; len]);
            let mut cur = &buf[..];
            assert!(read_msg(&mut cur).is_err(), "type {ty} with {len}-byte payload");
        }
    }

    #[test]
    fn truncated_block_ad_payloads_rejected() {
        // well-formed ad, then cut at every interesting boundary
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &RpcMsg::BlockAd { peer: "h:1".into(), manifests: vec![[1u8; 32]] },
        )
        .unwrap();
        let payload_start = 5; // len:u32 + type:u8
        for cut in [1usize, 3, 6, 20] {
            // rebuild a frame whose payload is truncated to `cut` bytes
            let payload = &buf[payload_start..payload_start + cut];
            let mut frame = Vec::new();
            frame.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
            frame.push(14);
            frame.extend_from_slice(payload);
            let mut cur = &frame[..];
            assert!(read_msg(&mut cur).is_err(), "BlockAd with {cut}-byte payload");
        }
    }

    #[test]
    fn mid_frame_eof_is_typed_transport_death() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &RpcMsg::RunTask(vec![0; 100])).unwrap();
        let mut cur = &buf[..20];
        let err = read_msg(&mut cur).unwrap_err();
        assert!(err.is_transport_death(), "mid-frame EOF must be typed: {err}");
    }

    #[test]
    fn truncated_fetch_payloads_rejected() {
        for (ty, len) in [(9u8, 31usize), (11, 35)] {
            let mut buf = Vec::new();
            buf.extend_from_slice(&((len + 1) as u32).to_le_bytes());
            buf.push(ty);
            buf.extend_from_slice(&vec![0u8; len]);
            let mut cur = &buf[..];
            assert!(read_msg(&mut cur).is_err(), "type {ty} with {len}-byte payload");
        }
    }

    #[test]
    fn truncated_hello_payload_rejected() {
        // a Hello frame whose payload is 3 bytes instead of 4
        let mut buf = Vec::new();
        buf.extend_from_slice(&4u32.to_le_bytes()); // len = type + 3
        buf.push(7);
        buf.extend_from_slice(&[1, 2, 3]);
        let mut cur = &buf[..];
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let mut cur: &[u8] = &[];
        assert!(read_msg(&mut cur).unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_error() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &RpcMsg::RunTask(vec![0; 100])).unwrap();
        let mut cur = &buf[..20];
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn zero_length_frame_rejected() {
        let buf = 0u32.to_le_bytes();
        let mut cur = &buf[..];
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn multiple_frames_stream() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &RpcMsg::Ping).unwrap();
        write_msg(&mut buf, &RpcMsg::TaskErr("x".into())).unwrap();
        let mut cur = &buf[..];
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), RpcMsg::Ping);
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), RpcMsg::TaskErr("x".into()));
        assert!(read_msg(&mut cur).unwrap().is_none());
    }
}
