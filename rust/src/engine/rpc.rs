//! Driver ⇄ worker RPC: length-prefixed binary frames over TCP.
//!
//! ```text
//! frame := len:u32 (type:u8 payload)   -- len covers type+payload
//! ```
//! The protocol is a simple request/response per connection: the driver
//! sends `RunTask`, the worker answers `TaskOk`/`TaskErr`. `Ping`/`Pong`
//! is the liveness probe used while waiting for worker startup.

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// Maximum frame size (guards against protocol desync).
pub const MAX_FRAME: u32 = 1 << 30;

/// RPC message.
#[derive(Debug, Clone, PartialEq)]
pub enum RpcMsg {
    /// Driver → worker: run this encoded [`super::plan::TaskSpec`].
    RunTask(Vec<u8>),
    /// Worker → driver: encoded [`super::plan::TaskOutput`].
    TaskOk(Vec<u8>),
    /// Worker → driver: task failed with message.
    TaskErr(String),
    Ping,
    Pong,
    /// Driver → worker: exit gracefully.
    Shutdown,
}

impl RpcMsg {
    fn type_byte(&self) -> u8 {
        match self {
            RpcMsg::RunTask(_) => 1,
            RpcMsg::TaskOk(_) => 2,
            RpcMsg::TaskErr(_) => 3,
            RpcMsg::Ping => 4,
            RpcMsg::Pong => 5,
            RpcMsg::Shutdown => 6,
        }
    }
}

/// Write one frame.
pub fn write_msg<W: Write>(w: &mut W, msg: &RpcMsg) -> Result<()> {
    let payload: &[u8] = match msg {
        RpcMsg::RunTask(b) | RpcMsg::TaskOk(b) => b,
        RpcMsg::TaskErr(s) => s.as_bytes(),
        _ => &[],
    };
    let len = (payload.len() + 1) as u32;
    if len > MAX_FRAME {
        return Err(Error::Engine(format!("frame too large: {len}")));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[msg.type_byte()])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. `Ok(None)` on clean EOF before any bytes.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<RpcMsg>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(Error::Io(e)),
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME {
        return Err(Error::Engine(format!("bad frame length {len}")));
    }
    // Read the type byte, then the payload straight into its own Vec via
    // `Read::take` — no zero-fill (`vec![0; len]`) and no re-copy of a
    // combined buffer; task payloads run to megabytes of scenario/bag
    // bytes on the RPC hot path.
    let mut ty_buf = [0u8; 1];
    r.read_exact(&mut ty_buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Engine("connection died mid-frame".into())
        } else {
            Error::Io(e)
        }
    })?;
    let ty = ty_buf[0];
    let payload_len = (len - 1) as usize;
    let mut payload = Vec::with_capacity(payload_len);
    r.take(payload_len as u64)
        .read_to_end(&mut payload)
        .map_err(Error::Io)?;
    if payload.len() < payload_len {
        return Err(Error::Engine("connection died mid-frame".into()));
    }
    let msg = match ty {
        1 => RpcMsg::RunTask(payload),
        2 => RpcMsg::TaskOk(payload),
        3 => RpcMsg::TaskErr(
            String::from_utf8(payload)
                .map_err(|_| Error::Engine("TaskErr not utf-8".into()))?,
        ),
        4 => RpcMsg::Ping,
        5 => RpcMsg::Pong,
        6 => RpcMsg::Shutdown,
        other => return Err(Error::Engine(format!("unknown rpc type {other}"))),
    };
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: RpcMsg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let mut cur = &buf[..];
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), msg);
        assert!(cur.is_empty());
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(RpcMsg::RunTask(vec![1, 2, 3]));
        roundtrip(RpcMsg::TaskOk(vec![]));
        roundtrip(RpcMsg::TaskErr("boom".into()));
        roundtrip(RpcMsg::Ping);
        roundtrip(RpcMsg::Pong);
        roundtrip(RpcMsg::Shutdown);
    }

    #[test]
    fn clean_eof_is_none() {
        let mut cur: &[u8] = &[];
        assert!(read_msg(&mut cur).unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_error() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &RpcMsg::RunTask(vec![0; 100])).unwrap();
        let mut cur = &buf[..20];
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn zero_length_frame_rejected() {
        let buf = 0u32.to_le_bytes();
        let mut cur = &buf[..];
        assert!(read_msg(&mut cur).is_err());
    }

    #[test]
    fn multiple_frames_stream() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &RpcMsg::Ping).unwrap();
        write_msg(&mut buf, &RpcMsg::TaskErr("x".into())).unwrap();
        let mut cur = &buf[..];
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), RpcMsg::Ping);
        assert_eq!(read_msg(&mut cur).unwrap().unwrap(), RpcMsg::TaskErr("x".into()));
        assert!(read_msg(&mut cur).unwrap().is_none());
    }
}
