//! Worker-side task execution: materialize the [`Source`], run the op
//! chain, apply the [`Action`]. Shared by local-mode threads and
//! standalone TCP workers — the execution semantics are identical, only
//! the transport differs.

use super::ops::{OpRegistry, TaskCtx};
use super::plan::{Action, PlayedRecord, Record, Source, TaskOutput, TaskSpec};
use crate::bag::{BagReader, BagWriter, Compression, MemoryChunkedFile};
use crate::error::{Error, Result};
use crate::msg::{Image, Message, Time};

/// Materialize a partition's input records from its source.
pub fn load_source(ctx: &TaskCtx, source: &Source) -> Result<Vec<Record>> {
    match source {
        Source::Inline { records } => Ok(records.clone()),
        Source::BagFile { data, topics } => {
            // Resolve through the worker's data plane (paper §3.2's
            // cache, generalized): a path reads from local disk on
            // first touch, a manifest fetches verified blocks from its
            // peer; either way repeats replay from RAM.
            let store = ctx.data.open(data)?;
            let mut reader = BagReader::open(store)?;
            let topic_refs: Option<Vec<&str>> = if topics.is_empty() {
                None
            } else {
                Some(topics.iter().map(|s| s.as_str()).collect())
            };
            let mut records = Vec::new();
            reader.for_each(topic_refs.as_deref(), |m| {
                records.push(
                    PlayedRecord {
                        topic: m.topic,
                        type_name: m.type_name,
                        time: m.time,
                        data: m.data,
                    }
                    .encode(),
                );
                Ok(())
            })?;
            Ok(records)
        }
        Source::SynthFrames { seed, count, width, height } => {
            let mut records = Vec::with_capacity(*count as usize);
            for i in 0..*count as u64 {
                let img = Image::synthetic(*width, *height, seed.wrapping_add(i));
                records.push(img.encode());
            }
            Ok(records)
        }
        Source::Range { start, end } => {
            Ok((*start..*end).map(|v| v.to_le_bytes().to_vec()).collect())
        }
        Source::Scenarios { scenarios } => {
            // Validate the shard up front: a poisoned scenario record is
            // deterministic data corruption, so it must fail the task
            // without a retry (Error::Sim is non-retryable).
            for (i, s) in scenarios.iter().enumerate() {
                crate::sim::decode_scenario(s).map_err(|e| {
                    Error::Sim(format!("scenario shard record {i} is poisoned: {e}"))
                })?;
            }
            Ok(scenarios.clone())
        }
        Source::BagSlices { data, topics, slices } => {
            // Same fail-fast contract as Scenarios: a poisoned slice
            // record is data corruption, not a transient fault. Each
            // output record is a self-contained slice job (data ref +
            // topics + slice) so the `run_replay` op needs no side
            // channel. An invalid data ref is equally permanent, so it
            // maps to non-retryable Error::Sim here.
            data.validate()
                .map_err(|e| Error::Sim(format!("bag slices data ref is invalid: {e}")))?;
            let mut records = Vec::with_capacity(slices.len());
            for (i, s) in slices.iter().enumerate() {
                let slice = crate::sim::replay::ReplaySlice::decode(s).map_err(|e| {
                    Error::Sim(format!("bag slice record {i} is poisoned: {e}"))
                })?;
                records.push(
                    crate::sim::replay::SliceJob {
                        data: data.clone(),
                        topics: topics.clone(),
                        slice,
                    }
                    .encode(),
                );
            }
            Ok(records)
        }
    }
}

/// Run one task end-to-end.
pub fn run_task(ctx: &TaskCtx, registry: &OpRegistry, spec: &TaskSpec) -> Result<TaskOutput> {
    let input = super::trace::span("source_load", || load_source(ctx, &spec.source))?;
    let records = registry.apply_chain(ctx, &spec.ops, input)?;
    match &spec.action {
        Action::Collect => Ok(TaskOutput::Records(records)),
        Action::Count => Ok(TaskOutput::Count(records.len() as u64)),
        Action::SaveBag { dir, topic, type_name } => {
            let mut w = BagWriter::new(
                MemoryChunkedFile::new(),
                Compression::None,
                4 * 1024 * 1024,
            )?;
            for (i, rec) in records.iter().enumerate() {
                w.write_raw(topic, type_name, Time::from_nanos(i as u64), rec.clone())?;
            }
            let store = w.finish()?;
            let path = format!("{dir}/part-{:05}.bag", spec.task_id);
            store.persist(&path)?;
            Ok(TaskOutput::Records(vec![path.into_bytes()]))
        }
        Action::Episodes => {
            for (i, rec) in records.iter().enumerate() {
                crate::sim::decode_result(rec).map_err(|e| {
                    Error::Sim(format!(
                        "episodes action: record {i} is not an EpisodeResult \
                         (is `run_episode` missing from the op chain?): {e}"
                    ))
                })?;
            }
            Ok(TaskOutput::Episodes(records))
        }
        Action::Replays => {
            for (i, rec) in records.iter().enumerate() {
                crate::sim::replay::ReplayVerdict::decode(rec).map_err(|e| {
                    Error::Sim(format!(
                        "replays action: record {i} is not a ReplayVerdict \
                         (is `run_replay` missing from the op chain?): {e}"
                    ))
                })?;
            }
            Ok(TaskOutput::Replays(records))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::OpCall;

    fn ctx() -> TaskCtx {
        TaskCtx::new(0, "artifacts")
    }

    #[test]
    fn range_source_count() {
        let reg = OpRegistry::with_builtins();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Range { start: 10, end: 60 },
            ops: vec![],
            action: Action::Count,
        };
        assert_eq!(run_task(&ctx(), &reg, &spec).unwrap(), TaskOutput::Count(50));
    }

    #[test]
    fn synth_frames_are_decodable_images() {
        let reg = OpRegistry::with_builtins();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::SynthFrames { seed: 3, count: 4, width: 8, height: 8 },
            ops: vec![],
            action: Action::Collect,
        };
        match run_task(&ctx(), &reg, &spec).unwrap() {
            TaskOutput::Records(rs) => {
                assert_eq!(rs.len(), 4);
                for r in rs {
                    Image::decode(&r).unwrap();
                }
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn bag_source_through_cache() {
        // Write a disk bag, read it through the executor twice; the second
        // read must be a cache hit.
        let dir = std::env::temp_dir().join("av_simd_test_exec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("exec_{}.bag", std::process::id()));
        {
            let mut w = crate::bag::create_disk(&path).unwrap();
            for i in 0..6u64 {
                w.write("/camera", Time::from_nanos(i), &Image::synthetic(4, 4, i)).unwrap();
            }
            w.finish().unwrap();
        }
        let ctx = ctx();
        let reg = OpRegistry::with_builtins();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::BagFile {
                data: super::super::data::DataRef::path(path.to_string_lossy().into_owned()),
                topics: vec![],
            },
            ops: vec![OpCall::new("take_payload", vec![])],
            action: Action::Count,
        };
        assert_eq!(run_task(&ctx, &reg, &spec).unwrap(), TaskOutput::Count(6));
        assert_eq!(run_task(&ctx, &reg, &spec).unwrap(), TaskOutput::Count(6));
        let (hits, misses, _) = ctx.data.cache().stats();
        assert_eq!(misses, 1, "first open misses");
        assert_eq!(hits, 1, "second open hits the memory cache");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scenario_source_validates_shard() {
        let reg = OpRegistry::with_builtins();
        crate::sim::register_sim_ops(&reg);
        let s = crate::sim::scenario_matrix(12.0)[0];
        let good = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Scenarios { scenarios: vec![crate::sim::encode_scenario(&s)] },
            ops: vec![OpCall::new("run_scenario", vec![])],
            action: Action::Count,
        };
        assert_eq!(run_task(&ctx(), &reg, &good).unwrap(), TaskOutput::Count(1));

        let poisoned = TaskSpec {
            source: Source::Scenarios { scenarios: vec![vec![0xff; 11]] },
            ..good
        };
        let err = run_task(&ctx(), &reg, &poisoned).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert!(!err.is_retryable(), "corrupt shard must not be retried");
    }

    #[test]
    fn episodes_action_rejects_non_results() {
        let reg = OpRegistry::with_builtins();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 0,
            attempt: 0,
            source: Source::Inline { records: vec![vec![1, 2, 3]] },
            ops: vec![],
            action: Action::Episodes,
        };
        let err = run_task(&ctx(), &reg, &spec).unwrap_err();
        assert!(err.to_string().contains("EpisodeResult"), "{err}");
    }

    #[test]
    fn save_bag_action_persists_partition() {
        let dir = std::env::temp_dir().join(format!("av_simd_test_save_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let reg = OpRegistry::with_builtins();
        let spec = TaskSpec {
            job_id: 1,
            task_id: 7,
            attempt: 0,
            source: Source::Inline { records: vec![vec![1, 2], vec![3]] },
            ops: vec![],
            action: Action::SaveBag {
                dir: dir.to_string_lossy().into_owned(),
                topic: "/out".into(),
                type_name: "raw".into(),
            },
        };
        let out = run_task(&ctx(), &reg, &spec).unwrap();
        let path = match out {
            TaskOutput::Records(rs) => String::from_utf8(rs[0].clone()).unwrap(),
            other => panic!("unexpected {other:?}"),
        };
        assert!(path.ends_with("part-00007.bag"));
        let mut r = crate::bag::open_disk(&path).unwrap();
        let msgs = r.play(None).unwrap();
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].data, vec![1, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
