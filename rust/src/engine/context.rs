//! Driver API: [`SimContext`] (the platform's `SparkContext`) and
//! [`Rdd`], the lazily-composed distributed dataset handle.
//!
//! An `Rdd` is lineage: per-partition [`Source`]s plus a chain of named
//! operator calls. Transformations append to the chain; actions
//! ([`Rdd::collect`], [`Rdd::count`], …) compile the lineage into one
//! task per partition and hand the batch to the scheduler.

use super::cluster::{Cluster, LocalCluster};
use super::ops::OpRegistry;
use super::plan::{Action, OpCall, Record, Source, TaskOutput, TaskSpec};
use super::remote::StandaloneCluster;
use super::scheduler::{run_job, JobReport};
use crate::config::{ClusterMode, PlatformConfig};
use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct ContextInner {
    cluster: Box<dyn Cluster>,
    registry: OpRegistry,
    job_counter: AtomicU64,
    max_retries: usize,
    default_parallelism: usize,
    last_report: std::sync::Mutex<Option<JobReport>>,
}

/// Driver-side entry point to the distributed engine.
#[derive(Clone)]
pub struct SimContext {
    inner: Arc<ContextInner>,
}

impl SimContext {
    /// Local (thread) cluster with `workers` workers.
    pub fn local(workers: usize) -> Self {
        let registry = crate::full_op_registry();
        let cluster = LocalCluster::new(workers, registry.clone(), "artifacts");
        Self::from_parts(Box::new(cluster), registry, 2, workers * 2)
    }

    /// Cluster per the platform config (local threads or standalone
    /// worker processes).
    pub fn from_config(cfg: &PlatformConfig) -> Result<Self> {
        let registry = crate::full_op_registry();
        let cluster: Box<dyn Cluster> = match cfg.cluster.mode {
            ClusterMode::Local => Box::new(LocalCluster::new(
                cfg.cluster.workers,
                registry.clone(),
                &cfg.perception.artifact_dir,
            )),
            ClusterMode::Standalone => Box::new(StandaloneCluster::launch(
                cfg.cluster.workers,
                cfg.cluster.base_port,
                &cfg.perception.artifact_dir,
            )?),
        };
        Ok(Self::from_parts(
            cluster,
            registry,
            cfg.cluster.task_retries,
            cfg.cluster.default_parallelism,
        ))
    }

    fn from_parts(
        cluster: Box<dyn Cluster>,
        registry: OpRegistry,
        max_retries: usize,
        default_parallelism: usize,
    ) -> Self {
        Self {
            inner: Arc::new(ContextInner {
                cluster,
                registry,
                job_counter: AtomicU64::new(1),
                max_retries,
                default_parallelism: default_parallelism.max(1),
                last_report: std::sync::Mutex::new(None),
            }),
        }
    }

    /// The operator registry (register custom ops before running jobs).
    pub fn registry(&self) -> &OpRegistry {
        &self.inner.registry
    }

    /// Number of workers in the underlying cluster.
    pub fn workers(&self) -> usize {
        self.inner.cluster.workers()
    }

    /// Backend name of the underlying cluster (`"local"` / `"standalone"`).
    pub fn backend(&self) -> &'static str {
        self.inner.cluster.backend()
    }

    /// Report of the most recently completed job.
    pub fn last_report(&self) -> Option<JobReport> {
        self.inner.last_report.lock().unwrap().clone()
    }

    /// Gracefully stop the underlying cluster (no-op for local pools).
    pub fn shutdown(&self) {
        self.inner.cluster.shutdown();
    }

    // ---- RDD constructors ----

    /// Distribute in-memory records across `partitions`.
    pub fn parallelize(&self, records: Vec<Record>, partitions: usize) -> Rdd {
        let p = partitions.max(1);
        let mut parts: Vec<Vec<Record>> = (0..p).map(|_| Vec::new()).collect();
        for (i, r) in records.into_iter().enumerate() {
            parts[i % p].push(r);
        }
        self.rdd(parts.into_iter().map(|records| Source::Inline { records }).collect())
    }

    /// One partition per `*.bag` file in `dir` (sorted for determinism).
    pub fn bag_dir(&self, dir: &str, topics: &[&str]) -> Result<Rdd> {
        let mut paths: Vec<String> = std::fs::read_dir(dir)
            .map_err(|e| Error::Engine(format!("bag_dir {dir}: {e}")))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().map(|x| x == "bag").unwrap_or(false))
            .map(|p| p.to_string_lossy().into_owned())
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(Error::Engine(format!("no .bag files in {dir}")));
        }
        let topics: Vec<String> = topics.iter().map(|s| s.to_string()).collect();
        Ok(self.rdd(
            paths
                .into_iter()
                .map(|path| Source::BagFile {
                    data: super::data::DataRef::path(path),
                    topics: topics.clone(),
                })
                .collect(),
        ))
    }

    /// Synthetic camera frames generated on the workers: `partitions`
    /// partitions of `frames_each` `width`×`height` RGB images.
    pub fn synth_frames(
        &self,
        partitions: usize,
        frames_each: u32,
        width: u32,
        height: u32,
        seed: u64,
    ) -> Rdd {
        self.rdd(
            (0..partitions.max(1) as u64)
                .map(|p| Source::SynthFrames {
                    seed: seed.wrapping_add(p.wrapping_mul(0x9e37_79b9)),
                    count: frames_each,
                    width,
                    height,
                })
                .collect(),
        )
    }

    /// Integers [0, n) split over the default parallelism.
    pub fn range(&self, n: u64) -> Rdd {
        let p = self.inner.default_parallelism as u64;
        let chunk = n.div_ceil(p).max(1);
        let mut sources = Vec::new();
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            sources.push(Source::Range { start, end });
            start = end;
        }
        if sources.is_empty() {
            sources.push(Source::Range { start: 0, end: 0 });
        }
        self.rdd(sources)
    }

    fn rdd(&self, sources: Vec<Source>) -> Rdd {
        Rdd { ctx: self.clone(), sources, ops: Vec::new() }
    }

    fn run(&self, tasks: Vec<TaskSpec>) -> Result<Vec<TaskOutput>> {
        let (outs, report) = run_job(self.inner.cluster.as_ref(), tasks, self.inner.max_retries)?;
        *self.inner.last_report.lock().unwrap() = Some(report);
        Ok(outs)
    }

    fn next_job_id(&self) -> u64 {
        self.inner.job_counter.fetch_add(1, Ordering::Relaxed)
    }
}

/// Lazily-composed distributed dataset.
#[derive(Clone)]
pub struct Rdd {
    ctx: SimContext,
    sources: Vec<Source>,
    ops: Vec<OpCall>,
}

impl Rdd {
    /// Number of partitions (= tasks this RDD compiles into).
    pub fn num_partitions(&self) -> usize {
        self.sources.len()
    }

    /// Append a named operator (must exist in the registry at run time).
    pub fn op(mut self, name: &str, params: Vec<u8>) -> Rdd {
        self.ops.push(OpCall::new(name, params));
        self
    }

    /// BinPipedRDD: pipe every partition through a child process running
    /// `logic` (paper §3.1).
    pub fn pipe(self, logic: &str) -> Rdd {
        self.op("binpipe", logic.as_bytes().to_vec())
    }

    /// Ablation: same logic, in-process (the JNI-design stand-in).
    pub fn pipe_inproc(self, logic: &str) -> Rdd {
        self.op("binpipe_inproc", logic.as_bytes().to_vec())
    }

    /// Keep only bag messages on `topic` (PlayedRecord partitions).
    pub fn filter_topic(self, topic: &str) -> Rdd {
        self.op("filter_topic", topic.as_bytes().to_vec())
    }

    /// Strip PlayedRecord framing down to raw message payloads.
    pub fn take_payload(self) -> Rdd {
        self.op("take_payload", vec![])
    }

    /// Calibrated per-record compute stall (see `simulate_compute` op).
    pub fn simulate_compute(self, micros_per_record: u64) -> Rdd {
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_varint(micros_per_record);
        self.op("simulate_compute", w.into_vec())
    }

    /// Keep the first `n` records of each partition.
    pub fn take_per_partition(self, n: u64) -> Rdd {
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_varint(n);
        self.op("take", w.into_vec())
    }

    fn tasks(&self, action: Action) -> Vec<TaskSpec> {
        let job_id = self.ctx.next_job_id();
        self.sources
            .iter()
            .enumerate()
            .map(|(i, source)| TaskSpec {
                job_id,
                task_id: i as u32,
                attempt: 0,
                source: source.clone(),
                ops: self.ops.clone(),
                action: action.clone(),
            })
            .collect()
    }

    // ---- actions ----

    /// Materialize every record on the driver.
    pub fn collect(&self) -> Result<Vec<Record>> {
        let outs = self.ctx.run(self.tasks(Action::Collect))?;
        let mut all = Vec::new();
        for o in outs {
            match o {
                TaskOutput::Records(mut rs) => all.append(&mut rs),
                other => return Err(Error::Engine(format!("collect got {other:?}"))),
            }
        }
        Ok(all)
    }

    /// Count records across all partitions.
    pub fn count(&self) -> Result<u64> {
        let outs = self.ctx.run(self.tasks(Action::Count))?;
        let mut total = 0;
        for o in outs {
            match o {
                TaskOutput::Count(n) => total += n,
                other => return Err(Error::Engine(format!("count got {other:?}"))),
            }
        }
        Ok(total)
    }

    /// Persist each partition as a bag under `dir`; returns written paths.
    pub fn save_bags(&self, dir: &str, topic: &str, type_name: &str) -> Result<Vec<String>> {
        std::fs::create_dir_all(dir)?;
        let outs = self.ctx.run(self.tasks(Action::SaveBag {
            dir: dir.to_string(),
            topic: topic.to_string(),
            type_name: type_name.to_string(),
        }))?;
        let mut paths = Vec::new();
        for o in outs {
            match o {
                TaskOutput::Records(rs) => {
                    for r in rs {
                        paths.push(String::from_utf8(r).map_err(|_| {
                            Error::Engine("save_bags returned non-utf8 path".into())
                        })?);
                    }
                }
                other => return Err(Error::Engine(format!("save got {other:?}"))),
            }
        }
        Ok(paths)
    }

    /// Driver-side shuffle: group records by the key produced by the
    /// registered `key_op` map operator (runs as a normal map, then the
    /// records are hash-grouped here — a two-stage job with a driver
    /// barrier, the honest small-cluster version of Spark's shuffle).
    /// Records must be encoded as `varint keylen ‖ key ‖ value`.
    pub fn group_by(&self, key_op: &str) -> Result<std::collections::HashMap<Vec<u8>, Vec<Record>>> {
        let keyed = self.clone().op(key_op, vec![]).collect()?;
        let mut groups: std::collections::HashMap<Vec<u8>, Vec<Record>> =
            std::collections::HashMap::new();
        for rec in keyed {
            let mut r = crate::util::bytes::ByteReader::new(&rec);
            let key = r.get_bytes_vec()?;
            let value = r.get_bytes_vec()?;
            groups.entry(key).or_default().push(value);
        }
        Ok(groups)
    }

    /// Redistribute current records across `partitions` (driver round
    /// trip; pairs with [`Rdd::group_by`] for two-stage pipelines).
    pub fn repartition(&self, partitions: usize) -> Result<Rdd> {
        let records = self.collect()?;
        Ok(self.ctx.parallelize(records, partitions))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelize_collect_roundtrip() {
        let sc = SimContext::local(3);
        let records: Vec<Record> = (0..10u8).map(|i| vec![i]).collect();
        let rdd = sc.parallelize(records.clone(), 4);
        assert_eq!(rdd.num_partitions(), 4);
        let mut out = rdd.collect().unwrap();
        out.sort();
        assert_eq!(out, records);
    }

    #[test]
    fn range_count() {
        let sc = SimContext::local(2);
        assert_eq!(sc.range(1000).count().unwrap(), 1000);
        assert_eq!(sc.range(0).count().unwrap(), 0);
    }

    #[test]
    fn synth_frames_partitions_differ() {
        let sc = SimContext::local(2);
        let rdd = sc.synth_frames(2, 3, 8, 8, 42);
        let frames = rdd.collect().unwrap();
        assert_eq!(frames.len(), 6);
        // partitions must not generate identical frames
        assert_ne!(frames[0], frames[3]);
    }

    #[test]
    fn custom_op_via_registry() {
        let sc = SimContext::local(2);
        sc.registry().register_map("double", |_c, _p, r| {
            Ok(r.iter().flat_map(|&b| [b, b]).collect())
        });
        let out = sc
            .parallelize(vec![vec![1], vec![2]], 2)
            .op("double", vec![])
            .collect()
            .unwrap();
        let mut out = out;
        out.sort();
        assert_eq!(out, vec![vec![1, 1], vec![2, 2]]);
    }

    #[test]
    fn take_per_partition_limits() {
        let sc = SimContext::local(2);
        let rdd = sc.parallelize((0..100u8).map(|i| vec![i]).collect(), 4);
        assert_eq!(rdd.take_per_partition(5).count().unwrap(), 20);
    }

    #[test]
    fn save_bags_writes_partitions() {
        let sc = SimContext::local(2);
        let dir = std::env::temp_dir().join(format!("av_simd_ctx_save_{}", std::process::id()));
        let rdd = sc.parallelize((0..8u8).map(|i| vec![i]).collect(), 2);
        let paths = rdd
            .save_bags(dir.to_str().unwrap(), "/rec", "raw")
            .unwrap();
        assert_eq!(paths.len(), 2);
        for p in &paths {
            assert!(std::path::Path::new(p).exists());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn group_by_hash_groups() {
        let sc = SimContext::local(2);
        // key = first byte parity, value = record
        sc.registry().register_map("key_parity", |_c, _p, r| {
            let mut w = crate::util::bytes::ByteWriter::new();
            w.put_bytes(&[r[0] % 2]);
            w.put_bytes(&r);
            Ok(w.into_vec())
        });
        let rdd = sc.parallelize((0..10u8).map(|i| vec![i]).collect(), 3);
        let groups = rdd.group_by("key_parity").unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[&vec![0u8]].len(), 5);
        assert_eq!(groups[&vec![1u8]].len(), 5);
    }

    #[test]
    fn job_report_is_recorded() {
        let sc = SimContext::local(2);
        sc.range(10).count().unwrap();
        let report = sc.last_report().unwrap();
        assert!(report.tasks >= 1);
        assert_eq!(report.retries, 0);
    }

    #[test]
    fn bag_dir_missing_is_error() {
        let sc = SimContext::local(1);
        assert!(sc.bag_dir("/definitely/not/here", &[]).is_err());
    }
}
