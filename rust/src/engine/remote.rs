//! Standalone cluster: worker *processes* over TCP.
//!
//! The driver either spawns N copies of this binary in `worker` mode
//! ([`StandaloneCluster::launch`]) or dials an externally managed fleet
//! from a [`super::deploy::ClusterSpec`] manifest
//! ([`StandaloneCluster::connect`] — hosts anywhere, not just
//! localhost). Every connection opens with the RPC version handshake,
//! so a stale worker binary is rejected before it can corrupt a job.
//!
//! Tasks stream out with one feeder thread per worker pulling from the
//! shared [`TaskStream`] (greedy load balancing, like Spark's executor
//! task slots). Dispatch is pipelined: each connection keeps up to
//! `PIPELINE_DEPTH` tasks in flight, so the next task's bytes are
//! already on the wire while the worker computes the current one. All
//! waiting is event-driven (condvars on the stream, blocking socket
//! reads) — there is no sleep-polling in the dispatch path. Lost
//! workers fail their in-flight tasks with a retryable error; the
//! scheduler re-queues them immediately and the stream continues on the
//! surviving workers.
//!
//! The fleet is elastic: [`StandaloneCluster::add_worker`] admits a
//! late-joining worker into every stream still running — the new feeder
//! starts pulling queued tasks immediately, which is how a sweep
//! absorbs capacity that comes up after the job started.

use super::cluster::Cluster;
use super::data::SwarmRegistry;
use super::deploy::ClusterSpec;
use super::fault::{FaultPlan, FAULT_TAG};
use super::plan::TaskSpec;
use super::stream::TaskStream;
use super::trace;
use super::worker::WorkerClient;
use crate::error::{Error, Result};
use std::collections::{HashSet, VecDeque};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// Max task attempts in flight per worker connection. Depth 2 hides the
/// request/response turnaround without hoarding tasks on a slow worker.
const PIPELINE_DEPTH: usize = 2;

/// Max encoded size of a frame sent while another task is already in
/// flight. The worker is single-threaded (it reads one task, computes,
/// then writes the reply), so a pipelined send must never be able to
/// fill the socket buffers while the worker is blocked writing a big
/// reply nobody is reading — that wedges both sides. Frames at or under
/// this size always fit in kernel buffering; bigger specs simply wait
/// for the pipeline to drain (the pre-pipelining protocol).
const PIPELINE_MAX_BYTES: usize = 64 * 1024;

/// A worker process + its RPC client. `child` is `None` for workers the
/// driver merely dialed (spec-connected fleets own their processes).
struct RemoteWorker {
    client: Mutex<Option<WorkerClient>>,
    child: Mutex<Option<Child>>,
    addr: String,
}

struct Workers {
    /// The fleet; grows through [`StandaloneCluster::add_worker`].
    workers: Mutex<Vec<Arc<RemoteWorker>>>,
    /// Streams opened on this cluster, kept weak so a finished stream
    /// (and its completions) can be dropped by the scheduler. Late
    /// joiners attach to every stream still alive here.
    streams: Mutex<Vec<Weak<TaskStream>>>,
    /// Which manifests each worker's block cache holds, fed by the
    /// `BlockAd` frames workers piggyback on task replies. Data sources
    /// consult it to order warm sibling peers ahead of the driver.
    swarm: SwarmRegistry,
    /// Injected-failure schedule for the feeder threads (inert unless
    /// built via [`StandaloneCluster::connect_with_faults`]).
    faults: FaultPlan,
}

/// Cluster of standalone worker processes (spawned locally or dialed
/// from a [`ClusterSpec`] manifest).
pub struct StandaloneCluster {
    inner: Arc<Workers>,
    /// True when this driver spawned the workers (shutdown stops them);
    /// false for [`StandaloneCluster::connect`]-mode clusters attached
    /// to an externally managed fleet, which stays up.
    owns_workers: bool,
}

impl StandaloneCluster {
    /// Spawn `n` worker processes on sequential ports starting at
    /// `base_port` and wait until all are reachable. Workers are copies
    /// of the current executable (`av-simd worker ...`); from an example
    /// or test binary — which has no `worker` subcommand — use
    /// [`StandaloneCluster::launch_program`] with the launcher path.
    pub fn launch(n: usize, base_port: u16, artifact_dir: &str) -> Result<Self> {
        let exe = std::env::current_exe()
            .map_err(|e| Error::Engine(format!("cannot locate current exe: {e}")))?;
        Self::launch_program(&exe, n, base_port, artifact_dir)
    }

    /// Like [`StandaloneCluster::launch`], but spawning an explicit
    /// worker binary (anything that serves `worker --listen ADDR --id N
    /// --artifacts DIR`, normally `target/release/av-simd`).
    pub fn launch_program(
        program: impl AsRef<std::path::Path>,
        n: usize,
        base_port: u16,
        artifact_dir: &str,
    ) -> Result<Self> {
        assert!(n >= 1);
        let exe = program.as_ref();
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let addr = format!("127.0.0.1:{}", base_port + i as u16);
            let child = Command::new(exe)
                .args([
                    "worker",
                    "--listen",
                    &addr,
                    "--id",
                    &i.to_string(),
                    "--artifacts",
                    artifact_dir,
                ])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| Error::Engine(format!("spawn worker {i} at {addr}: {e}")))?;
            workers.push(Arc::new(RemoteWorker {
                client: Mutex::new(None),
                child: Mutex::new(Some(child)),
                addr,
            }));
        }
        // Connect after all spawns so startup overlaps. The connect
        // handshake checks liveness + protocol version per worker.
        for (i, w) in workers.iter().enumerate() {
            let client = WorkerClient::connect(&w.addr, Duration::from_secs(20))
                .map_err(|e| Error::Engine(format!("worker {i}: {e}")))?;
            *w.client.lock().unwrap() = Some(client);
        }
        Ok(Self {
            inner: Arc::new(Workers {
                workers: Mutex::new(workers),
                streams: Mutex::new(Vec::new()),
                swarm: SwarmRegistry::default(),
                faults: FaultPlan::none(),
            }),
            owns_workers: true,
        })
    }

    /// Dial an externally managed fleet from a [`ClusterSpec`]: connect
    /// and version-handshake every endpoint in the manifest. The fleet
    /// is *not* stopped by [`Cluster::shutdown`] — it belongs to
    /// whatever launched it (use [`StandaloneCluster::stop_workers`] to
    /// stop it explicitly).
    pub fn connect(spec: &ClusterSpec) -> Result<Self> {
        Self::connect_with_faults(spec, FaultPlan::none())
    }

    /// Test-only flavor of [`StandaloneCluster::connect`]: the given
    /// [`FaultPlan`] is consulted by every feeder thread, so scheduled
    /// connection drops surface as real transport deaths (failed
    /// in-flight attempts, swarm eviction, feeder exit) without an
    /// actual network fault.
    pub fn connect_with_faults(spec: &ClusterSpec, faults: FaultPlan) -> Result<Self> {
        let mut workers = Vec::with_capacity(spec.workers.len());
        for endpoint in &spec.workers {
            let addr = endpoint.addr();
            let client = WorkerClient::connect(&addr, spec.connect_timeout)
                .map_err(|e| Error::Engine(format!("cluster '{}': {e}", spec.name)))?;
            workers.push(Arc::new(RemoteWorker {
                client: Mutex::new(Some(client)),
                child: Mutex::new(None),
                addr,
            }));
        }
        Ok(Self {
            inner: Arc::new(Workers {
                workers: Mutex::new(workers),
                streams: Mutex::new(Vec::new()),
                swarm: SwarmRegistry::default(),
                faults,
            }),
            owns_workers: false,
        })
    }

    /// Admit a late-joining worker into the fleet. The endpoint is
    /// dialed and version-handshaked like any other; on success it joins
    /// every stream still running — its feeder starts pulling queued
    /// tasks immediately — and serves all future streams.
    pub fn add_worker(&self, addr: &str, timeout: Duration) -> Result<()> {
        let client = WorkerClient::connect(addr, timeout)?;
        let worker = Arc::new(RemoteWorker {
            client: Mutex::new(Some(client)),
            child: Mutex::new(None),
            addr: addr.to_string(),
        });
        self.inner.workers.lock().unwrap().push(worker.clone());
        // join every live stream (prune dead/drained entries on the way)
        let live: Vec<Arc<TaskStream>> = {
            let mut streams = self.inner.streams.lock().unwrap();
            streams.retain(|s| s.upgrade().map(|s| !s.drained()).unwrap_or(false));
            streams.iter().filter_map(Weak::upgrade).collect()
        };
        for stream in live {
            stream.attach_worker();
            let w = worker.clone();
            let swarm = self.inner.swarm.clone();
            let faults = self.inner.faults.clone();
            std::thread::Builder::new()
                .name(format!("av-simd-feeder-join-{addr}"))
                .spawn(move || feeder_loop(&w, &stream, &swarm, &faults))
                .expect("spawn feeder thread");
        }
        Ok(())
    }

    /// Stop the fleet: send `Shutdown` to every reachable worker, then
    /// reap spawned children (graceful wait with capped backoff, kill on
    /// timeout). Failures are logged with the worker's `host:port` and
    /// how many exit polls were made — they never poison the other
    /// workers' shutdown.
    pub fn stop_workers(&self) {
        let workers: Vec<Arc<RemoteWorker>> = self.inner.workers.lock().unwrap().clone();
        for w in &workers {
            match w.client.lock().unwrap().as_mut() {
                Some(c) => {
                    if let Err(e) = c.shutdown() {
                        crate::logmsg!("warn", "shutdown rpc to worker {}: {e}", w.addr);
                    }
                }
                // The client is checked out only while a feeder owns the
                // connection (lock contention means we waited for it) or
                // after a transport death — either way the Shutdown RPC
                // cannot be sent; spawned children are still reaped below.
                None => crate::logmsg!(
                    "warn",
                    "worker {}: no live connection to send Shutdown (transport \
                     lost or stream still open); process reaping still applies",
                    w.addr
                ),
            }
        }
        for w in &workers {
            let mut child_guard = w.child.lock().unwrap();
            let Some(child) = child_guard.as_mut() else { continue };
            // Give it a moment to exit gracefully (exponential backoff —
            // `try_wait` has no blocking-with-timeout form), then kill.
            let deadline = Instant::now() + Duration::from_secs(2);
            let mut backoff = Duration::from_millis(1);
            let mut polls = 0usize;
            loop {
                polls += 1;
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_millis(50));
                    }
                    _ => {
                        crate::logmsg!(
                            "warn",
                            "worker {} did not exit after {polls} poll(s); killing",
                            w.addr
                        );
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

impl Cluster for StandaloneCluster {
    fn workers(&self) -> usize {
        self.inner.workers.lock().unwrap().len()
    }

    fn open_stream(&self) -> Arc<TaskStream> {
        let stream = TaskStream::new();
        // Register for late joiners *before* reading the worker list
        // (pruning finished streams on the way). Paired with add_worker
        // doing the opposite — worker first, then stream scan — this
        // closes the admission race: however the two interleave, a
        // joining worker either lands in the copy below or sees the
        // stream in the registry. The overlap case spawns a duplicate
        // feeder, which finds the client taken and detaches harmlessly.
        {
            let mut streams = self.inner.streams.lock().unwrap();
            streams.retain(|s| s.upgrade().map(|s| !s.drained()).unwrap_or(false));
            streams.push(Arc::downgrade(&stream));
        }
        let workers: Vec<Arc<RemoteWorker>> = self.inner.workers.lock().unwrap().clone();
        // Attach every worker *before* spawning any feeder, so an early
        // transport death cannot momentarily zero the worker count and
        // fail pending tasks while healthy feeders are still starting.
        for _ in &workers {
            stream.attach_worker();
        }
        for (i, w) in workers.into_iter().enumerate() {
            let stream2 = stream.clone();
            let swarm = self.inner.swarm.clone();
            let faults = self.inner.faults.clone();
            std::thread::Builder::new()
                .name(format!("av-simd-feeder-{i}"))
                .spawn(move || feeder_loop(&w, &stream2, &swarm, &faults))
                .expect("spawn feeder thread");
        }
        stream
    }

    fn swarm(&self) -> Option<SwarmRegistry> {
        Some(self.inner.swarm.clone())
    }

    fn shutdown(&self) {
        if self.owns_workers {
            self.stop_workers();
        }
        // connect-mode: the fleet is externally managed — leave it up
    }

    fn backend(&self) -> &'static str {
        "standalone"
    }
}

impl Drop for StandaloneCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One in-flight attempt on a connection.
struct InFlight {
    seq: u64,
    spec: TaskSpec,
    queue_wait: Duration,
    sent_at: Instant,
}

/// Feeder: stream tasks to one worker connection, keeping up to
/// [`PIPELINE_DEPTH`] in flight, until the stream closes or the
/// transport dies. Detaches from the stream on every exit path. Swarm
/// cache advertisements riding on task replies are forwarded to the
/// cluster's registry after every receive — and evicted again if this
/// connection dies, so cold fetchers never burn a connect-timeout on a
/// corpse (a *clean* drain keeps the ads: the worker process and its
/// block cache are still up, only this stream is done with them).
fn feeder_loop(w: &RemoteWorker, stream: &TaskStream, swarm: &SwarmRegistry, faults: &FaultPlan) {
    struct Detach<'a>(&'a TaskStream);
    impl Drop for Detach<'_> {
        fn drop(&mut self) {
            self.0.detach_worker();
        }
    }
    let _detach = Detach(stream);

    let mut guard = w.client.lock().unwrap();
    // Own the client for the session (put back on clean exit; a dead
    // transport stays taken, which is how the worker is marked lost).
    let Some(mut client) = guard.take() else {
        return; // worker previously declared dead (or serving another stream)
    };

    // Block-server peers this connection advertised into the swarm;
    // dropped from the registry on every transport-death exit.
    let mut ad_peers: HashSet<String> = HashSet::new();
    let mut inflight: VecDeque<InFlight> = VecDeque::new();
    // A pulled task too large to pipeline safely; sent once the
    // pipeline drains. Invariant: only Some while `inflight` is
    // non-empty or between fill and the next fill pass.
    let mut deferred: Option<(u64, TaskSpec, Duration)> = None;
    loop {
        // Fill the pipeline. Only block on the stream when nothing is in
        // flight — otherwise a pending reply could starve behind a wait.
        while inflight.len() < PIPELINE_DEPTH {
            let pulled = if let Some(t) = deferred.take() {
                t
            } else if inflight.is_empty() {
                match stream.pop_task() {
                    Some(t) => t,
                    None => {
                        *guard = Some(client); // stream closed and drained
                        return;
                    }
                }
            } else {
                match stream.try_pop() {
                    Some(t) => t,
                    None => break,
                }
            };
            let (seq, spec, queue_wait) = pulled;
            let encoded = spec.encode();
            if !inflight.is_empty() && encoded.len() > PIPELINE_MAX_BYTES {
                // too big to ship behind an outstanding reply (deadlock
                // risk — see PIPELINE_MAX_BYTES); wait for the drain
                deferred = Some((seq, spec, queue_wait));
                break;
            }
            if let Err(e) = client.send_task_encoded_traced(encoded, trace::enabled()) {
                stream.complete(
                    seq,
                    spec,
                    Err(Error::Transport(format!("worker {}: {e}", w.addr))),
                    queue_wait,
                    Duration::ZERO,
                );
                fail_undispatched(stream, &mut inflight, &mut deferred, &w.addr);
                evict_ads(swarm, &ad_peers, &w.addr);
                return; // transport unusable: client stays dropped
            }
            inflight.push_back(InFlight { seq, spec, queue_wait, sent_at: Instant::now() });
        }

        // Read one reply (FIFO per connection).
        let f = inflight.pop_front().expect("pipeline fill guarantees one in flight");
        if faults.connection_should_drop() {
            // Injected transport death: drop the socket (the worker sees
            // EOF and re-accepts) and fail this connection's attempts
            // exactly like a real wire cut.
            drop(client);
            stream.complete(
                f.seq,
                f.spec,
                Err(Error::Transport(format!(
                    "{FAULT_TAG}: connection to worker {} dropped", w.addr
                ))),
                f.queue_wait,
                f.sent_at.elapsed(),
            );
            fail_undispatched(stream, &mut inflight, &mut deferred, &w.addr);
            evict_ads(swarm, &ad_peers, &w.addr);
            return;
        }
        let reply = client.recv_reply(f.spec.task_id);
        for (peer, manifests) in client.take_advertisements() {
            swarm.advertise(&peer, &manifests);
            ad_peers.insert(peer);
        }
        // Forward piggybacked span batches to the installed trace sink,
        // shifting worker timestamps onto the driver's clock.
        let batches = client.take_trace_batches();
        if !batches.is_empty() {
            if let Some(log) = trace::active() {
                for batch in &batches {
                    log.absorb(batch, client.clock_offset_ns);
                }
            }
        }
        match reply {
            Ok(out) => {
                stream.complete(f.seq, f.spec, Ok(out), f.queue_wait, f.sent_at.elapsed())
            }
            Err(e) => {
                let transport_dead = e.is_transport_death();
                let wrapped = if transport_dead {
                    Error::Transport(format!("worker {}: {e}", w.addr))
                } else {
                    Error::Engine(format!("worker {}: {e}", w.addr))
                };
                stream.complete(f.seq, f.spec, Err(wrapped), f.queue_wait, f.sent_at.elapsed());
                if transport_dead {
                    // Worker lost: fail everything queued behind the dead
                    // reply; surviving workers drain the stream.
                    fail_undispatched(stream, &mut inflight, &mut deferred, &w.addr);
                    evict_ads(swarm, &ad_peers, &w.addr);
                    return;
                }
            }
        }
    }
}

/// Drop a dead connection's block-server advertisements from the swarm
/// (see [`SwarmRegistry::evict`]).
fn evict_ads(swarm: &SwarmRegistry, ad_peers: &HashSet<String>, addr: &str) {
    for peer in ad_peers {
        swarm.evict(peer);
    }
    if !ad_peers.is_empty() {
        crate::logmsg!(
            "info",
            "worker {addr} lost: evicted {} swarm advertisement peer(s)",
            ad_peers.len()
        );
    }
}

/// Fail every attempt still held by a dead connection — queued replies
/// and any deferred jumbo task (retryable — the scheduler re-runs them
/// on surviving workers).
fn fail_undispatched(
    stream: &TaskStream,
    inflight: &mut VecDeque<InFlight>,
    deferred: &mut Option<(u64, TaskSpec, Duration)>,
    addr: &str,
) {
    while let Some(f) = inflight.pop_front() {
        stream.complete(
            f.seq,
            f.spec,
            Err(Error::Transport(format!("worker {addr} lost with task in flight"))),
            f.queue_wait,
            f.sent_at.elapsed(),
        );
    }
    if let Some((seq, spec, queue_wait)) = deferred.take() {
        // the deferred task was never dispatched — don't claim it was
        stream.complete(
            seq,
            spec,
            Err(Error::Transport(format!(
                "worker {addr} lost before dispatch: queued task never sent"
            ))),
            queue_wait,
            Duration::ZERO,
        );
    }
}

// Integration tests for StandaloneCluster live in rust/tests/ — the
// spawn paths need the built `av-simd` binary on disk, and the
// spec-connect / late-join paths drive in-process `worker::serve`
// threads (rust/tests/deploy.rs).
