//! Standalone cluster: worker *processes* over TCP.
//!
//! The driver spawns N copies of this binary in `worker` mode, connects a
//! [`WorkerClient`] to each, and streams tasks out with one feeder
//! thread per worker pulling from the shared [`TaskStream`] (greedy load
//! balancing, like Spark's executor task slots). Dispatch is pipelined:
//! each connection keeps up to [`PIPELINE_DEPTH`] tasks in flight, so
//! the next task's bytes are already on the wire while the worker
//! computes the current one. All waiting is event-driven (condvars on
//! the stream, blocking socket reads) — there is no sleep-polling in the
//! dispatch path. Lost workers fail their in-flight tasks with a
//! retryable error; the scheduler re-queues them immediately and the
//! stream continues on the surviving workers.

use super::cluster::Cluster;
use super::plan::TaskSpec;
use super::stream::TaskStream;
use super::worker::WorkerClient;
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Max task attempts in flight per worker connection. Depth 2 hides the
/// request/response turnaround without hoarding tasks on a slow worker.
const PIPELINE_DEPTH: usize = 2;

/// Max encoded size of a frame sent while another task is already in
/// flight. The worker is single-threaded (it reads one task, computes,
/// then writes the reply), so a pipelined send must never be able to
/// fill the socket buffers while the worker is blocked writing a big
/// reply nobody is reading — that wedges both sides. Frames at or under
/// this size always fit in kernel buffering; bigger specs simply wait
/// for the pipeline to drain (the pre-pipelining protocol).
const PIPELINE_MAX_BYTES: usize = 64 * 1024;

/// A spawned worker process + its RPC client.
struct RemoteWorker {
    client: Mutex<Option<WorkerClient>>,
    child: Mutex<Child>,
    addr: String,
}

struct Workers {
    workers: Vec<RemoteWorker>,
}

/// Cluster of spawned worker processes.
pub struct StandaloneCluster {
    inner: Arc<Workers>,
}

impl StandaloneCluster {
    /// Spawn `n` worker processes on sequential ports starting at
    /// `base_port` and wait until all are reachable. Workers are copies
    /// of the current executable (`av-simd worker ...`); from an example
    /// or test binary — which has no `worker` subcommand — use
    /// [`StandaloneCluster::launch_program`] with the launcher path.
    pub fn launch(n: usize, base_port: u16, artifact_dir: &str) -> Result<Self> {
        let exe = std::env::current_exe()
            .map_err(|e| Error::Engine(format!("cannot locate current exe: {e}")))?;
        Self::launch_program(&exe, n, base_port, artifact_dir)
    }

    /// Like [`StandaloneCluster::launch`], but spawning an explicit
    /// worker binary (anything that serves `worker --listen ADDR --id N
    /// --artifacts DIR`, normally `target/release/av-simd`).
    pub fn launch_program(
        program: impl AsRef<std::path::Path>,
        n: usize,
        base_port: u16,
        artifact_dir: &str,
    ) -> Result<Self> {
        assert!(n >= 1);
        let exe = program.as_ref();
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let addr = format!("127.0.0.1:{}", base_port + i as u16);
            let child = Command::new(exe)
                .args([
                    "worker",
                    "--listen",
                    &addr,
                    "--id",
                    &i.to_string(),
                    "--artifacts",
                    artifact_dir,
                ])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| Error::Engine(format!("spawn worker {i}: {e}")))?;
            workers.push(RemoteWorker {
                client: Mutex::new(None),
                child: Mutex::new(child),
                addr,
            });
        }
        // Connect after all spawns so startup overlaps.
        for (i, w) in workers.iter().enumerate() {
            let client =
                WorkerClient::connect(&w.addr, std::time::Duration::from_secs(20))
                    .map_err(|e| Error::Engine(format!("worker {i}: {e}")))?;
            *w.client.lock().unwrap() = Some(client);
        }
        Ok(Self { inner: Arc::new(Workers { workers }) })
    }
}

impl Cluster for StandaloneCluster {
    fn workers(&self) -> usize {
        self.inner.workers.len()
    }

    fn open_stream(&self) -> Arc<TaskStream> {
        let stream = TaskStream::new();
        // Attach every worker *before* spawning any feeder, so an early
        // transport death cannot momentarily zero the worker count and
        // fail pending tasks while healthy feeders are still starting.
        for _ in &self.inner.workers {
            stream.attach_worker();
        }
        for i in 0..self.inner.workers.len() {
            let inner = self.inner.clone();
            let stream = stream.clone();
            std::thread::Builder::new()
                .name(format!("av-simd-feeder-{i}"))
                .spawn(move || feeder_loop(&inner.workers[i], &stream))
                .expect("spawn feeder thread");
        }
        stream
    }

    fn shutdown(&self) {
        for w in &self.inner.workers {
            if let Some(c) = w.client.lock().unwrap().as_mut() {
                let _ = c.shutdown();
            }
        }
        for w in &self.inner.workers {
            let mut child = w.child.lock().unwrap();
            // Give it a moment to exit gracefully (exponential backoff —
            // `try_wait` has no blocking-with-timeout form), then kill.
            let deadline = Instant::now() + Duration::from_secs(2);
            let mut backoff = Duration::from_millis(1);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_millis(50));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }

    fn backend(&self) -> &'static str {
        "standalone"
    }
}

impl Drop for StandaloneCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One in-flight attempt on a connection.
struct InFlight {
    seq: u64,
    spec: TaskSpec,
    queue_wait: Duration,
    sent_at: Instant,
}

/// Feeder: stream tasks to one worker connection, keeping up to
/// [`PIPELINE_DEPTH`] in flight, until the stream closes or the
/// transport dies. Detaches from the stream on every exit path.
fn feeder_loop(w: &RemoteWorker, stream: &TaskStream) {
    struct Detach<'a>(&'a TaskStream);
    impl Drop for Detach<'_> {
        fn drop(&mut self) {
            self.0.detach_worker();
        }
    }
    let _detach = Detach(stream);

    let mut guard = w.client.lock().unwrap();
    // Own the client for the session (put back on clean exit; a dead
    // transport stays taken, which is how the worker is marked lost).
    let Some(mut client) = guard.take() else {
        return; // worker previously declared dead
    };

    let mut inflight: VecDeque<InFlight> = VecDeque::new();
    // A pulled task too large to pipeline safely; sent once the
    // pipeline drains. Invariant: only Some while `inflight` is
    // non-empty or between fill and the next fill pass.
    let mut deferred: Option<(u64, TaskSpec, Duration)> = None;
    loop {
        // Fill the pipeline. Only block on the stream when nothing is in
        // flight — otherwise a pending reply could starve behind a wait.
        while inflight.len() < PIPELINE_DEPTH {
            let pulled = if let Some(t) = deferred.take() {
                t
            } else if inflight.is_empty() {
                match stream.pop_task() {
                    Some(t) => t,
                    None => {
                        *guard = Some(client); // stream closed and drained
                        return;
                    }
                }
            } else {
                match stream.try_pop() {
                    Some(t) => t,
                    None => break,
                }
            };
            let (seq, spec, queue_wait) = pulled;
            let encoded = spec.encode();
            if !inflight.is_empty() && encoded.len() > PIPELINE_MAX_BYTES {
                // too big to ship behind an outstanding reply (deadlock
                // risk — see PIPELINE_MAX_BYTES); wait for the drain
                deferred = Some((seq, spec, queue_wait));
                break;
            }
            if let Err(e) = client.send_task_encoded(encoded) {
                stream.complete(
                    seq,
                    spec,
                    Err(Error::Engine(format!("worker {}: {e}", w.addr))),
                    queue_wait,
                    Duration::ZERO,
                );
                fail_undispatched(stream, &mut inflight, &mut deferred, &w.addr);
                return; // transport unusable: client stays dropped
            }
            inflight.push_back(InFlight { seq, spec, queue_wait, sent_at: Instant::now() });
        }

        // Read one reply (FIFO per connection).
        let f = inflight.pop_front().expect("pipeline fill guarantees one in flight");
        match client.recv_reply(f.spec.task_id) {
            Ok(out) => {
                stream.complete(f.seq, f.spec, Ok(out), f.queue_wait, f.sent_at.elapsed())
            }
            Err(e) => {
                let msg = e.to_string();
                let transport_dead = matches!(e, Error::Io(_))
                    || msg.contains("hung up")
                    || msg.contains("died mid-frame");
                stream.complete(
                    f.seq,
                    f.spec,
                    Err(Error::Engine(format!("worker {}: {e}", w.addr))),
                    f.queue_wait,
                    f.sent_at.elapsed(),
                );
                if transport_dead {
                    // Worker lost: fail everything queued behind the dead
                    // reply; surviving workers drain the stream.
                    fail_undispatched(stream, &mut inflight, &mut deferred, &w.addr);
                    return;
                }
            }
        }
    }
}

/// Fail every attempt still held by a dead connection — queued replies
/// and any deferred jumbo task (retryable — the scheduler re-runs them
/// on surviving workers).
fn fail_undispatched(
    stream: &TaskStream,
    inflight: &mut VecDeque<InFlight>,
    deferred: &mut Option<(u64, TaskSpec, Duration)>,
    addr: &str,
) {
    while let Some(f) = inflight.pop_front() {
        stream.complete(
            f.seq,
            f.spec,
            Err(Error::Engine(format!("worker {addr} lost with task in flight"))),
            f.queue_wait,
            f.sent_at.elapsed(),
        );
    }
    if let Some((seq, spec, queue_wait)) = deferred.take() {
        stream.complete(
            seq,
            spec,
            Err(Error::Engine(format!("worker {addr} lost with task in flight"))),
            queue_wait,
            Duration::ZERO,
        );
    }
}

// Integration tests for StandaloneCluster live in rust/tests/ — they need
// the built `av-simd` binary on disk, which unit tests don't have.
