//! Standalone cluster: worker *processes* over TCP.
//!
//! The driver spawns N copies of this binary in `worker` mode, connects a
//! [`WorkerClient`] to each, and fans task batches out with one feeder
//! thread per worker pulling from a shared queue (greedy load balancing,
//! like Spark's executor task slots). Lost workers fail their in-flight
//! task with a retryable error; the scheduler re-queues it and the batch
//! continues on the surviving workers.

use super::cluster::Cluster;
use super::plan::{TaskOutput, TaskSpec};
use super::worker::WorkerClient;
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

/// A spawned worker process + its RPC client.
struct RemoteWorker {
    client: Mutex<Option<WorkerClient>>,
    child: Mutex<Child>,
    addr: String,
}

/// Cluster of spawned worker processes.
pub struct StandaloneCluster {
    workers: Vec<RemoteWorker>,
}

impl StandaloneCluster {
    /// Spawn `n` worker processes on sequential ports starting at
    /// `base_port` and wait until all are reachable. Workers are copies
    /// of the current executable (`av-simd worker ...`); from an example
    /// or test binary — which has no `worker` subcommand — use
    /// [`StandaloneCluster::launch_program`] with the launcher path.
    pub fn launch(n: usize, base_port: u16, artifact_dir: &str) -> Result<Self> {
        let exe = std::env::current_exe()
            .map_err(|e| Error::Engine(format!("cannot locate current exe: {e}")))?;
        Self::launch_program(&exe, n, base_port, artifact_dir)
    }

    /// Like [`StandaloneCluster::launch`], but spawning an explicit
    /// worker binary (anything that serves `worker --listen ADDR --id N
    /// --artifacts DIR`, normally `target/release/av-simd`).
    pub fn launch_program(
        program: impl AsRef<std::path::Path>,
        n: usize,
        base_port: u16,
        artifact_dir: &str,
    ) -> Result<Self> {
        assert!(n >= 1);
        let exe = program.as_ref();
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let addr = format!("127.0.0.1:{}", base_port + i as u16);
            let child = Command::new(exe)
                .args([
                    "worker",
                    "--listen",
                    &addr,
                    "--id",
                    &i.to_string(),
                    "--artifacts",
                    artifact_dir,
                ])
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| Error::Engine(format!("spawn worker {i}: {e}")))?;
            workers.push(RemoteWorker {
                client: Mutex::new(None),
                child: Mutex::new(child),
                addr,
            });
        }
        // Connect after all spawns so startup overlaps.
        for (i, w) in workers.iter().enumerate() {
            let client =
                WorkerClient::connect(&w.addr, std::time::Duration::from_secs(20))
                    .map_err(|e| Error::Engine(format!("worker {i}: {e}")))?;
            *w.client.lock().unwrap() = Some(client);
        }
        Ok(Self { workers })
    }
}

impl Cluster for StandaloneCluster {
    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn run_tasks(&self, tasks: &[TaskSpec]) -> Vec<Result<TaskOutput>> {
        let queue: Mutex<VecDeque<usize>> = Mutex::new((0..tasks.len()).collect());
        let results: Vec<Mutex<Option<Result<TaskOutput>>>> =
            (0..tasks.len()).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|scope| {
            for w in &self.workers {
                scope.spawn(|| {
                    let mut guard = w.client.lock().unwrap();
                    let client = match guard.as_mut() {
                        Some(c) => c,
                        None => return, // worker previously declared dead
                    };
                    loop {
                        let idx = match queue.lock().unwrap().pop_front() {
                            Some(i) => i,
                            None => break,
                        };
                        match client.run_task(&tasks[idx]) {
                            Ok(out) => {
                                *results[idx].lock().unwrap() = Some(Ok(out));
                            }
                            Err(e) => {
                                let transport_dead = matches!(e, Error::Io(_))
                                    || e.to_string().contains("hung up");
                                *results[idx].lock().unwrap() =
                                    Some(Err(Error::Engine(format!(
                                        "worker {}: {e}",
                                        w.addr
                                    ))));
                                if transport_dead {
                                    // Worker lost: stop pulling; surviving
                                    // workers drain the queue.
                                    *guard = None;
                                    break;
                                }
                            }
                        }
                    }
                });
            }
        });

        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .unwrap_or_else(|| Err(Error::Engine("task never dispatched".into())))
            })
            .collect()
    }

    fn shutdown(&self) {
        for w in &self.workers {
            if let Some(c) = w.client.lock().unwrap().as_mut() {
                let _ = c.shutdown();
            }
        }
        for w in &self.workers {
            let mut child = w.child.lock().unwrap();
            // Give it a moment to exit gracefully, then kill.
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if std::time::Instant::now() < deadline => {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }

    fn backend(&self) -> &'static str {
        "standalone"
    }
}

impl Drop for StandaloneCluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// Integration tests for StandaloneCluster live in rust/tests/ — they need
// the built `av-simd` binary on disk, which unit tests don't have.
