//! Streaming task pipeline — the work-stealing channel between the
//! scheduler and a cluster's workers.
//!
//! The old execution model was barrier-synchronous: `run_job` handed the
//! whole batch to `Cluster::run_tasks`, waited for every task (so one
//! straggler shard idled every worker between retry waves), then ran a
//! full extra round per wave. A [`TaskStream`] replaces that: the driver
//! submits tasks as sequenced work items, idle workers pull them the
//! moment a slot frees up, and completions flow back in *finish* order.
//! Failed tasks re-enter the queue immediately — a retry overlaps the
//! still-running stragglers instead of waiting for them.
//!
//! The stream is backend-agnostic: `LocalCluster`'s persistent thread
//! pool and `StandaloneCluster`'s per-connection feeders both speak it.
//! All waiting is event-driven (condvars), never sleep-polling.

use super::plan::{TaskOutput, TaskSpec};
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One finished task attempt, delivered to the driver in finish order.
#[derive(Debug)]
pub struct Completion {
    /// Driver-assigned sequence number (the slot this result fills; the
    /// scheduler uses the original task index so outputs stay ordered).
    pub seq: u64,
    /// The spec that ran — returned so a retry can be resubmitted with a
    /// bumped attempt number without the driver keeping a copy.
    pub spec: TaskSpec,
    /// The attempt's outcome (task errors come through as `Err`).
    pub result: Result<TaskOutput>,
    /// Time the attempt spent queued before a worker picked it up.
    pub queue_wait: Duration,
    /// Execution wall time (includes RPC transport for remote workers).
    pub wall: Duration,
}

struct StreamInner {
    pending: VecDeque<(u64, TaskSpec, Instant)>,
    done: VecDeque<Completion>,
    in_flight: usize,
    closed: bool,
    /// Set by [`TaskStream::abandon`]: completions still owed by
    /// executing workers are dropped on delivery instead of queued.
    discard: bool,
    /// Attached workers (standalone feeders attach/detach; the local
    /// pool polls without attaching and sets `tracks_workers` false).
    workers: usize,
    tracks_workers: bool,
}

/// Outcome of a bounded wait for a completion
/// ([`TaskStream::next_completion_timeout`]).
#[derive(Debug)]
pub enum CompletionWait {
    /// A finished attempt arrived.
    Completion(Completion),
    /// The stream is closed and fully drained — no completion will ever
    /// arrive again.
    Drained,
    /// The timeout elapsed with nothing to deliver (tasks may still be
    /// pending or executing).
    TimedOut,
}

/// A live streaming session between the scheduler and a set of workers.
///
/// Driver side: [`TaskStream::submit`] / [`TaskStream::next_completion`]
/// / [`TaskStream::close`]. Worker side: [`TaskStream::pop_task`] (or
/// the non-blocking [`TaskStream::try_pop`]) and
/// [`TaskStream::complete`].
pub struct TaskStream {
    inner: Mutex<StreamInner>,
    /// Workers blocked waiting for tasks.
    work_ready: Condvar,
    /// The driver blocked waiting for completions.
    done_ready: Condvar,
    /// Optional backend hook fired after submit/close (the local pool
    /// uses it to wake threads that multiplex several streams).
    waker: Mutex<Option<Box<dyn Fn() + Send + Sync>>>,
}

impl TaskStream {
    /// Create an empty stream (no waker, no workers attached).
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(StreamInner {
                pending: VecDeque::new(),
                done: VecDeque::new(),
                in_flight: 0,
                closed: false,
                discard: false,
                workers: 0,
                tracks_workers: false,
            }),
            work_ready: Condvar::new(),
            done_ready: Condvar::new(),
            waker: Mutex::new(None),
        })
    }

    /// Install the backend wake hook (called once by `open_stream`).
    pub fn set_waker(&self, f: impl Fn() + Send + Sync + 'static) {
        *self.waker.lock().unwrap() = Some(Box::new(f));
    }

    fn wake_backend(&self) {
        if let Some(f) = self.waker.lock().unwrap().as_ref() {
            f();
        }
    }

    /// Enqueue a task attempt under sequence slot `seq`. Retries reuse
    /// the seq of the attempt they replace. If every tracked worker has
    /// already detached the task fails immediately (there is nobody left
    /// to run it) instead of hanging the driver.
    pub fn submit(&self, seq: u64, spec: TaskSpec) {
        {
            let mut g = self.inner.lock().unwrap();
            debug_assert!(!g.closed, "submit after close");
            if g.tracks_workers && g.workers == 0 {
                g.done.push_back(Completion {
                    seq,
                    spec,
                    result: Err(Error::Transport(
                        "no workers left to run task: all workers lost".into(),
                    )),
                    queue_wait: Duration::ZERO,
                    wall: Duration::ZERO,
                });
                self.done_ready.notify_all();
                return;
            }
            g.pending.push_back((seq, spec, Instant::now()));
            self.work_ready.notify_one();
        }
        self.wake_backend();
    }

    /// Declare that no further tasks will be submitted. Blocked workers
    /// drain the queue and then see `None` from [`TaskStream::pop_task`].
    pub fn close(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.closed = true;
            self.work_ready.notify_all();
            self.done_ready.notify_all();
        }
        self.wake_backend();
    }

    /// True once the stream is closed and no task is pending (workers
    /// multiplexing several streams use this to drop finished ones).
    pub fn drained(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.closed && g.pending.is_empty()
    }

    /// Worker side: blocking pull. Returns `None` only after
    /// [`TaskStream::close`] with the queue empty. The returned
    /// `Duration` is the task's queue wait.
    pub fn pop_task(&self) -> Option<(u64, TaskSpec, Duration)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some((seq, spec, enqueued)) = g.pending.pop_front() {
                g.in_flight += 1;
                return Some((seq, spec, enqueued.elapsed()));
            }
            if g.closed {
                return None;
            }
            g = self.work_ready.wait(g).unwrap();
        }
    }

    /// Worker side: non-blocking pull (the local pool scans several
    /// streams and must never park on one while another has work).
    pub fn try_pop(&self) -> Option<(u64, TaskSpec, Duration)> {
        let mut g = self.inner.lock().unwrap();
        let (seq, spec, enqueued) = g.pending.pop_front()?;
        g.in_flight += 1;
        Some((seq, spec, enqueued.elapsed()))
    }

    /// Worker side: deliver a finished attempt. After
    /// [`TaskStream::abandon`] the result is dropped (the in-flight
    /// count still settles, so worker bookkeeping stays consistent).
    pub fn complete(
        &self,
        seq: u64,
        spec: TaskSpec,
        result: Result<TaskOutput>,
        queue_wait: Duration,
        wall: Duration,
    ) {
        let mut g = self.inner.lock().unwrap();
        debug_assert!(g.in_flight > 0, "complete without matching pop");
        g.in_flight = g.in_flight.saturating_sub(1);
        if !g.discard {
            g.done.push_back(Completion { seq, spec, result, queue_wait, wall });
        }
        self.done_ready.notify_all();
    }

    /// Driver side: blocking wait for the next completion, in finish
    /// order. Returns `None` once the stream is closed and fully drained
    /// (no pending, no in-flight, no undelivered completions).
    pub fn next_completion(&self) -> Option<Completion> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(c) = g.done.pop_front() {
                return Some(c);
            }
            if g.closed && g.pending.is_empty() && g.in_flight == 0 {
                return None;
            }
            g = self.done_ready.wait(g).unwrap();
        }
    }

    /// Driver side: bounded wait for the next completion. Distinguishes
    /// "nothing yet" ([`CompletionWait::TimedOut`]) from "never again"
    /// ([`CompletionWait::Drained`]) — the speculative scheduler polls
    /// with this so stragglers are noticed even while no completions
    /// arrive.
    pub fn next_completion_timeout(&self, timeout: Duration) -> CompletionWait {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(c) = g.done.pop_front() {
                return CompletionWait::Completion(c);
            }
            if g.closed && g.pending.is_empty() && g.in_flight == 0 {
                return CompletionWait::Drained;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return CompletionWait::TimedOut;
            }
            g = self.done_ready.wait_timeout(g, left).unwrap().0;
        }
    }

    /// Attempts currently executing on workers (popped, not completed).
    pub fn in_flight(&self) -> usize {
        self.inner.lock().unwrap().in_flight
    }

    /// Tasks queued but not yet picked up by a worker.
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// Close *and* disown the stream: queued tasks and undelivered
    /// completions are dropped, and any attempt still executing has its
    /// eventual completion discarded on delivery. The speculative
    /// scheduler uses this to return the moment every sequence slot is
    /// resolved instead of waiting out losing straggler attempts.
    pub fn abandon(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.closed = true;
            g.discard = true;
            g.pending.clear();
            g.done.clear();
            self.work_ready.notify_all();
            self.done_ready.notify_all();
        }
        self.wake_backend();
    }

    /// RAII close guard: closes the stream when dropped (idempotent), so
    /// worker loops always unblock even if the driver unwinds mid-job.
    /// Call as `stream.clone().close_on_drop()` to keep using the stream.
    pub fn close_on_drop(self: Arc<Self>) -> CloseGuard {
        CloseGuard(self)
    }

    /// Register a worker serving this stream (standalone feeders). Once
    /// any worker has attached, the stream knows its worker population
    /// and can fail tasks when the last one detaches.
    pub fn attach_worker(&self) {
        let mut g = self.inner.lock().unwrap();
        g.tracks_workers = true;
        g.workers += 1;
    }

    /// A tracked worker left (drained stream or lost transport). When
    /// the last one goes, everything still pending fails with a
    /// retryable error so the driver never waits on a dead cluster.
    pub fn detach_worker(&self) {
        let mut g = self.inner.lock().unwrap();
        g.workers = g.workers.saturating_sub(1);
        if g.workers == 0 && !g.pending.is_empty() {
            while let Some((seq, spec, enqueued)) = g.pending.pop_front() {
                let queue_wait = enqueued.elapsed();
                g.done.push_back(Completion {
                    seq,
                    spec,
                    result: Err(Error::Transport(
                        "no workers left to run task: all workers lost".into(),
                    )),
                    queue_wait,
                    wall: Duration::ZERO,
                });
            }
            self.done_ready.notify_all();
        }
    }
}

/// Closes its stream on drop (see [`TaskStream::close_on_drop`]).
pub struct CloseGuard(Arc<TaskStream>);

impl Drop for CloseGuard {
    fn drop(&mut self) {
        self.0.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::plan::{Action, Source};

    fn spec(id: u32) -> TaskSpec {
        TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: 1 },
            ops: vec![],
            action: Action::Count,
        }
    }

    #[test]
    fn completions_flow_in_finish_order() {
        let s = TaskStream::new();
        s.submit(0, spec(0));
        s.submit(1, spec(1));
        let (seq_a, spec_a, qw_a) = s.pop_task().unwrap();
        let (seq_b, spec_b, qw_b) = s.pop_task().unwrap();
        assert_eq!((seq_a, seq_b), (0, 1));
        // finish b first: the driver must see b first
        s.complete(seq_b, spec_b, Ok(TaskOutput::Count(2)), qw_b, Duration::ZERO);
        s.complete(seq_a, spec_a, Ok(TaskOutput::Count(1)), qw_a, Duration::ZERO);
        assert_eq!(s.next_completion().unwrap().seq, 1);
        assert_eq!(s.next_completion().unwrap().seq, 0);
        s.close();
        assert!(s.next_completion().is_none());
    }

    #[test]
    fn close_unblocks_workers() {
        let s = TaskStream::new();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop_task());
        std::thread::sleep(Duration::from_millis(20));
        s.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn last_detach_fails_pending_tasks() {
        let s = TaskStream::new();
        s.attach_worker();
        s.submit(0, spec(0));
        s.submit(1, spec(1));
        let (seq, sp, qw) = s.pop_task().unwrap();
        s.complete(seq, sp, Ok(TaskOutput::Count(1)), qw, Duration::ZERO);
        s.detach_worker(); // worker lost with task 1 still queued
        let c0 = s.next_completion().unwrap();
        assert!(c0.result.is_ok());
        let c1 = s.next_completion().unwrap();
        assert_eq!(c1.seq, 1);
        let err = c1.result.unwrap_err();
        assert!(err.to_string().contains("no workers left"), "{err}");
        assert!(err.is_retryable(), "worker loss must stay retryable");
        // resubmits against a dead stream fail immediately, not hang
        s.submit(1, spec(1));
        assert!(s.next_completion().unwrap().result.is_err());
    }

    #[test]
    fn abandon_discards_late_completions() {
        let s = TaskStream::new();
        s.submit(0, spec(0));
        s.submit(1, spec(1));
        let (seq, sp, qw) = s.pop_task().unwrap();
        s.abandon(); // task 1 still queued: dropped; task 0 executing
        assert_eq!(s.pending(), 0, "queued work dropped");
        assert_eq!(s.in_flight(), 1, "executing attempt still tracked");
        s.complete(seq, sp, Ok(TaskOutput::Count(1)), qw, Duration::ZERO);
        assert_eq!(s.in_flight(), 0, "late completion settles bookkeeping");
        assert!(s.next_completion().is_none(), "late completion discarded");
        assert!(s.drained());
    }

    #[test]
    fn timeout_wait_distinguishes_timeout_from_drained() {
        let s = TaskStream::new();
        s.submit(0, spec(0));
        let (seq, sp, qw) = s.pop_task().unwrap();
        match s.next_completion_timeout(Duration::from_millis(10)) {
            CompletionWait::TimedOut => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        s.complete(seq, sp, Ok(TaskOutput::Count(1)), qw, Duration::ZERO);
        match s.next_completion_timeout(Duration::from_millis(10)) {
            CompletionWait::Completion(c) => assert_eq!(c.seq, 0),
            other => panic!("expected Completion, got {other:?}"),
        }
        s.close();
        match s.next_completion_timeout(Duration::from_millis(10)) {
            CompletionWait::Drained => {}
            other => panic!("expected Drained, got {other:?}"),
        }
    }

    #[test]
    fn cross_thread_pipeline_completes() {
        let s = TaskStream::new();
        let worker = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut served = 0u64;
                while let Some((seq, sp, qw)) = s.pop_task() {
                    let out = TaskOutput::Count(seq);
                    s.complete(seq, sp, Ok(out), qw, Duration::from_micros(1));
                    served += 1;
                }
                served
            })
        };
        for i in 0..32 {
            s.submit(i, spec(i as u32));
        }
        let mut got = 0;
        while got < 32 {
            let c = s.next_completion().unwrap();
            assert_eq!(c.result.unwrap(), TaskOutput::Count(c.seq));
            got += 1;
        }
        s.close();
        assert_eq!(worker.join().unwrap(), 32);
    }
}
