//! Deployment layer: `ClusterSpec` manifests for multi-host standalone
//! clusters.
//!
//! The paper's platform treats the worker fleet as a managed resource —
//! a Spark manager owns N playback nodes spread over many machines. The
//! deploy layer is our equivalent: a [`ClusterSpec`] names every worker
//! endpoint (`host:port`, with per-host capacity expansion), how long to
//! wait for each to come up, and optionally how to launch workers on
//! *this* machine. Specs are plain files (TOML or JSON — provisioning
//! systems prefer JSON, humans prefer TOML) so the same manifest drives
//! `av-simd deploy`, `av-simd sweep --cluster-spec`, and
//! [`super::remote::StandaloneCluster::connect`].
//!
//! Health checking goes through the RPC handshake
//! ([`super::worker::WorkerClient::handshake`]): every probe verifies
//! both liveness and protocol version, so a stale binary on one box is
//! caught at deploy time, not mid-sweep.
//!
//! ```
//! use av_simd::engine::deploy::ClusterSpec;
//!
//! let spec = ClusterSpec::from_toml_text(r#"
//!     [cluster]
//!     name = "lab"
//!     connect_timeout_ms = 5000
//!
//!     [workers]
//!     hosts = ["10.0.0.1:7077*2", "10.0.0.2:7077"]
//!     capacity = 1
//! "#).unwrap();
//! // "*2" expands to two sequential ports on 10.0.0.1
//! assert_eq!(spec.addrs(), vec![
//!     "10.0.0.1:7077".to_string(),
//!     "10.0.0.1:7078".to_string(),
//!     "10.0.0.2:7077".to_string(),
//! ]);
//! ```

use super::checkpoint::CheckpointConfig;
use super::scheduler::Speculation;
use super::worker::WorkerClient;
use crate::config::{flatten_json, parse_toml, TomlValue};
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

/// One worker endpoint in a [`ClusterSpec`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerEndpoint {
    /// Hostname or IP address.
    pub host: String,
    /// TCP port the worker listens on.
    pub port: u16,
}

impl WorkerEndpoint {
    /// The `host:port` dial string.
    pub fn addr(&self) -> String {
        format!("{}:{}", self.host, self.port)
    }

    /// True when the endpoint is on this machine (loopback), i.e. a
    /// candidate for [`launch_local_workers`].
    pub fn is_local(&self) -> bool {
        matches!(self.host.as_str(), "127.0.0.1" | "localhost" | "::1")
    }

    /// Parse one manifest entry into endpoints. Entries are
    /// `host:port` (one worker), `host:port*N` (N worker *processes* on
    /// sequential ports starting at `port`), or `host:port+N` (N
    /// connections — task slots — to one multi-slot worker process on
    /// that single port, see `worker --slots`); with no suffix the
    /// spec-wide `capacity` applies as `*capacity`. The two suffixes
    /// cannot be combined.
    pub fn parse(entry: &str, default_capacity: usize) -> Result<Vec<WorkerEndpoint>> {
        if entry.contains('*') && entry.contains('+') {
            return Err(Error::Config(format!(
                "cluster spec: '{entry}' mixes '*N' (processes on sequential \
                 ports) with '+N' (slots on one port) — use one or the other"
            )));
        }
        // `+N`: N duplicate endpoints — the driver dials the same
        // host:port once per slot
        if let Some((addr, n)) = entry.rsplit_once('+') {
            let slots: usize = n.trim().parse().map_err(|_| {
                Error::Config(format!("cluster spec: bad slot count in '{entry}'"))
            })?;
            if slots == 0 {
                return Err(Error::Config(format!(
                    "cluster spec: zero slots in '{entry}'"
                )));
            }
            let (host, port) = Self::split_host_port(addr.trim(), entry)?;
            return Ok((0..slots)
                .map(|_| WorkerEndpoint { host: host.to_string(), port })
                .collect());
        }
        let (addr, count) = match entry.rsplit_once('*') {
            Some((addr, n)) => {
                let n: usize = n.trim().parse().map_err(|_| {
                    Error::Config(format!("cluster spec: bad capacity in '{entry}'"))
                })?;
                (addr.trim(), n)
            }
            None => (entry.trim(), default_capacity),
        };
        if count == 0 {
            return Err(Error::Config(format!(
                "cluster spec: zero capacity in '{entry}'"
            )));
        }
        let (host, port) = Self::split_host_port(addr, entry)?;
        if (port as usize) + count - 1 > u16::MAX as usize {
            return Err(Error::Config(format!(
                "cluster spec: '{entry}' expands past port 65535"
            )));
        }
        Ok((0..count)
            .map(|j| WorkerEndpoint { host: host.to_string(), port: port + j as u16 })
            .collect())
    }

    fn split_host_port<'a>(addr: &'a str, entry: &str) -> Result<(&'a str, u16)> {
        let (host, port) = addr.rsplit_once(':').ok_or_else(|| {
            Error::Config(format!("cluster spec: '{entry}' is not host:port"))
        })?;
        if host.is_empty() {
            return Err(Error::Config(format!("cluster spec: empty host in '{entry}'")));
        }
        let port: u16 = port.parse().map_err(|_| {
            Error::Config(format!("cluster spec: bad port in '{entry}'"))
        })?;
        Ok((host, port))
    }
}

/// A deployable cluster manifest: every worker endpoint the driver
/// should dial, plus connection and launch parameters. See the module
/// docs for the file format.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Human-readable fleet name (shows up in errors and the deploy
    /// status table).
    pub name: String,
    /// Expanded worker endpoints, in manifest order.
    pub workers: Vec<WorkerEndpoint>,
    /// Per-endpoint connect/handshake budget.
    pub connect_timeout: Duration,
    /// Artifact directory passed to locally launched workers.
    pub artifact_dir: String,
    /// Worker binary for [`launch_local_workers`] (usually
    /// `target/release/av-simd`); `None` means the fleet is launched by
    /// something else (systemd, k8s, ssh loops).
    pub launch_program: Option<String>,
    /// Block-store root on the *driver* host for data-plane publishes
    /// (`[storage] root = ...`): `av-simd replay --publish` against
    /// this spec publishes the bag there and serves it to the fleet.
    pub store_root: Option<String>,
    /// Hostname workers should dial to reach the driver's block server
    /// (`[storage] advertise = ...`). Defaults to `127.0.0.1`, which is
    /// only right for single-box fleets — multi-host manifests must set
    /// the driver's reachable address.
    pub advertise_host: Option<String>,
    /// Speculative straggler re-execution policy for jobs against this
    /// fleet (`[speculation]` section: `enabled`, `multiplier`,
    /// `min_samples`). Naming the section enables speculation unless
    /// `enabled = false` is given; `None` means the manifest is silent
    /// and the driver's own default (off) applies.
    pub speculation: Option<Speculation>,
    /// Durable job checkpointing (`[checkpoint]` section: `root`,
    /// `every`, `resume`). Naming the section turns checkpointing on
    /// for `sweep`/`replay` runs against this fleet; `None` leaves it
    /// to the driver's `--checkpoint` flag.
    pub checkpoint: Option<CheckpointConfig>,
}

impl ClusterSpec {
    /// Load a manifest from disk, dispatching on content: files whose
    /// first non-whitespace byte is `{` parse as JSON, everything else
    /// as the TOML subset.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read cluster spec {}: {e}", path.display())))?;
        Self::load_from_str(&text)
    }

    /// Parse a manifest from text with the same content dispatch as
    /// [`ClusterSpec::load`] (leading `{` → JSON, otherwise TOML).
    pub fn load_from_str(text: &str) -> Result<Self> {
        if text.trim_start().starts_with('{') {
            Self::from_json_text(text)
        } else {
            Self::from_toml_text(text)
        }
    }

    /// Parse a TOML-subset manifest.
    pub fn from_toml_text(text: &str) -> Result<Self> {
        Self::from_map(&parse_toml(text)?)
    }

    /// Parse a JSON manifest (same sections and keys as the TOML form).
    pub fn from_json_text(text: &str) -> Result<Self> {
        Self::from_map(&flatten_json(text)?)
    }

    /// Build a spec from the flat `"section.key"` map both parsers
    /// produce. Unknown keys are errors — manifest typos fail loudly.
    pub fn from_map(doc: &BTreeMap<String, TomlValue>) -> Result<Self> {
        let mut name = "cluster".to_string();
        let mut connect_timeout = Duration::from_secs(20);
        let mut artifact_dir = "artifacts".to_string();
        let mut launch_program = None;
        let mut store_root = None;
        let mut advertise_host = None;
        let mut speculation: Option<Speculation> = None;
        let mut checkpoint: Option<CheckpointConfig> = None;
        let mut hosts: Vec<String> = Vec::new();
        let mut capacity = 1usize;
        for (key, val) in doc {
            match key.as_str() {
                "cluster.name" => name = val.as_str()?.to_string(),
                "cluster.connect_timeout_ms" => {
                    connect_timeout = Duration::from_millis(val.as_usize()? as u64)
                }
                "cluster.artifact_dir" => artifact_dir = val.as_str()?.to_string(),
                "workers.hosts" => hosts = val.as_str_array()?.to_vec(),
                "workers.capacity" => capacity = val.as_usize()?,
                "launch.program" => launch_program = Some(val.as_str()?.to_string()),
                "storage.root" => store_root = Some(val.as_str()?.to_string()),
                "storage.advertise" => advertise_host = Some(val.as_str()?.to_string()),
                "speculation.enabled" => {
                    speculation.get_or_insert_with(Speculation::on).enabled = val.as_bool()?
                }
                "speculation.multiplier" => {
                    let m = val.as_f64()?;
                    if !(m.is_finite() && m > 0.0) {
                        return Err(Error::Config(format!(
                            "cluster spec: speculation.multiplier must be a \
                             positive number, got {m}"
                        )));
                    }
                    speculation.get_or_insert_with(Speculation::on).multiplier = m;
                }
                "speculation.min_samples" => {
                    speculation.get_or_insert_with(Speculation::on).min_samples =
                        val.as_usize()?
                }
                "checkpoint.root" => {
                    checkpoint.get_or_insert_with(CheckpointConfig::default).root =
                        val.as_str()?.to_string()
                }
                "checkpoint.every" => {
                    let every = val.as_usize()?;
                    if every == 0 {
                        return Err(Error::Config(
                            "cluster spec: checkpoint.every must be >= 1".into(),
                        ));
                    }
                    checkpoint.get_or_insert_with(CheckpointConfig::default).every = every;
                }
                "checkpoint.resume" => {
                    checkpoint.get_or_insert_with(CheckpointConfig::default).resume =
                        val.as_bool()?
                }
                other => {
                    return Err(Error::Config(format!(
                        "cluster spec: unknown key '{other}'"
                    )))
                }
            }
        }
        if capacity == 0 {
            return Err(Error::Config("cluster spec: workers.capacity must be >= 1".into()));
        }
        let mut workers = Vec::new();
        // An addr may repeat *within* one entry (`host:port+N` opens N
        // slot connections to one worker on purpose), but the same addr
        // appearing in two different entries is a manifest mistake that
        // would double-dial one worker.
        let mut seen = std::collections::BTreeSet::new();
        for entry in &hosts {
            let expanded = WorkerEndpoint::parse(entry, capacity)?;
            let mut entry_addrs = std::collections::BTreeSet::new();
            for w in &expanded {
                if !entry_addrs.insert(w.addr()) {
                    continue; // intra-entry duplicate: intended slots
                }
                if !seen.insert(w.addr()) {
                    return Err(Error::Config(format!(
                        "cluster spec: duplicate endpoint {}",
                        w.addr()
                    )));
                }
            }
            workers.extend(expanded);
        }
        if workers.is_empty() {
            return Err(Error::Config(
                "cluster spec: workers.hosts must name at least one endpoint".into(),
            ));
        }
        Ok(Self {
            name,
            workers,
            connect_timeout,
            artifact_dir,
            launch_program,
            store_root,
            advertise_host,
            speculation,
            checkpoint,
        })
    }

    /// Dial strings for every endpoint, in manifest order.
    pub fn addrs(&self) -> Vec<String> {
        self.workers.iter().map(WorkerEndpoint::addr).collect()
    }
}

/// Outcome of health-checking one endpoint (see [`probe`]).
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    /// The endpoint that was dialed.
    pub addr: String,
    /// The worker's self-reported id, when the handshake succeeded.
    pub worker_id: Option<u64>,
    /// The failure, when it did not.
    pub error: Option<String>,
}

impl WorkerHealth {
    /// True when the worker answered the version handshake.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Health-check every endpoint in the spec: TCP connect + the
/// [`WorkerClient::handshake`] version RPC. Never fails as a whole —
/// each endpoint reports independently so an operator sees the full
/// fleet state in one pass. Endpoints are probed *concurrently* (one
/// thread each), so a fleet with several dead boxes reports after one
/// `connect_timeout`, not one per dead box. Probing is read-only: the
/// probe connection closes after the handshake and the worker keeps
/// serving. Results come back in manifest order.
pub fn probe(spec: &ClusterSpec) -> Vec<WorkerHealth> {
    let timeout = spec.connect_timeout;
    std::thread::scope(|s| {
        let handles: Vec<_> = spec
            .workers
            .iter()
            .map(|w| {
                s.spawn(move || {
                    let addr = w.addr();
                    match WorkerClient::connect(&addr, timeout) {
                        Ok(client) => WorkerHealth {
                            addr,
                            worker_id: Some(client.worker_id),
                            error: None,
                        },
                        Err(e) => {
                            WorkerHealth { addr, worker_id: None, error: Some(e.to_string()) }
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("probe thread panicked"))
            .collect()
    })
}

/// One worker's live telemetry snapshot (see [`probe_stats`]).
#[derive(Debug, Clone)]
pub struct WorkerStats {
    /// The endpoint that was dialed.
    pub addr: String,
    /// The worker's self-reported id, when the handshake succeeded.
    pub worker_id: Option<u64>,
    /// The worker's metrics snapshot, when the fetch succeeded.
    pub snapshot: Option<crate::metrics::MetricsSnapshot>,
    /// The failure, when it did not.
    pub error: Option<String>,
}

/// Fetch a live [`crate::metrics::MetricsSnapshot`] from every endpoint
/// in the spec (the `av-simd top` / `deploy --probe --stats` data
/// source). Like [`probe`]: concurrent, never fails as a whole,
/// read-only, results in manifest order.
pub fn probe_stats(spec: &ClusterSpec) -> Vec<WorkerStats> {
    let timeout = spec.connect_timeout;
    std::thread::scope(|s| {
        let handles: Vec<_> = spec
            .workers
            .iter()
            .map(|w| {
                s.spawn(move || {
                    let addr = w.addr();
                    match WorkerClient::connect(&addr, timeout) {
                        Ok(mut client) => {
                            let worker_id = Some(client.worker_id);
                            match client.fetch_stats() {
                                Ok(snap) => WorkerStats {
                                    addr,
                                    worker_id,
                                    snapshot: Some(snap),
                                    error: None,
                                },
                                Err(e) => WorkerStats {
                                    addr,
                                    worker_id,
                                    snapshot: None,
                                    error: Some(e.to_string()),
                                },
                            }
                        }
                        Err(e) => WorkerStats {
                            addr,
                            worker_id: None,
                            snapshot: None,
                            error: Some(e.to_string()),
                        },
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("stats probe thread panicked"))
            .collect()
    })
}

/// Render a fleet stats table (the `av-simd top` body): one row per
/// worker with task counts, cache hit rate, bytes served from the block
/// cache, and slot occupancy. Unreachable workers render their error.
pub fn render_stats(stats: &[WorkerStats]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "  {:<24} {:>3}  {:>6} {:>6}  {:>6}  {:>12}  {:>6}\n",
        "worker", "id", "done", "failed", "hit%", "served", "slots"
    ));
    for w in stats {
        let id = w
            .worker_id
            .map(|i| i.to_string())
            .unwrap_or_else(|| "-".to_string());
        match &w.snapshot {
            Some(s) => {
                let hits = s.gauge("worker_cache_hits");
                let misses = s.gauge("worker_cache_misses");
                let lookups = hits + misses;
                let hit_pct = if lookups == 0 {
                    "-".to_string()
                } else {
                    format!("{:.1}", hits as f64 * 100.0 / lookups as f64)
                };
                out.push_str(&format!(
                    "  {:<24} {:>3}  {:>6} {:>6}  {:>6}  {:>12}  {:>3}/{}\n",
                    w.addr,
                    id,
                    s.counter("worker_tasks_done"),
                    s.counter("worker_tasks_failed"),
                    hit_pct,
                    crate::util::human_bytes(s.counter("block_bytes_served")),
                    s.gauge("worker_slots_busy"),
                    s.gauge("worker_slots_total"),
                ));
            }
            None => {
                out.push_str(&format!(
                    "  {:<24} {:>3}  DOWN {}\n",
                    w.addr,
                    id,
                    w.error.as_deref().unwrap_or("unknown")
                ));
            }
        }
    }
    out
}

/// Spawn a worker process (via the spec's `launch.program`) for every
/// *unique loopback* endpoint in the spec, detached — the children
/// outlive the calling process, so `av-simd deploy --launch` then exit
/// leaves a serving fleet behind. An endpoint that appears `N` times
/// (the `host:port+N` slot syntax) gets **one** process launched with
/// `--slots N`, matching the `N` connections drivers will open to it.
/// Remote endpoints are skipped (launching over SSH/orchestrators is
/// the operator's side of the contract — see `docs/OPERATIONS.md`);
/// returns the spawned children in first-appearance order alongside how
/// many endpoints were skipped.
pub fn launch_local_workers(
    spec: &ClusterSpec,
) -> Result<(Vec<std::process::Child>, usize)> {
    let program = spec.launch_program.as_deref().ok_or_else(|| {
        Error::Config("cluster spec has no [launch] program to spawn workers with".into())
    })?;
    // group endpoints: (addr, slot count), first-appearance order
    let mut order: Vec<String> = Vec::new();
    let mut slots: BTreeMap<String, usize> = BTreeMap::new();
    let mut skipped = 0usize;
    for w in &spec.workers {
        if !w.is_local() {
            skipped += 1;
            continue;
        }
        let addr = w.addr();
        match slots.get_mut(&addr) {
            Some(n) => *n += 1,
            None => {
                order.push(addr.clone());
                slots.insert(addr, 1);
            }
        }
    }
    let mut children = Vec::new();
    for (i, addr) in order.iter().enumerate() {
        let n_slots = slots[addr];
        let child = std::process::Command::new(program)
            .args([
                "worker",
                "--listen",
                addr,
                "--id",
                &i.to_string(),
                "--slots",
                &n_slots.to_string(),
                "--artifacts",
                &spec.artifact_dir,
            ])
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::inherit())
            .spawn()
            .map_err(|e| {
                Error::Engine(format!("launch worker {i} at {addr} via '{program}': {e}"))
            })?;
        children.push(child);
    }
    Ok((children, skipped))
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOML_SPEC: &str = r#"
        # two-box lab fleet
        [cluster]
        name = "lab"
        connect_timeout_ms = 1500
        artifact_dir = "artifacts"

        [workers]
        hosts = ["10.0.0.1:7077", "10.0.0.2:7100*3"]
        capacity = 2

        [launch]
        program = "target/release/av-simd"
    "#;

    const JSON_SPEC: &str = r#"{
        "cluster": {"name": "lab", "connect_timeout_ms": 1500, "artifact_dir": "artifacts"},
        "workers": {"hosts": ["10.0.0.1:7077", "10.0.0.2:7100*3"], "capacity": 2},
        "launch": {"program": "target/release/av-simd"}
    }"#;

    #[test]
    fn toml_and_json_manifests_parse_identically() {
        let a = ClusterSpec::from_toml_text(TOML_SPEC).unwrap();
        let b = ClusterSpec::from_json_text(JSON_SPEC).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.name, "lab");
        assert_eq!(a.connect_timeout, Duration::from_millis(1500));
        // capacity 2 for the first entry, explicit *3 for the second
        assert_eq!(
            a.addrs(),
            vec![
                "10.0.0.1:7077".to_string(),
                "10.0.0.1:7078".to_string(),
                "10.0.0.2:7100".to_string(),
                "10.0.0.2:7101".to_string(),
                "10.0.0.2:7102".to_string(),
            ]
        );
        assert_eq!(a.launch_program.as_deref(), Some("target/release/av-simd"));
    }

    #[test]
    fn defaults_fill_in() {
        let spec =
            ClusterSpec::from_toml_text("[workers]\nhosts = [\"127.0.0.1:7500\"]\n").unwrap();
        assert_eq!(spec.name, "cluster");
        assert_eq!(spec.connect_timeout, Duration::from_secs(20));
        assert_eq!(spec.artifact_dir, "artifacts");
        assert!(spec.launch_program.is_none());
        assert!(spec.store_root.is_none());
        assert!(spec.advertise_host.is_none());
        assert!(spec.speculation.is_none());
        assert!(spec.checkpoint.is_none());
        assert!(spec.workers[0].is_local());
    }

    #[test]
    fn storage_section_parses() {
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.2:7077\"]\n\
             [storage]\nroot = \"/srv/av-store\"\nadvertise = \"10.0.0.1\"\n",
        )
        .unwrap();
        assert_eq!(spec.store_root.as_deref(), Some("/srv/av-store"));
        assert_eq!(spec.advertise_host.as_deref(), Some("10.0.0.1"));
    }

    #[test]
    fn speculation_section_parses() {
        // naming any key enables speculation with defaults filled in
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.2:7077\"]\n\
             [speculation]\nmultiplier = 2.0\n",
        )
        .unwrap();
        let s = spec.speculation.unwrap();
        assert!(s.enabled);
        assert_eq!(s.multiplier, 2.0);
        assert_eq!(s.min_samples, Speculation::default().min_samples);
        // explicit opt-out keeps tuned values but disables
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.2:7077\"]\n\
             [speculation]\nenabled = false\nmin_samples = 9\n",
        )
        .unwrap();
        let s = spec.speculation.unwrap();
        assert!(!s.enabled);
        assert_eq!(s.min_samples, 9);
        // nonsense multipliers fail loudly
        for bad in ["0.0", "-1.5", "nan"] {
            let toml = format!(
                "[workers]\nhosts = [\"h:7077\"]\n[speculation]\nmultiplier = {bad}\n"
            );
            assert!(ClusterSpec::from_toml_text(&toml).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn checkpoint_section_parses() {
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.2:7077\"]\n\
             [checkpoint]\nroot = \"/srv/av-ckpt\"\nevery = 4\nresume = true\n",
        )
        .unwrap();
        let ck = spec.checkpoint.unwrap();
        assert_eq!(ck.root, "/srv/av-ckpt");
        assert_eq!(ck.every, 4);
        assert!(ck.resume);
        // naming any key fills the rest with defaults
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.2:7077\"]\n[checkpoint]\nevery = 2\n",
        )
        .unwrap();
        let ck = spec.checkpoint.unwrap();
        assert_eq!(ck.root, CheckpointConfig::default().root);
        assert_eq!(ck.every, 2);
        assert!(!ck.resume);
        // a zero cadence would never flush — reject it
        assert!(ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"h:7077\"]\n[checkpoint]\nevery = 0\n"
        )
        .is_err());
    }

    #[test]
    fn bad_specs_fail_loudly() {
        // no workers
        assert!(ClusterSpec::from_toml_text("[cluster]\nname = \"x\"\n").is_err());
        // unknown key
        assert!(ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"h:1\"]\nbogus = 1\n"
        )
        .is_err());
        // malformed endpoints
        for entry in ["nohost", "h:notaport", ":7077", "h:70000", "h:7077*0", "h:65535*2"] {
            let toml = format!("[workers]\nhosts = [\"{entry}\"]\n");
            assert!(ClusterSpec::from_toml_text(&toml).is_err(), "accepted '{entry}'");
        }
        // duplicate endpoint after expansion
        assert!(ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"h:7077*2\", \"h:7078\"]\n"
        )
        .is_err());
        // zero capacity
        assert!(ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"h:7077\"]\ncapacity = 0\n"
        )
        .is_err());
    }

    #[test]
    fn slot_syntax_expands_to_duplicate_endpoints() {
        let spec = ClusterSpec::from_toml_text(
            "[workers]\nhosts = [\"10.0.0.1:7077+3\", \"10.0.0.2:7077\"]\n",
        )
        .unwrap();
        assert_eq!(
            spec.addrs(),
            vec![
                "10.0.0.1:7077".to_string(),
                "10.0.0.1:7077".to_string(),
                "10.0.0.1:7077".to_string(),
                "10.0.0.2:7077".to_string(),
            ]
        );
        // zero slots, mixed suffixes, cross-entry duplicates all fail
        for bad in [
            "[workers]\nhosts = [\"h:7077+0\"]\n",
            "[workers]\nhosts = [\"h:7077+2*2\"]\n",
            "[workers]\nhosts = [\"h:7077+nope\"]\n",
            "[workers]\nhosts = [\"h:7077+2\", \"h:7077\"]\n",
        ] {
            assert!(ClusterSpec::from_toml_text(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn load_dispatches_on_content() {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_spec_{}_{:x}",
            std::process::id(),
            crate::util::now_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let toml_path = dir.join("fleet.toml");
        std::fs::write(&toml_path, TOML_SPEC).unwrap();
        let json_path = dir.join("fleet.json");
        std::fs::write(&json_path, JSON_SPEC).unwrap();
        let a = ClusterSpec::load(&toml_path).unwrap();
        let b = ClusterSpec::load(&json_path).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn probe_reports_per_endpoint() {
        // nothing listens on the reserved port: the probe must report the
        // failure (with the endpoint) rather than erroring out entirely
        let spec = ClusterSpec {
            name: "t".into(),
            workers: vec![WorkerEndpoint { host: "127.0.0.1".into(), port: 1 }],
            connect_timeout: Duration::from_millis(50),
            artifact_dir: "artifacts".into(),
            launch_program: None,
            store_root: None,
            advertise_host: None,
            speculation: None,
            checkpoint: None,
        };
        let health = probe(&spec);
        assert_eq!(health.len(), 1);
        assert!(!health[0].ok());
        assert_eq!(health[0].addr, "127.0.0.1:1");
        assert!(health[0].error.as_ref().unwrap().contains("127.0.0.1:1"));
    }
}
