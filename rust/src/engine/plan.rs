//! Serializable execution plans.
//!
//! A task must cross a process boundary in standalone mode (driver →
//! TCP → worker), so the unit of work is fully described by data: a
//! per-partition [`Source`], a chain of named [`OpCall`]s (the platform's
//! substitute for Spark closure serialization — operators are registered
//! by name in the [`super::ops::OpRegistry`] on both sides), and a
//! terminal [`Action`].
//!
//! Records are raw byte vectors (`RDD[Bytes]`, exactly the paper's §3.1
//! model); typed views are layered on top by the ops themselves.

use super::data::DataRef;
use crate::error::{Error, Result};
use crate::msg::Time;
use crate::util::bytes::{ByteReader, ByteWriter};

/// One data record flowing through the engine.
pub type Record = Vec<u8>;

/// Where a partition's records come from.
#[derive(Debug, Clone, PartialEq)]
pub enum Source {
    /// Records shipped inline with the task (parallelize / shuffled data).
    Inline { records: Vec<Record> },
    /// One bag; records are encoded [`PlayedRecord`]s, optionally
    /// filtered to `topics` (empty = all). `data` names the bytes — a
    /// worker-local path or a content-addressed manifest fetched
    /// through the data plane (see [`DataRef`]).
    BagFile {
        /// Where the bag bytes come from.
        data: DataRef,
        /// Topic filter (empty = all topics).
        topics: Vec<String>,
    },
    /// Synthetic camera frames generated on the worker (scalability
    /// workloads without disk); records are encoded `msg::Image`s.
    SynthFrames { seed: u64, count: u32, width: u32, height: u32 },
    /// Integer range [start, end); records are 8-byte LE u64.
    Range { start: u64, end: u64 },
    /// One shard of a scenario sweep: records are encoded
    /// [`crate::sim::Scenario`]s (see `sim::sweep`). Validated on load so
    /// a poisoned shard fails fast on the worker instead of deep inside
    /// an episode.
    Scenarios { scenarios: Vec<Record> },
    /// One shard of a distributed bag replay (see `sim::replay`): time
    /// slices of the bag named by `data`, filtered to `topics` (empty =
    /// all). `slices` are encoded [`crate::sim::replay::ReplaySlice`]s;
    /// loading emits one self-contained slice-job record per slice
    /// (data ref + topics + slice), validated up front so a poisoned
    /// slice fails fast on the worker.
    BagSlices {
        /// Bag the slices replay (resolved through the worker's data
        /// plane — local path or manifest fetch).
        data: DataRef,
        /// Topic filter shared by every slice (empty = all topics).
        topics: Vec<String>,
        /// Encoded [`crate::sim::replay::ReplaySlice`] records.
        slices: Vec<Record>,
    },
}

impl Source {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Source::Inline { records } => {
                w.put_u8(0);
                w.put_varint(records.len() as u64);
                for r in records {
                    w.put_bytes(r);
                }
            }
            Source::BagFile { data, topics } => {
                w.put_u8(1);
                data.encode_into(w);
                w.put_varint(topics.len() as u64);
                for t in topics {
                    w.put_str(t);
                }
            }
            Source::SynthFrames { seed, count, width, height } => {
                w.put_u8(2);
                w.put_u64(*seed);
                w.put_u32(*count);
                w.put_u32(*width);
                w.put_u32(*height);
            }
            Source::Range { start, end } => {
                w.put_u8(3);
                w.put_u64(*start);
                w.put_u64(*end);
            }
            Source::Scenarios { scenarios } => {
                w.put_u8(4);
                w.put_varint(scenarios.len() as u64);
                for s in scenarios {
                    w.put_bytes(s);
                }
            }
            Source::BagSlices { data, topics, slices } => {
                w.put_u8(5);
                data.encode_into(w);
                w.put_varint(topics.len() as u64);
                for t in topics {
                    w.put_str(t);
                }
                w.put_varint(slices.len() as u64);
                for s in slices {
                    w.put_bytes(s);
                }
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        match r.get_u8()? {
            0 => {
                let n = r.get_varint()? as usize;
                let mut records = Vec::with_capacity(n);
                for _ in 0..n {
                    records.push(r.get_bytes_vec()?);
                }
                Ok(Source::Inline { records })
            }
            1 => {
                let data = DataRef::decode(r)?;
                let n = r.get_varint()? as usize;
                // capacity capped like the BagSlices arm: a corrupt
                // frame's varint must not drive a huge pre-allocation
                let mut topics = Vec::with_capacity(n.min(1 << 10));
                for _ in 0..n {
                    topics.push(r.get_str()?);
                }
                Ok(Source::BagFile { data, topics })
            }
            2 => Ok(Source::SynthFrames {
                seed: r.get_u64()?,
                count: r.get_u32()?,
                width: r.get_u32()?,
                height: r.get_u32()?,
            }),
            3 => Ok(Source::Range { start: r.get_u64()?, end: r.get_u64()? }),
            4 => {
                let n = r.get_varint()? as usize;
                let mut scenarios = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    scenarios.push(r.get_bytes_vec()?);
                }
                Ok(Source::Scenarios { scenarios })
            }
            5 => {
                let data = DataRef::decode(r)?;
                let n = r.get_varint()? as usize;
                let mut topics = Vec::with_capacity(n.min(1 << 10));
                for _ in 0..n {
                    topics.push(r.get_str()?);
                }
                let n = r.get_varint()? as usize;
                let mut slices = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    slices.push(r.get_bytes_vec()?);
                }
                Ok(Source::BagSlices { data, topics, slices })
            }
            other => Err(Error::Engine(format!("unknown source tag {other}"))),
        }
    }

    /// Rough description for logs / UI.
    pub fn describe(&self) -> String {
        match self {
            Source::Inline { records } => format!("inline[{}]", records.len()),
            Source::BagFile { data, .. } => format!("bag:{}", data.describe()),
            Source::SynthFrames { count, width, height, .. } => {
                format!("synth[{count} x {width}x{height}]")
            }
            Source::Range { start, end } => format!("range[{start}..{end})"),
            Source::Scenarios { scenarios } => format!("scenarios[{}]", scenarios.len()),
            Source::BagSlices { data, slices, .. } => {
                format!("bag-slices:{}[{}]", data.describe(), slices.len())
            }
        }
    }
}

/// A named operator application.
#[derive(Debug, Clone, PartialEq)]
pub struct OpCall {
    /// Operator name (must be registered on the executing worker).
    pub name: String,
    /// Opaque operator parameters (ops define their own encoding).
    pub params: Vec<u8>,
}

impl OpCall {
    /// Build an operator call.
    pub fn new(name: impl Into<String>, params: Vec<u8>) -> Self {
        Self { name: name.into(), params }
    }

    fn encode(&self, w: &mut ByteWriter) {
        w.put_str(&self.name);
        w.put_bytes(&self.params);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self { name: r.get_str()?, params: r.get_bytes_vec()? })
    }
}

/// Terminal operation of a task.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Return the partition's records to the driver.
    Collect,
    /// Return only the record count.
    Count,
    /// Write records into a bag file under `dir` (the "persist to HDFS"
    /// path); returns the written path as a single record.
    SaveBag { dir: String, topic: String, type_name: String },
    /// Terminal for scenario sweeps: validates that every record is a
    /// decodable `EpisodeResult` (i.e. the op chain actually ran the
    /// episodes) and returns them as [`TaskOutput::Episodes`], preserving
    /// record order.
    Episodes,
    /// Terminal for bag replays: validates that every record is a
    /// decodable `ReplayVerdict` (i.e. the op chain actually replayed
    /// the slices) and returns them as [`TaskOutput::Replays`],
    /// preserving record order.
    Replays,
}

impl Action {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Action::Collect => w.put_u8(0),
            Action::Count => w.put_u8(1),
            Action::SaveBag { dir, topic, type_name } => {
                w.put_u8(2);
                w.put_str(dir);
                w.put_str(topic);
                w.put_str(type_name);
            }
            Action::Episodes => w.put_u8(3),
            Action::Replays => w.put_u8(4),
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        match r.get_u8()? {
            0 => Ok(Action::Collect),
            1 => Ok(Action::Count),
            2 => Ok(Action::SaveBag {
                dir: r.get_str()?,
                topic: r.get_str()?,
                type_name: r.get_str()?,
            }),
            3 => Ok(Action::Episodes),
            4 => Ok(Action::Replays),
            other => Err(Error::Engine(format!("unknown action tag {other}"))),
        }
    }
}

/// A fully-described unit of work.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Job this task belongs to (for logs and metrics).
    pub job_id: u64,
    /// Index of this task within the job.
    pub task_id: u32,
    /// Retry attempt number (0 = first run).
    pub attempt: u32,
    /// Where the task's input records come from.
    pub source: Source,
    /// Operator chain applied to the records, in order.
    pub ops: Vec<OpCall>,
    /// How the op-chain output is reduced into a [`TaskOutput`].
    pub action: Action,
}

impl TaskSpec {
    /// Serialize for the RPC wire / replay logs.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.job_id);
        w.put_u32(self.task_id);
        w.put_u32(self.attempt);
        self.source.encode(&mut w);
        w.put_varint(self.ops.len() as u64);
        for op in &self.ops {
            op.encode(&mut w);
        }
        self.action.encode(&mut w);
        w.into_vec()
    }

    /// Decode a [`TaskSpec::encode`] payload.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        let job_id = r.get_u64()?;
        let task_id = r.get_u32()?;
        let attempt = r.get_u32()?;
        let source = Source::decode(&mut r)?;
        let n = r.get_varint()? as usize;
        let mut ops = Vec::with_capacity(n);
        for _ in 0..n {
            ops.push(OpCall::decode(&mut r)?);
        }
        let action = Action::decode(&mut r)?;
        Ok(Self { job_id, task_id, attempt, source, ops, action })
    }
}

/// What a finished task hands back.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskOutput {
    /// Collected records (produced by [`Action::Collect`]).
    Records(Vec<Record>),
    /// Record count (produced by [`Action::Count`]).
    Count(u64),
    /// Encoded `EpisodeResult`s, in the shard's scenario order (produced
    /// by [`Action::Episodes`]).
    Episodes(Vec<Record>),
    /// Encoded `ReplayVerdict`s, in the shard's slice order (produced by
    /// [`Action::Replays`]).
    Replays(Vec<Record>),
}

impl TaskOutput {
    /// Serialize for the RPC wire.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            TaskOutput::Records(rs) => {
                w.put_u8(0);
                w.put_varint(rs.len() as u64);
                for r in rs {
                    w.put_bytes(r);
                }
            }
            TaskOutput::Count(n) => {
                w.put_u8(1);
                w.put_u64(*n);
            }
            TaskOutput::Episodes(rs) => {
                w.put_u8(2);
                w.put_varint(rs.len() as u64);
                for r in rs {
                    w.put_bytes(r);
                }
            }
            TaskOutput::Replays(rs) => {
                w.put_u8(3);
                w.put_varint(rs.len() as u64);
                for r in rs {
                    w.put_bytes(r);
                }
            }
        }
        w.into_vec()
    }

    /// Decode a [`TaskOutput::encode`] payload.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        match r.get_u8()? {
            0 => {
                let n = r.get_varint()? as usize;
                let mut rs = Vec::with_capacity(n);
                for _ in 0..n {
                    rs.push(r.get_bytes_vec()?);
                }
                Ok(TaskOutput::Records(rs))
            }
            1 => Ok(TaskOutput::Count(r.get_u64()?)),
            2 => {
                let n = r.get_varint()? as usize;
                let mut rs = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    rs.push(r.get_bytes_vec()?);
                }
                Ok(TaskOutput::Episodes(rs))
            }
            3 => {
                let n = r.get_varint()? as usize;
                let mut rs = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    rs.push(r.get_bytes_vec()?);
                }
                Ok(TaskOutput::Replays(rs))
            }
            other => Err(Error::Engine(format!("unknown output tag {other}"))),
        }
    }
}

/// A bag message flattened into an engine record (topic + type + time +
/// payload). This is how bag contents flow through RDDs.
#[derive(Debug, Clone, PartialEq)]
pub struct PlayedRecord {
    /// Topic the message was played from.
    pub topic: String,
    /// Message type name (e.g. `sim/Tick`).
    pub type_name: String,
    /// Bag timestamp.
    pub time: Time,
    /// Raw message payload.
    pub data: Vec<u8>,
}

impl PlayedRecord {
    /// Serialize into an engine record.
    pub fn encode(&self) -> Record {
        let mut w = ByteWriter::with_capacity(self.data.len() + 32);
        w.put_str(&self.topic);
        w.put_str(&self.type_name);
        w.put_u64(self.time.nanos);
        w.put_bytes(&self.data);
        w.into_vec()
    }

    /// Decode a [`PlayedRecord::encode`] record.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        Ok(Self {
            topic: r.get_str()?,
            type_name: r.get_str()?,
            time: Time::from_nanos(r.get_u64()?),
            data: r.get_bytes_vec()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TaskSpec {
        TaskSpec {
            job_id: 9,
            task_id: 3,
            attempt: 1,
            source: Source::BagFile {
                data: DataRef::path("/data/x.bag"),
                topics: vec!["/camera".into()],
            },
            ops: vec![
                OpCall::new("take_payload", vec![]),
                OpCall::new("binpipe", b"rotate90".to_vec()),
            ],
            action: Action::Collect,
        }
    }

    #[test]
    fn task_spec_roundtrip() {
        let s = spec();
        assert_eq!(TaskSpec::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn all_sources_roundtrip() {
        for source in [
            Source::Inline { records: vec![vec![1], vec![2, 3]] },
            Source::BagFile { data: DataRef::path("p"), topics: vec![] },
            Source::BagFile {
                data: DataRef::Manifest {
                    id: crate::storage::ManifestId([0xA5; 32]),
                    peer: "10.0.0.9:7199".into(),
                },
                topics: vec!["/camera".into()],
            },
            Source::SynthFrames { seed: 7, count: 10, width: 64, height: 48 },
            Source::Range { start: 5, end: 50 },
            Source::Scenarios { scenarios: vec![vec![0, 1, 2], vec![]] },
            Source::BagSlices {
                data: DataRef::path("/data/drive.bag"),
                topics: vec!["/camera".into(), "/lidar".into()],
                slices: vec![vec![1, 2, 3], vec![4]],
            },
            Source::BagSlices {
                data: DataRef::Manifest {
                    id: crate::storage::ManifestId([3; 32]),
                    peer: "127.0.0.1:9000".into(),
                },
                topics: vec![],
                slices: vec![vec![9; 28]],
            },
        ] {
            let s = TaskSpec { source: source.clone(), ..spec() };
            assert_eq!(TaskSpec::decode(&s.encode()).unwrap().source, source);
        }
    }

    #[test]
    fn invalid_data_ref_rejected_at_decode() {
        // a BagFile source whose data ref names a peer without a port
        // must fail the plan-time validation inside decode
        let s = TaskSpec {
            source: Source::BagFile {
                data: DataRef::Manifest {
                    id: crate::storage::ManifestId([1; 32]),
                    peer: "noport".into(),
                },
                topics: vec![],
            },
            ..spec()
        };
        assert!(TaskSpec::decode(&s.encode()).is_err());
    }

    #[test]
    fn all_actions_roundtrip() {
        for action in [
            Action::Collect,
            Action::Count,
            Action::SaveBag {
                dir: "/out".into(),
                topic: "/t".into(),
                type_name: "T".into(),
            },
            Action::Episodes,
            Action::Replays,
        ] {
            let s = TaskSpec { action: action.clone(), ..spec() };
            assert_eq!(TaskSpec::decode(&s.encode()).unwrap().action, action);
        }
    }

    #[test]
    fn output_roundtrip() {
        for out in [
            TaskOutput::Records(vec![vec![1, 2], vec![], vec![9; 100]]),
            TaskOutput::Count(12345),
            TaskOutput::Episodes(vec![vec![3; 40], vec![7; 40]]),
            TaskOutput::Replays(vec![vec![5; 16], vec![]]),
        ] {
            assert_eq!(TaskOutput::decode(&out.encode()).unwrap(), out);
        }
    }

    #[test]
    fn played_record_roundtrip() {
        let p = PlayedRecord {
            topic: "/camera".into(),
            type_name: "av/sensor/Image".into(),
            time: Time::from_nanos(42),
            data: vec![1, 2, 3],
        };
        assert_eq!(PlayedRecord::decode(&p.encode()).unwrap(), p);
    }

    #[test]
    fn corrupt_spec_rejected() {
        let mut buf = spec().encode();
        buf.truncate(buf.len() / 2);
        assert!(TaskSpec::decode(&buf).is_err());
    }
}
