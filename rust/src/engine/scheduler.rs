//! Job scheduler: streams tasks to a [`Cluster`], retries failed
//! attempts immediately (no round barrier), and records job metrics.
//!
//! The core is [`run_provider`]: it opens a [`TaskStream`], pulls tasks
//! lazily from a [`TaskProvider`], and reacts to completions as they
//! arrive — a retryable failure re-enters the queue the moment it is
//! observed, so a retry overlaps the still-running stragglers instead of
//! waiting for the whole batch. The provider decides *what* runs (it may
//! cut work lazily at a cursor, as the adaptive sweep does) and folds
//! each successful output back into driver state; the scheduler owns the
//! completion/retry/metrics loop once, for every driver.
//!
//! `run_job` is the fixed-task-list convenience on top (outputs returned
//! in task order; each completion carries the sequence slot it fills).
//!
//! `run_job_rounds` is the old barrier-synchronous model (one full
//! `run_tasks` batch per retry wave), kept as the comparison baseline
//! for the scheduler benches (`examples/bench_engine.rs`) and as the
//! reference semantics the streaming path must reproduce.

use super::checkpoint::Checkpointer;
use super::cluster::Cluster;
use super::fault::{FaultPlan, FAULT_TAG};
use super::plan::{TaskOutput, TaskSpec};
use super::stream::{CompletionWait, TaskStream};
use super::trace::{self, StageStat, TraceCtx};
use crate::error::{Error, Result};
use crate::util::mono_nanos;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// How often the speculative scheduler wakes to scan for stragglers
/// while no completions are arriving.
const SPECULATION_POLL: Duration = Duration::from_millis(20);

/// Speculative-execution policy for straggler tasks (Spark's
/// `spark.speculation`): once at least `min_samples` attempts have
/// completed, a running attempt whose wall exceeds `multiplier` × the
/// p95 completed-attempt wall gets a duplicate submitted — provided
/// idle worker capacity exists — and whichever completion lands first
/// resolves the sequence slot (the loser is discarded, so results stay
/// byte-identical to a non-speculative run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Speculation {
    /// Master switch; `false` means the scheduler never duplicates work.
    pub enabled: bool,
    /// Straggler threshold as a multiple of the running p95 task wall.
    pub multiplier: f64,
    /// Completed-attempt samples required before any speculation.
    pub min_samples: usize,
}

impl Default for Speculation {
    fn default() -> Self {
        Self { enabled: false, multiplier: 1.5, min_samples: 4 }
    }
}

impl Speculation {
    /// Speculation enabled with the default tuning (1.5× p95, 4 samples).
    pub fn on() -> Self {
        Self { enabled: true, ..Self::default() }
    }
}

/// Bounded exponential delay before resubmitting an attempt that died
/// of transport loss ([`Error::is_transport_death`]). Without it a
/// retryable attempt re-enters the queue immediately and can hot-loop
/// against a fleet that is momentarily all-dead (e.g. workers
/// restarting); with it, attempt `k` sleeps `base × 2^(k-1)`, capped.
/// Non-transport retryable failures (task-level engine errors) still
/// re-enter immediately — backoff is for dead wires, not flaky ops.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryBackoff {
    /// Delay before the first transport-death retry.
    pub base: Duration,
    /// Ceiling the exponential never exceeds.
    pub cap: Duration,
}

impl Default for RetryBackoff {
    fn default() -> Self {
        Self { base: Duration::from_millis(10), cap: Duration::from_millis(500) }
    }
}

impl RetryBackoff {
    /// Delay for retry attempt `attempt` (1-based: the first retry is
    /// attempt 1 and sleeps `base`).
    pub fn delay(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(16);
        self.base.saturating_mul(1u32 << shift).min(self.cap)
    }
}

/// Optional hooks threaded through [`run_provider_hooked`]: durable
/// checkpointing, deterministic fault injection, and transport-death
/// retry backoff. `RunHooks::default()` is a no-op configuration
/// (no checkpoint, no faults, default backoff).
#[derive(Default)]
pub struct RunHooks<'a> {
    /// Fold each resolved output into a durable checkpoint before the
    /// provider consumes it (keyed by [`TaskProvider::checkpoint_slot`]).
    pub checkpoint: Option<&'a mut Checkpointer>,
    /// Injected-failure schedule (drives the driver-abort fault; worker
    /// and transport faults live in the cluster backends).
    pub faults: Option<FaultPlan>,
    /// Backoff policy for transport-death retries.
    pub backoff: RetryBackoff,
}

/// Per-job execution report.
#[derive(Debug, Clone)]
pub struct JobReport {
    /// Job id (from the first task's `job_id`).
    pub job_id: u64,
    /// Number of tasks in the job.
    pub tasks: usize,
    /// Retry attempts consumed across all tasks.
    pub retries: usize,
    /// End-to-end job wall time.
    pub wall: std::time::Duration,
    /// Per-attempt execution wall time (includes RPC transport for
    /// remote workers). Zero for `run_job_rounds` (the batch API does
    /// not observe per-task timing).
    pub task_wall_p50: Duration,
    /// 95th-percentile per-attempt execution wall time.
    pub task_wall_p95: Duration,
    /// Time attempts spent queued before a worker picked them up.
    pub queue_wait_p50: Duration,
    /// 95th-percentile queue wait.
    pub queue_wait_p95: Duration,
    /// Speculative duplicate attempts launched for straggler tasks
    /// (zero unless [`Speculation::enabled`]).
    pub speculations: usize,
    /// Per-stage time totals from the installed trace sink (empty when
    /// no [`super::trace::TraceLog`] is installed). Execution facts
    /// only — never serialized into result payloads, so report bytes
    /// stay identical with tracing on or off.
    pub stages: Vec<StageStat>,
}

impl JobReport {
    fn new(job_id: u64, tasks: usize, retries: usize, wall: Duration) -> Self {
        Self {
            job_id,
            tasks,
            retries,
            wall,
            task_wall_p50: Duration::ZERO,
            task_wall_p95: Duration::ZERO,
            queue_wait_p50: Duration::ZERO,
            queue_wait_p95: Duration::ZERO,
            speculations: 0,
            stages: Vec::new(),
        }
    }
}

/// The trace context a task spec carries (stamped on every driver event
/// and on the traced dispatch frame).
fn ctx_of(t: &TaskSpec) -> TraceCtx {
    TraceCtx { job_id: t.job_id, task_id: t.task_id, attempt: t.attempt }
}

/// Nearest-rank percentile over an unsorted set of durations.
fn percentile(samples: &mut [Duration], q: f64) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    samples.sort_unstable();
    let idx = ((samples.len() as f64 * q) as usize).min(samples.len() - 1);
    samples[idx]
}

/// A lazy task source driving [`run_provider`].
///
/// The scheduler pulls tasks on demand (so a provider may cut work at a
/// cursor using information that only exists once earlier tasks have
/// finished — the adaptive sweep re-shards its unsubmitted tail this
/// way) and hands every successful output straight back, so the provider
/// places results without the scheduler buffering them.
///
/// Sequence slots are assigned by the scheduler: the `seq` passed to
/// [`TaskProvider::next_task`] is the slot the eventual completion (or
/// any retry of it) reports under in [`TaskProvider::on_output`].
pub trait TaskProvider {
    /// Produce the task for sequence slot `seq` (monotonic from 0), or
    /// `None` when the provider is exhausted. Not called again after
    /// returning `None`, nor after a task has permanently failed.
    fn next_task(&mut self, seq: u64) -> Option<TaskSpec>;

    /// Fold a successful completion back into driver state. `wall` is
    /// the attempt's execution time (providers that calibrate against
    /// measured wall use it). An `Err` aborts the job after in-flight
    /// tasks drain.
    fn on_output(&mut self, seq: u64, output: TaskOutput, wall: Duration) -> Result<()>;

    /// Max unfinished attempts in flight; the scheduler stops pulling
    /// new tasks while at the window. Bounding it keeps a tail of work
    /// unsubmitted (and therefore still re-plannable). Default:
    /// effectively unbounded.
    fn window(&self) -> usize {
        usize::MAX
    }

    /// Plan-stable checkpoint slot for sequence slot `seq`. Sequence
    /// numbers restart from 0 when a job resumes with fewer tasks, so a
    /// resumable provider maps `seq` to an identifier derived from the
    /// plan itself (slice index, case offset). Default: identity, which
    /// is correct for fresh non-resumable runs.
    fn checkpoint_slot(&self, seq: u64) -> u64 {
        seq
    }
}

/// Submission frontier for round-barrier providers.
///
/// A provider whose plan advances in rounds of `round_size` tasks (the
/// next round's tasks depend on *every* output of the current round,
/// e.g. a coverage-guided fuzzer re-aiming its mutator) cannot stall
/// the scheduler by returning `None` from
/// [`TaskProvider::next_task`] — `None` means exhausted forever.
/// Instead it bounds [`TaskProvider::window`] dynamically: given
/// `resolved` total resolved outputs, this returns the first task slot
/// (exclusive) that may be submitted without crossing into the round
/// after the one currently in flight. The scheduler re-reads the window
/// at the top of every dispatch iteration, so the frontier advances the
/// moment a round fully resolves, preserving full intra-round
/// parallelism with a barrier only at round boundaries.
pub fn round_window(resolved: u64, round_size: u64) -> u64 {
    let t = round_size.max(1);
    (resolved / t + 1).saturating_mul(t)
}

/// Run a provider-driven job to completion with bounded retries,
/// streaming. This is the one completion/retry/metrics loop every
/// driver (fixed jobs, adaptive sweeps, bag replays) goes through.
/// Speculation is off; see [`run_provider_with`] for the policy knob.
pub fn run_provider(
    cluster: &dyn Cluster,
    provider: &mut dyn TaskProvider,
    max_retries: usize,
) -> Result<JobReport> {
    run_provider_with(cluster, provider, max_retries, Speculation::default())
}

/// Live-attempt bookkeeping for one unresolved sequence slot (only kept
/// while speculation is enabled).
struct Running {
    spec: TaskSpec,
    started: Instant,
    /// Attempts currently in flight for this slot (1, or 2 with a twin).
    attempts: usize,
    /// A duplicate was already launched; never speculate a slot twice
    /// per attempt.
    speculated: bool,
}

/// Scan running attempts for stragglers and submit duplicates while
/// idle worker capacity exists. Returns the number launched.
fn speculate_stragglers(
    cluster: &dyn Cluster,
    stream: &TaskStream,
    running: &mut HashMap<u64, Running>,
    walls: &[Duration],
    policy: Speculation,
) -> usize {
    if walls.len() < policy.min_samples.max(1) {
        return 0;
    }
    let mut sorted = walls.to_vec();
    let p95 = percentile(&mut sorted, 0.95);
    // 1 ms floor so near-zero p95s (instant tasks) cannot make every
    // task look like a straggler the moment it is popped
    let threshold =
        Duration::from_secs_f64((p95.as_secs_f64() * policy.multiplier).max(0.001));
    let mut launched = 0usize;
    for (seq, r) in running.iter_mut() {
        if stream.pending() > 0 || stream.in_flight() >= cluster.workers() {
            break; // no idle capacity — never queue duplicates behind real work
        }
        if r.speculated || r.attempts != 1 || r.started.elapsed() <= threshold {
            continue;
        }
        if let Some(log) = trace::active() {
            log.driver_event("speculate", ctx_of(&r.spec), mono_nanos(), 0);
        }
        stream.submit(*seq, r.spec.clone());
        r.attempts = 2;
        r.speculated = true;
        launched += 1;
    }
    launched
}

/// [`run_provider`] with an explicit [`Speculation`] policy. With
/// speculation on, the scheduler polls completions on a short timeout,
/// duplicates straggler attempts onto idle workers, resolves each
/// sequence slot with whichever completion lands first (the loser is
/// discarded wholesale — it touches neither provider state nor the
/// timing samples), and returns without waiting out losing attempts.
pub fn run_provider_with(
    cluster: &dyn Cluster,
    provider: &mut dyn TaskProvider,
    max_retries: usize,
    speculation: Speculation,
) -> Result<JobReport> {
    run_provider_hooked(cluster, provider, max_retries, speculation, RunHooks::default())
}

/// [`run_provider_with`] plus [`RunHooks`]: durable checkpointing of
/// resolved outputs, deterministic fault injection (driver abort), and
/// transport-death retry backoff. Each resolved output is folded into
/// the checkpoint *before* the provider consumes it, so a checkpoint
/// entry implies the output was durably observed; the final record is
/// flushed on every exit path (success or abort) so a killed driver
/// resumes from the last resolved prefix.
pub fn run_provider_hooked(
    cluster: &dyn Cluster,
    provider: &mut dyn TaskProvider,
    max_retries: usize,
    speculation: Speculation,
    mut hooks: RunHooks<'_>,
) -> Result<JobReport> {
    let start = Instant::now();
    let mut completed = 0u64;
    let mut walls: Vec<Duration> = Vec::new();
    let mut waits: Vec<Duration> = Vec::new();
    let mut job_id = 0u64;
    let mut submitted = 0u64;
    let mut outstanding = 0usize;
    let mut exhausted = false;
    let mut retries_used = 0usize;
    let mut speculations = 0usize;
    let mut first_err: Option<Error> = None;
    // live sequence slots → attempt bookkeeping (speculation only)
    let mut running: HashMap<u64, Running> = HashMap::new();

    let m = crate::metrics::Metrics::global();
    let wall_hist = m.histogram("engine_task_wall");
    let wait_hist = m.histogram("engine_task_queue_wait");

    let stream = cluster.open_stream();
    // closes the stream on every exit path (incl. panics), so workers
    // never stay parked on an abandoned job
    let _close = stream.clone().close_on_drop();

    loop {
        // Pull up to the provider's window. New work stops after the
        // first permanent failure — in-flight tasks just drain.
        let window = provider.window().max(1);
        while first_err.is_none() && !exhausted && outstanding < window {
            match provider.next_task(submitted) {
                Some(t) => {
                    if submitted == 0 {
                        job_id = t.job_id;
                    }
                    if speculation.enabled {
                        running.insert(
                            submitted,
                            Running {
                                spec: t.clone(),
                                started: Instant::now(),
                                attempts: 1,
                                speculated: false,
                            },
                        );
                    }
                    if let Some(log) = trace::active() {
                        log.driver_event("submit", ctx_of(&t), mono_nanos(), 0);
                    }
                    stream.submit(submitted, t);
                    submitted += 1;
                    outstanding += 1;
                }
                None => exhausted = true,
            }
        }
        if outstanding == 0 {
            break;
        }
        let c = if speculation.enabled {
            match stream.next_completion_timeout(SPECULATION_POLL) {
                CompletionWait::Completion(c) => Some(c),
                CompletionWait::Drained => None,
                CompletionWait::TimedOut => {
                    speculations +=
                        speculate_stragglers(cluster, &stream, &mut running, &walls, speculation);
                    continue;
                }
            }
        } else {
            stream.next_completion()
        };
        let Some(c) = c else {
            return Err(first_err.unwrap_or_else(|| {
                Error::Engine(format!(
                    "job {job_id}: task stream ended with {outstanding} task(s) unresolved"
                ))
            }));
        };
        if speculation.enabled && !running.contains_key(&c.seq) {
            // the losing twin of an already-resolved slot: discard it
            // wholesale (its wall would double-count in the metrics and
            // skew the straggler threshold)
            continue;
        }
        outstanding -= 1; // tentatively resolved; retry/absorb re-raises
        walls.push(c.wall);
        waits.push(c.queue_wait);
        wall_hist.observe(c.wall);
        wait_hist.observe(c.queue_wait);
        if let Some(log) = trace::active() {
            // Reconstruct the attempt timeline backward from observation:
            // the attempt finished "now", ran for `wall`, and queued for
            // `queue_wait` before that.
            let now = mono_nanos();
            let wall_ns = c.wall.as_nanos() as u64;
            let wait_ns = c.queue_wait.as_nanos() as u64;
            let ctx = ctx_of(&c.spec);
            let run_start = now.saturating_sub(wall_ns);
            log.driver_event("queue_wait", ctx, run_start.saturating_sub(wait_ns), wait_ns);
            log.driver_event("task_wall", ctx, run_start, wall_ns);
        }
        match c.result {
            Ok(out) => {
                running.remove(&c.seq);
                if first_err.is_none() {
                    // checkpoint first: an entry must never exist for an
                    // output the provider has not (or will not) see in a
                    // resumed run's pre-fill
                    if let Some(ck) = hooks.checkpoint.as_deref_mut() {
                        if let Err(e) = ck.observe(provider.checkpoint_slot(c.seq), &out) {
                            first_err = Some(e);
                        }
                    }
                    if first_err.is_none() {
                        if let Err(e) = provider.on_output(c.seq, out, c.wall) {
                            first_err = Some(e);
                        }
                    }
                    if first_err.is_none() {
                        completed += 1;
                        let abort = hooks
                            .faults
                            .as_ref()
                            .is_some_and(|f| f.driver_abort_due(completed));
                        if abort {
                            first_err = Some(Error::Engine(format!(
                                "{FAULT_TAG}: driver aborted after {completed} completion(s)"
                            )));
                        }
                    }
                }
            }
            Err(e) => {
                let live = running.get(&c.seq).map(|r| r.attempts).unwrap_or(1);
                if speculation.enabled && live > 1 {
                    // this slot's twin is still executing and may yet
                    // succeed — absorb the failure instead of retrying
                    crate::logmsg!(
                        "warn",
                        "job {job_id} task {} attempt failed with twin in flight \
                         (absorbed): {e}",
                        c.spec.task_id
                    );
                    if let Some(r) = running.get_mut(&c.seq) {
                        r.attempts -= 1;
                    }
                    outstanding += 1;
                    continue;
                }
                crate::logmsg!(
                    "warn",
                    "job {job_id} task {} attempt {} failed: {e}",
                    c.spec.task_id,
                    c.spec.attempt
                );
                if first_err.is_none()
                    && (c.spec.attempt as usize) < max_retries
                    && e.is_retryable()
                {
                    // immediate re-entry: the retry runs on the next free
                    // worker while stragglers are still in flight —
                    // except transport deaths, which back off briefly so
                    // a momentarily all-dead fleet isn't hot-looped
                    let mut t = c.spec;
                    t.attempt += 1;
                    retries_used += 1;
                    if e.is_transport_death() {
                        std::thread::sleep(hooks.backoff.delay(t.attempt));
                    }
                    if speculation.enabled {
                        if let Some(r) = running.get_mut(&c.seq) {
                            r.spec = t.clone();
                            r.started = Instant::now();
                            r.speculated = false; // a fresh attempt may speculate anew
                        }
                    }
                    if let Some(log) = trace::active() {
                        log.driver_event("retry", ctx_of(&t), mono_nanos(), 0);
                    }
                    stream.submit(c.seq, t);
                    outstanding += 1;
                } else {
                    running.remove(&c.seq);
                    if first_err.is_none() {
                        first_err = Some(Error::Engine(format!(
                            "job {job_id} task {} failed after {} attempt(s): {e}",
                            c.spec.task_id,
                            c.spec.attempt + 1
                        )));
                    }
                }
            }
        }
    }
    if speculation.enabled {
        // don't wait out losing straggler attempts — that wait is the
        // tail latency speculation exists to cut
        stream.abandon();
    } else {
        stream.close();
    }

    // Final checkpoint flush on every exit path: a permanent failure
    // (including the injected driver abort) must still leave the record
    // current so a restarted driver resumes from the resolved prefix.
    if let Some(ck) = hooks.checkpoint.as_deref_mut() {
        if let Err(e) = ck.flush() {
            if first_err.is_none() {
                first_err = Some(e);
            } else {
                crate::logmsg!("warn", "checkpoint flush failed during job abort: {e}");
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let mut report = JobReport::new(job_id, submitted as usize, retries_used, start.elapsed());
    report.speculations = speculations;
    if let Some(log) = trace::active() {
        report.stages = log.stage_totals(Some(job_id));
    }
    report.task_wall_p50 = percentile(&mut walls, 0.50);
    report.task_wall_p95 = percentile(&mut walls, 0.95);
    report.queue_wait_p50 = percentile(&mut waits, 0.50);
    report.queue_wait_p95 = percentile(&mut waits, 0.95);
    // process metrics (`Metrics::global().report()`)
    m.counter("engine_jobs_completed").inc();
    m.counter("engine_tasks_completed").add(submitted);
    m.counter("engine_task_retries").add(retries_used as u64);
    m.counter("engine_task_speculations").add(speculations as u64);
    m.histogram("engine_job_wall").observe(report.wall);
    Ok(report)
}

/// Fixed task list as a provider: submit everything, collect outputs by
/// sequence slot.
struct VecProvider {
    tasks: std::vec::IntoIter<TaskSpec>,
    outputs: Vec<Option<TaskOutput>>,
}

impl TaskProvider for VecProvider {
    fn next_task(&mut self, _seq: u64) -> Option<TaskSpec> {
        self.tasks.next()
    }

    fn on_output(&mut self, seq: u64, output: TaskOutput, _wall: Duration) -> Result<()> {
        self.outputs[seq as usize] = Some(output);
        Ok(())
    }
}

/// Run a job: all tasks to completion with bounded retries, streaming.
/// Returns outputs in task order plus the report. A convenience wrapper
/// over [`run_provider`] with a fixed task list.
pub fn run_job(
    cluster: &dyn Cluster,
    tasks: Vec<TaskSpec>,
    max_retries: usize,
) -> Result<(Vec<TaskOutput>, JobReport)> {
    run_job_with(cluster, tasks, max_retries, Speculation::default())
}

/// [`run_job`] with an explicit [`Speculation`] policy (the fixed-list
/// convenience over [`run_provider_with`]).
pub fn run_job_with(
    cluster: &dyn Cluster,
    tasks: Vec<TaskSpec>,
    max_retries: usize,
    speculation: Speculation,
) -> Result<(Vec<TaskOutput>, JobReport)> {
    let total = tasks.len();
    let mut provider = VecProvider {
        tasks: tasks.into_iter(),
        outputs: (0..total).map(|_| None).collect(),
    };
    let report = run_provider_with(cluster, &mut provider, max_retries, speculation)?;
    let outputs: Vec<TaskOutput> = provider
        .outputs
        .into_iter()
        .map(|o| o.expect("all sequence slots filled or job errored"))
        .collect();
    Ok((outputs, report))
}

/// The pre-streaming scheduler: submit the whole batch, wait at the
/// round barrier, then run one extra full round per retry wave. Kept
/// verbatim so `bench_engine` can measure the streaming path against it
/// and tests can assert both produce identical outputs.
pub fn run_job_rounds(
    cluster: &dyn Cluster,
    mut tasks: Vec<TaskSpec>,
    max_retries: usize,
) -> Result<(Vec<TaskOutput>, JobReport)> {
    let job_id = tasks.first().map(|t| t.job_id).unwrap_or(0);
    let total = tasks.len();
    let start = Instant::now();
    let mut outputs: Vec<Option<TaskOutput>> = vec![None; total];
    // positions[i] = original index of tasks[i] in the job
    let mut positions: Vec<usize> = (0..total).collect();
    let mut retries_used = 0usize;

    loop {
        let results = cluster.run_tasks(&tasks);
        debug_assert_eq!(results.len(), tasks.len());
        let mut retry_tasks = Vec::new();
        let mut retry_positions = Vec::new();
        let mut first_err: Option<Error> = None;

        for ((task, pos), res) in tasks.into_iter().zip(positions.iter().copied()).zip(results) {
            match res {
                Ok(out) => outputs[pos] = Some(out),
                Err(e) => {
                    if (task.attempt as usize) < max_retries && e.is_retryable() {
                        let mut t = task;
                        t.attempt += 1;
                        retry_tasks.push(t);
                        retry_positions.push(pos);
                        retries_used += 1;
                    } else if first_err.is_none() {
                        first_err = Some(Error::Engine(format!(
                            "job {job_id} task {} failed after {} attempt(s): {e}",
                            task.task_id,
                            task.attempt + 1
                        )));
                    }
                }
            }
        }

        if let Some(e) = first_err {
            return Err(e);
        }
        if retry_tasks.is_empty() {
            break;
        }
        tasks = retry_tasks;
        positions = retry_positions;
    }

    let outputs: Vec<TaskOutput> = outputs
        .into_iter()
        .map(|o| o.expect("all positions filled or job errored"))
        .collect();
    Ok((outputs, JobReport::new(job_id, total, retries_used, start.elapsed())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::cluster::LocalCluster;
    use super::super::ops::OpRegistry;
    use super::super::plan::{Action, OpCall, Source};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    fn count_task(id: u32, n: u64, ops: Vec<OpCall>) -> TaskSpec {
        TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops,
            action: Action::Count,
        }
    }

    #[test]
    fn healthy_job_completes() {
        let c = LocalCluster::new(2, OpRegistry::with_builtins(), "artifacts");
        let tasks = (0..8).map(|i| count_task(i, 10, vec![])).collect();
        let (outs, report) = run_job(&c, tasks, 2).unwrap();
        assert_eq!(outs.len(), 8);
        assert_eq!(report.retries, 0);
        assert!(outs.iter().all(|o| *o == TaskOutput::Count(10)));
        // streaming path must observe per-attempt timing
        assert!(report.task_wall_p95 >= report.task_wall_p50);
        assert!(report.queue_wait_p95 >= report.queue_wait_p50);
    }

    #[test]
    fn transient_failures_are_retried() {
        let reg = OpRegistry::with_builtins();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        // Fails the first two invocations globally, then succeeds.
        reg.register("flaky", move |_c, _p, records| {
            if a.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(Error::Engine("transient".into()))
            } else {
                Ok(records)
            }
        });
        let c = LocalCluster::new(1, reg, "artifacts");
        let tasks = vec![
            count_task(0, 5, vec![OpCall::new("flaky", vec![])]),
            count_task(1, 5, vec![OpCall::new("flaky", vec![])]),
        ];
        let (outs, report) = run_job(&c, tasks, 3).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(report.retries >= 1 && report.retries <= 2, "retries {}", report.retries);
    }

    #[test]
    fn permanent_failure_fails_job_with_context() {
        let reg = OpRegistry::with_builtins();
        reg.register("broken", |_c, _p, _r| Err(Error::Engine("always".into())));
        let c = LocalCluster::new(2, reg, "artifacts");
        let tasks = vec![count_task(3, 5, vec![OpCall::new("broken", vec![])])];
        let err = run_job(&c, tasks, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("task 3"), "{msg}");
        assert!(msg.contains("2 attempt"), "{msg}");
    }

    #[test]
    fn non_retryable_errors_fail_fast() {
        let reg = OpRegistry::with_builtins();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        reg.register("corrupt", move |_c, _p, _r| {
            a.fetch_add(1, Ordering::SeqCst);
            Err(Error::Corrupt("bad bytes".into()))
        });
        let c = LocalCluster::new(1, reg, "artifacts");
        let tasks = vec![count_task(0, 5, vec![OpCall::new("corrupt", vec![])])];
        assert!(run_job(&c, tasks, 5).is_err());
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "corruption is not retried");
    }

    #[test]
    fn round_window_gates_rounds_without_losing_parallelism() {
        // nothing resolved: the whole first round may be in flight
        assert_eq!(round_window(0, 4), 4);
        // mid-round: frontier stays at the round boundary
        assert_eq!(round_window(1, 4), 4);
        assert_eq!(round_window(3, 4), 4);
        // round complete: the next round opens in full
        assert_eq!(round_window(4, 4), 8);
        assert_eq!(round_window(9, 4), 12);
        // degenerate round size is clamped, not a division by zero
        assert_eq!(round_window(5, 0), 6);
    }

    #[test]
    fn empty_job_is_ok() {
        let c = LocalCluster::new(1, OpRegistry::with_builtins(), "artifacts");
        let (outs, _) = run_job(&c, vec![], 1).unwrap();
        assert!(outs.is_empty());
    }

    #[test]
    fn streaming_and_rounds_agree_on_outputs() {
        let c = LocalCluster::new(3, OpRegistry::with_builtins(), "artifacts");
        let mk = || (0..12).map(|i| count_task(i, (i as u64 + 1) * 3, vec![])).collect();
        let (a, _) = run_job(&c, mk(), 2).unwrap();
        let (b, _) = run_job_rounds(&c, mk(), 2).unwrap();
        assert_eq!(a, b);
    }

    /// Millisecond stall op: params = varint millis (whole-task stall,
    /// independent of record count).
    fn stall_op(reg: &OpRegistry) {
        reg.register("stall_ms", |_c, params, records| {
            let mut r = crate::util::bytes::ByteReader::new(params);
            let ms = r.get_varint()?;
            std::thread::sleep(Duration::from_millis(ms));
            Ok(records)
        });
    }

    fn stall_params(ms: u64) -> Vec<u8> {
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_varint(ms);
        w.into_vec()
    }

    /// Op that fails the first attempt of each task, then stalls: params
    /// = varint millis. Shared `seen` set keys on task_id.
    fn fail_once_then_stall_op(reg: &OpRegistry, seen: Arc<Mutex<std::collections::HashSet<u32>>>) {
        reg.register("fail_once_then_stall", move |_c, params, records| {
            let mut r = crate::util::bytes::ByteReader::new(params);
            let task_id = r.get_varint()? as u32;
            let ms = r.get_varint()?;
            if seen.lock().unwrap().insert(task_id) {
                return Err(Error::Engine("transient first-attempt failure".into()));
            }
            std::thread::sleep(Duration::from_millis(ms));
            Ok(records)
        });
    }

    fn fail_once_params(task_id: u32, ms: u64) -> Vec<u8> {
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_varint(task_id as u64);
        w.put_varint(ms);
        w.into_vec()
    }

    /// Provider that counts `on_output` deliveries per sequence slot —
    /// the dedup witness for speculative twins.
    struct CountingProvider {
        tasks: std::vec::IntoIter<TaskSpec>,
        delivered: Vec<usize>,
    }

    impl TaskProvider for CountingProvider {
        fn next_task(&mut self, _seq: u64) -> Option<TaskSpec> {
            self.tasks.next()
        }

        fn on_output(&mut self, seq: u64, _output: TaskOutput, _wall: Duration) -> Result<()> {
            self.delivered[seq as usize] += 1;
            Ok(())
        }
    }

    /// A zero multiplier makes every running task a straggler the moment
    /// one sample exists; with an idle worker the scheduler must
    /// duplicate the straggler, and first-completion-wins must deliver
    /// every slot to the provider exactly once.
    #[test]
    fn speculative_duplicates_are_deduped_to_one_delivery_per_slot() {
        let reg = OpRegistry::with_builtins();
        stall_op(&reg);
        let c = LocalCluster::new(2, reg, "artifacts");
        // three quick tasks seed the wall samples; the fourth straggles
        // long enough for the 20ms speculation poll to notice it
        let mut tasks: Vec<TaskSpec> =
            (0..3).map(|i| count_task(i, 4, vec![OpCall::new("stall_ms", stall_params(5))])).collect();
        tasks.push(count_task(3, 4, vec![OpCall::new("stall_ms", stall_params(250))]));
        let total = tasks.len();
        let mut provider =
            CountingProvider { tasks: tasks.into_iter(), delivered: vec![0; total] };
        let policy = Speculation { enabled: true, multiplier: 0.0, min_samples: 1 };
        let report = run_provider_with(&c, &mut provider, 2, policy).unwrap();
        assert!(
            provider.delivered.iter().all(|&n| n == 1),
            "every slot delivered exactly once, got {:?}",
            provider.delivered
        );
        assert!(report.speculations >= 1, "straggler was never speculated");
        assert_eq!(report.tasks, 4);
        assert_eq!(report.retries, 0, "speculation is not a retry");
    }

    /// Speculation off must leave the classic scheduler untouched: same
    /// outputs, zero speculations reported.
    #[test]
    fn disabled_speculation_reports_zero_and_matches_plain_run() {
        let c = LocalCluster::new(2, OpRegistry::with_builtins(), "artifacts");
        let mk = || (0..6).map(|i| count_task(i, (i as u64 + 1) * 2, vec![])).collect();
        let (plain, _) = run_job(&c, mk(), 2).unwrap();
        let (with, report) = run_job_with(&c, mk(), 2, Speculation::default()).unwrap();
        assert_eq!(plain, with);
        assert_eq!(report.speculations, 0);
    }

    #[test]
    fn backoff_delays_grow_and_cap() {
        let b = RetryBackoff::default();
        assert_eq!(b.delay(1), Duration::from_millis(10));
        assert_eq!(b.delay(2), Duration::from_millis(20));
        assert_eq!(b.delay(3), Duration::from_millis(40));
        assert_eq!(b.delay(10), Duration::from_millis(500));
        assert_eq!(b.delay(u32::MAX), Duration::from_millis(500), "shift saturates");
    }

    /// Driver-abort fault: the run fails with the fault tag after
    /// exactly N resolved outputs, and the checkpoint holds exactly
    /// those N entries (later drained completions are not folded).
    #[test]
    fn hooked_run_checkpoints_then_injected_abort_stops_folding() {
        use super::super::checkpoint::{CheckpointConfig, Checkpointer};
        use super::super::fault::FaultPlan;

        let dir = std::env::temp_dir().join(format!(
            "av_simd_sched_ckpt_{}_{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let cfg = CheckpointConfig::new(dir.to_str().unwrap().to_string());
        let fp = [3u8; 32];
        let mut ck = Checkpointer::open(&cfg, 1, fp).unwrap();

        let c = LocalCluster::new(1, OpRegistry::with_builtins(), "artifacts");
        let tasks: Vec<TaskSpec> = (0..5).map(|i| count_task(i, 10, vec![])).collect();
        let total = tasks.len();
        let mut provider =
            CountingProvider { tasks: tasks.into_iter(), delivered: vec![0; total] };
        let hooks = RunHooks {
            checkpoint: Some(&mut ck),
            faults: Some(FaultPlan::none().abort_driver_after(2)),
            backoff: RetryBackoff::default(),
        };
        let err =
            run_provider_hooked(&c, &mut provider, 2, Speculation::default(), hooks).unwrap_err();
        assert!(err.to_string().contains(FAULT_TAG), "{err}");
        assert_eq!(ck.len(), 2, "exactly the pre-abort completions are durable");

        // The record survives a reopen and its payloads decode.
        let resume = CheckpointConfig { resume: true, ..cfg };
        let ck2 = Checkpointer::open(&resume, 1, fp).unwrap();
        assert_eq!(ck2.len(), 2);
        for payload in ck2.resolved().values() {
            assert_eq!(TaskOutput::decode(payload).unwrap(), TaskOutput::Count(10));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The retry-wave regression the streaming scheduler removes: a
    /// straggler plus a task whose retry is expensive. Round-based, the
    /// retry only starts after the straggler's round ends (~2 stalls
    /// serialized); streaming, the retry overlaps the straggler.
    #[test]
    fn retry_overlaps_straggler_instead_of_waiting_for_the_round() {
        const STALL: u64 = 120;
        let mk_tasks = || {
            vec![
                count_task(0, 4, vec![OpCall::new("stall_ms", stall_params(STALL))]),
                count_task(
                    1,
                    4,
                    vec![OpCall::new("fail_once_then_stall", fail_once_params(1, STALL))],
                ),
            ]
        };

        let reg = OpRegistry::with_builtins();
        stall_op(&reg);
        fail_once_then_stall_op(&reg, Arc::new(Mutex::new(std::collections::HashSet::new())));
        let c = LocalCluster::new(2, reg, "artifacts");
        let t0 = Instant::now();
        let (outs, report) = run_job(&c, mk_tasks(), 2).unwrap();
        let streaming_wall = t0.elapsed();
        assert_eq!(outs.len(), 2);
        assert_eq!(report.retries, 1);

        let reg = OpRegistry::with_builtins();
        stall_op(&reg);
        fail_once_then_stall_op(&reg, Arc::new(Mutex::new(std::collections::HashSet::new())));
        let c = LocalCluster::new(2, reg, "artifacts");
        let t0 = Instant::now();
        let (outs2, _) = run_job_rounds(&c, mk_tasks(), 2).unwrap();
        let rounds_wall = t0.elapsed();
        assert_eq!(outs, outs2);

        // rounds: straggler round (~STALL) then the retry round (~STALL)
        // ≈ 2×STALL; streaming: both overlap ≈ 1×STALL. Generous margin
        // for noisy CI runners: streaming must beat rounds by ≥ 1.3×.
        assert!(
            streaming_wall.as_secs_f64() * 1.3 < rounds_wall.as_secs_f64(),
            "streaming {streaming_wall:?} not faster than rounds {rounds_wall:?}"
        );
    }
}
