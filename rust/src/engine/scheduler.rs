//! Job scheduler: submits task batches to a [`Cluster`], retries failed
//! tasks (with fresh attempt numbers), and records job metrics.

use super::cluster::Cluster;
use super::plan::{TaskOutput, TaskSpec};
use crate::error::{Error, Result};
use std::time::Instant;

/// Per-job execution report.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub job_id: u64,
    pub tasks: usize,
    pub retries: usize,
    pub wall: std::time::Duration,
}

/// Run a job: all tasks to completion with bounded retries.
/// Returns outputs in task order plus the report.
pub fn run_job(
    cluster: &dyn Cluster,
    mut tasks: Vec<TaskSpec>,
    max_retries: usize,
) -> Result<(Vec<TaskOutput>, JobReport)> {
    let job_id = tasks.first().map(|t| t.job_id).unwrap_or(0);
    let total = tasks.len();
    let start = Instant::now();
    let mut outputs: Vec<Option<TaskOutput>> = vec![None; total];
    // positions[i] = original index of tasks[i] in the job
    let mut positions: Vec<usize> = (0..total).collect();
    let mut retries_used = 0usize;

    loop {
        let results = cluster.run_tasks(&tasks);
        debug_assert_eq!(results.len(), tasks.len());
        let mut retry_tasks = Vec::new();
        let mut retry_positions = Vec::new();
        let mut first_err: Option<Error> = None;

        for ((task, pos), res) in tasks.into_iter().zip(positions.iter().copied()).zip(results) {
            match res {
                Ok(out) => outputs[pos] = Some(out),
                Err(e) => {
                    crate::logmsg!(
                        "warn",
                        "job {job_id} task {} attempt {} failed: {e}",
                        task.task_id,
                        task.attempt
                    );
                    if (task.attempt as usize) < max_retries && e.is_retryable() {
                        let mut t = task;
                        t.attempt += 1;
                        retry_tasks.push(t);
                        retry_positions.push(pos);
                        retries_used += 1;
                    } else if first_err.is_none() {
                        first_err = Some(Error::Engine(format!(
                            "job {job_id} task {} failed after {} attempt(s): {e}",
                            task.task_id,
                            task.attempt + 1
                        )));
                    }
                }
            }
        }

        if let Some(e) = first_err {
            return Err(e);
        }
        if retry_tasks.is_empty() {
            break;
        }
        tasks = retry_tasks;
        positions = retry_positions;
    }

    let outputs: Vec<TaskOutput> = outputs
        .into_iter()
        .map(|o| o.expect("all positions filled or job errored"))
        .collect();
    let report =
        JobReport { job_id, tasks: total, retries: retries_used, wall: start.elapsed() };
    // process metrics (`Metrics::global().report()`)
    let m = crate::metrics::Metrics::global();
    m.counter("engine_jobs_completed").inc();
    m.counter("engine_tasks_completed").add(total as u64);
    m.counter("engine_task_retries").add(retries_used as u64);
    m.histogram("engine_job_wall").observe(report.wall);
    Ok((outputs, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::cluster::LocalCluster;
    use super::super::ops::OpRegistry;
    use super::super::plan::{Action, OpCall, Source};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn count_task(id: u32, n: u64, ops: Vec<OpCall>) -> TaskSpec {
        TaskSpec {
            job_id: 1,
            task_id: id,
            attempt: 0,
            source: Source::Range { start: 0, end: n },
            ops,
            action: Action::Count,
        }
    }

    #[test]
    fn healthy_job_completes() {
        let c = LocalCluster::new(2, OpRegistry::with_builtins(), "artifacts");
        let tasks = (0..8).map(|i| count_task(i, 10, vec![])).collect();
        let (outs, report) = run_job(&c, tasks, 2).unwrap();
        assert_eq!(outs.len(), 8);
        assert_eq!(report.retries, 0);
        assert!(outs.iter().all(|o| *o == TaskOutput::Count(10)));
    }

    #[test]
    fn transient_failures_are_retried() {
        let reg = OpRegistry::with_builtins();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        // Fails the first two invocations globally, then succeeds.
        reg.register("flaky", move |_c, _p, records| {
            if a.fetch_add(1, Ordering::SeqCst) < 2 {
                Err(Error::Engine("transient".into()))
            } else {
                Ok(records)
            }
        });
        let c = LocalCluster::new(1, reg, "artifacts");
        let tasks = vec![
            count_task(0, 5, vec![OpCall::new("flaky", vec![])]),
            count_task(1, 5, vec![OpCall::new("flaky", vec![])]),
        ];
        let (outs, report) = run_job(&c, tasks, 3).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(report.retries >= 1 && report.retries <= 2, "retries {}", report.retries);
    }

    #[test]
    fn permanent_failure_fails_job_with_context() {
        let reg = OpRegistry::with_builtins();
        reg.register("broken", |_c, _p, _r| Err(Error::Engine("always".into())));
        let c = LocalCluster::new(2, reg, "artifacts");
        let tasks = vec![count_task(3, 5, vec![OpCall::new("broken", vec![])])];
        let err = run_job(&c, tasks, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("task 3"), "{msg}");
        assert!(msg.contains("2 attempt"), "{msg}");
    }

    #[test]
    fn non_retryable_errors_fail_fast() {
        let reg = OpRegistry::with_builtins();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        reg.register("corrupt", move |_c, _p, _r| {
            a.fetch_add(1, Ordering::SeqCst);
            Err(Error::Corrupt("bad bytes".into()))
        });
        let c = LocalCluster::new(1, reg, "artifacts");
        let tasks = vec![count_task(0, 5, vec![OpCall::new("corrupt", vec![])])];
        assert!(run_job(&c, tasks, 5).is_err());
        assert_eq!(attempts.load(Ordering::SeqCst), 1, "corruption is not retried");
    }

    #[test]
    fn empty_job_is_ok() {
        let c = LocalCluster::new(1, OpRegistry::with_builtins(), "artifacts");
        let (outs, _) = run_job(&c, vec![], 1).unwrap();
        assert!(outs.is_empty());
    }
}
