//! Operator registry — the engine's substitute for Spark closure
//! serialization.
//!
//! Operators are whole-partition transforms registered under stable names
//! on both driver and workers (built-ins at startup; applications may
//! register more before creating workers — in local mode closures work
//! directly, in standalone mode the op must exist in the worker binary,
//! exactly like Spark needing the application jar on every executor).

use super::data::DataPlane;
use super::plan::{OpCall, PlayedRecord, Record};
use crate::error::{Error, Result};
use crate::pipe::{self, ChildSpec, LogicRegistry, PipeItem};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Services available to operators while running a task.
#[derive(Clone)]
pub struct TaskCtx {
    /// Worker-local data plane (paper §3.2's in-memory cache,
    /// generalized): resolves `DataRef`s — bags by path *or*
    /// content-addressed blocks fetched from a block peer — through one
    /// LRU byte cache shared by every clone of this context (all task
    /// slots of a worker process).
    pub data: DataPlane,
    /// AOT artifact directory for PJRT-backed ops.
    pub artifact_dir: String,
    /// Worker id (0-based) for logs and data-gen seeding.
    pub worker_id: usize,
    /// In-process user-logic registry (for the JNI-analogue ablation).
    pub logic: LogicRegistry,
}

impl TaskCtx {
    /// Context for worker `worker_id` with artifacts under `artifact_dir`.
    pub fn new(worker_id: usize, artifact_dir: impl Into<String>) -> Self {
        Self {
            data: DataPlane::new(1 << 30),
            artifact_dir: artifact_dir.into(),
            worker_id,
            logic: crate::full_logic_registry(),
        }
    }
}

/// A whole-partition operator.
pub type PartitionOp =
    Arc<dyn Fn(&TaskCtx, &[u8], Vec<Record>) -> Result<Vec<Record>> + Send + Sync>;

/// Thread-safe operator registry.
#[derive(Clone, Default)]
pub struct OpRegistry {
    ops: Arc<RwLock<HashMap<String, PartitionOp>>>,
}

impl OpRegistry {
    /// Empty registry (no operators — see [`OpRegistry::with_builtins`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Registry pre-loaded with all built-in operators.
    pub fn with_builtins() -> Self {
        let r = Self::new();
        register_builtin_ops(&r);
        r
    }

    /// Register a whole-partition operator.
    pub fn register(
        &self,
        name: &str,
        f: impl Fn(&TaskCtx, &[u8], Vec<Record>) -> Result<Vec<Record>> + Send + Sync + 'static,
    ) {
        self.ops.write().unwrap().insert(name.to_string(), Arc::new(f));
    }

    /// Register a per-record map (convenience).
    pub fn register_map(
        &self,
        name: &str,
        f: impl Fn(&TaskCtx, &[u8], Record) -> Result<Record> + Send + Sync + 'static,
    ) {
        self.register(name, move |ctx, params, records| {
            records.into_iter().map(|r| f(ctx, params, r)).collect()
        });
    }

    /// Register a per-record filter (convenience).
    pub fn register_filter(
        &self,
        name: &str,
        f: impl Fn(&TaskCtx, &[u8], &Record) -> Result<bool> + Send + Sync + 'static,
    ) {
        self.register(name, move |ctx, params, records| {
            let mut out = Vec::with_capacity(records.len());
            for r in records {
                if f(ctx, params, &r)? {
                    out.push(r);
                }
            }
            Ok(out)
        });
    }

    /// Look up an operator by name (actionable error when missing).
    pub fn get(&self, name: &str) -> Result<PartitionOp> {
        self.ops.read().unwrap().get(name).cloned().ok_or_else(|| {
            Error::Engine(format!(
                "unknown operator '{name}' — not registered on this worker \
                 (standalone workers only know built-ins and ops registered in main())"
            ))
        })
    }

    /// All registered operator names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.ops.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Apply an op chain to a partition.
    pub fn apply_chain(
        &self,
        ctx: &TaskCtx,
        ops: &[OpCall],
        mut records: Vec<Record>,
    ) -> Result<Vec<Record>> {
        for call in ops {
            let f = self.get(&call.name)?;
            records =
                super::trace::span_detail("op", &call.name, || f(ctx, &call.params, records))?;
        }
        Ok(records)
    }
}

/// Convert engine records → pipe items (records are opaque bytes).
fn records_to_items(records: Vec<Record>) -> Vec<PipeItem> {
    records.into_iter().map(PipeItem::Bytes).collect()
}

/// Convert pipe items back → engine records. Non-bytes items are
/// re-encoded through the codec so nothing is lost.
fn items_to_records(items: Vec<PipeItem>) -> Vec<Record> {
    items
        .into_iter()
        .map(|item| match item {
            PipeItem::Bytes(b) => b,
            other => {
                let mut w = crate::util::bytes::ByteWriter::new();
                other.encode_into(&mut w);
                w.into_vec()
            }
        })
        .collect()
}

/// Built-in operators available on every worker.
pub fn register_builtin_ops(reg: &OpRegistry) {
    // -- generic --
    reg.register("identity", |_ctx, _p, records| Ok(records));

    // params = varint n: keep first n records
    reg.register("take", |_ctx, params, mut records| {
        let mut r = crate::util::bytes::ByteReader::new(params);
        let n = r.get_varint()? as usize;
        records.truncate(n);
        Ok(records)
    });

    // Calibrated compute stall: params = varint micros per record.
    // Simulates an N-core cluster's CPU-bound perception work on this
    // 1-core testbed (DESIGN.md substitution table): the whole platform
    // path (scheduling, sources, collect) is real; only the DNN FLOPs
    // are replaced by a timed stall workers can overlap.
    reg.register("simulate_compute", |_ctx, params, records| {
        let mut r = crate::util::bytes::ByteReader::new(params);
        let micros = r.get_varint()?;
        std::thread::sleep(std::time::Duration::from_micros(
            micros * records.len() as u64,
        ));
        Ok(records)
    });

    // -- played-record (bag message) ops --
    // Extract the raw message payload from PlayedRecords.
    reg.register_map("take_payload", |_ctx, _p, rec| {
        Ok(PlayedRecord::decode(&rec)?.data)
    });

    // params = topic string: keep only messages on that topic.
    reg.register_filter("filter_topic", |_ctx, params, rec| {
        let topic = std::str::from_utf8(params)
            .map_err(|_| Error::Engine("filter_topic params not utf-8".into()))?;
        Ok(PlayedRecord::decode(rec)?.topic == topic)
    });

    // -- BinPipedRDD ops (paper §3.1) --
    // params = user-logic name. Pipes the partition through a child
    // process of this binary in `user-logic` mode.
    reg.register("binpipe", |ctx, params, records| {
        let logic = std::str::from_utf8(params)
            .map_err(|_| Error::Engine("binpipe params not utf-8".into()))?;
        let mut spec = ChildSpec::for_logic(logic)?;
        spec.env
            .push(("AV_SIMD_ARTIFACTS".into(), ctx.artifact_dir.clone()));
        let out = pipe::pipe_through_child(&spec, records_to_items(records))?;
        Ok(items_to_records(out))
    });

    // Ablation baseline: the same user logic run in-process (what the
    // paper's rejected JNI design would have bought).
    reg.register("binpipe_inproc", |ctx, params, records| {
        let logic = std::str::from_utf8(params)
            .map_err(|_| Error::Engine("binpipe_inproc params not utf-8".into()))?;
        let f = ctx.logic.get(logic)?;
        let out = f(records_to_items(records))?;
        Ok(items_to_records(out))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::{Message, Time};

    fn ctx() -> TaskCtx {
        TaskCtx::new(0, "artifacts")
    }

    #[test]
    fn unknown_op_is_actionable_error() {
        let reg = OpRegistry::with_builtins();
        let err = match reg.get("frobnicate") { Err(e) => e, Ok(_) => panic!("expected error") };
        assert!(err.to_string().contains("frobnicate"));
    }

    #[test]
    fn chain_applies_in_order() {
        let reg = OpRegistry::with_builtins();
        reg.register_map("append_a", |_c, _p, mut r| {
            r.push(b'a');
            Ok(r)
        });
        reg.register_map("append_b", |_c, _p, mut r| {
            r.push(b'b');
            Ok(r)
        });
        let out = reg
            .apply_chain(
                &ctx(),
                &[OpCall::new("append_a", vec![]), OpCall::new("append_b", vec![])],
                vec![vec![b'x']],
            )
            .unwrap();
        assert_eq!(out, vec![b"xab".to_vec()]);
    }

    #[test]
    fn take_op_truncates() {
        let reg = OpRegistry::with_builtins();
        let mut w = crate::util::bytes::ByteWriter::new();
        w.put_varint(2);
        let out = reg
            .apply_chain(
                &ctx(),
                &[OpCall::new("take", w.into_vec())],
                vec![vec![1], vec![2], vec![3]],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn filter_topic_and_take_payload() {
        let reg = OpRegistry::with_builtins();
        let recs: Vec<Record> = [("/camera", b"img".to_vec()), ("/lidar", b"pc".to_vec())]
            .into_iter()
            .map(|(topic, data)| {
                PlayedRecord {
                    topic: topic.into(),
                    type_name: "T".into(),
                    time: Time::ZERO,
                    data,
                }
                .encode()
            })
            .collect();
        let out = reg
            .apply_chain(
                &ctx(),
                &[
                    OpCall::new("filter_topic", b"/camera".to_vec()),
                    OpCall::new("take_payload", vec![]),
                ],
                recs,
            )
            .unwrap();
        assert_eq!(out, vec![b"img".to_vec()]);
    }

    #[test]
    fn binpipe_inproc_runs_logic() {
        let reg = OpRegistry::with_builtins();
        let img = crate::msg::Image::synthetic(4, 6, 1);
        let out = reg
            .apply_chain(
                &ctx(),
                &[OpCall::new("binpipe_inproc", b"rotate90".to_vec())],
                vec![img.encode()],
            )
            .unwrap();
        let rot = crate::msg::Image::decode(&out[0]).unwrap();
        assert_eq!((rot.width, rot.height), (6, 4));
    }

    #[test]
    fn register_filter_propagates_errors() {
        let reg = OpRegistry::with_builtins();
        reg.register_filter("always_err", |_c, _p, _r| {
            Err(Error::Engine("nope".into()))
        });
        let res = reg.apply_chain(
            &ctx(),
            &[OpCall::new("always_err", vec![])],
            vec![vec![1]],
        );
        assert!(res.is_err());
    }
}
