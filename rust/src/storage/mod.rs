//! DFS-lite — the platform's HDFS stand-in (paper Fig 3's storage tier).
//!
//! A [`BlockStore`] is a directory of content-addressed, hash-verified
//! blocks plus manifests mapping an object to its block list. Manifests
//! come in two flavours: *named* (the original `put`/`get` API — a
//! logical path chosen by the caller) and *content-addressed* (the
//! [`BlockStore::publish`] API — the manifest is stored under the
//! SHA-256 of its own bytes, so a [`ManifestId`] is a verifiable name
//! for an exact byte sequence; this is what the engine's data plane
//! ships over RPC).
//!
//! Blocks are addressed by SHA-256 — NOT CRC32: bag records embed their
//! own CRC32, and `CRC(m ‖ CRC(m))` is a constant residue, so distinct
//! bags can share a whole-file CRC32 (a real collision our integration
//! suite caught). A cryptographic hash makes dedupe sound.
//! It gives the engine the two HDFS behaviours the paper relies on:
//! durable binary outputs (`RDD[Bytes] → HDFS`) and chunked re-reads, with
//! corruption detection on every read. Replication across machines is out
//! of scope (single-box testbed); the API is shaped so a replicated
//! implementation could slot in. What *is* in scope is shipping blocks
//! between machines: see `engine::data` for the RPC fetch path and
//! [`BlockChunkStore`] for replaying a bag directly off verified blocks.

use crate::bag::ChunkStore;
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A 32-byte SHA-256 content address (block or manifest).
pub type BlockId = [u8; 32];

/// Content address of a block: SHA-256 digest (from `util::sha256`; the
/// offline crate set has no `sha2`).
fn block_id(data: &[u8]) -> BlockId {
    crate::util::sha256::digest(data)
}

/// Hex-encode a 32-byte content address (lowercase, 64 chars). One
/// `String` allocation and a nibble lookup table — this sits on the
/// data plane's block-naming hot path (every block write, read, fetch,
/// and cache key goes through it), where the old per-byte
/// `format!("{b:02x}")` allocated 32 intermediate `String`s per id.
pub fn hex32(id: &BlockId) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(64);
    for &b in id {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0x0f) as usize] as char);
    }
    out
}

fn hex(id: &BlockId) -> String {
    hex32(id)
}

/// Content address of a published manifest: the SHA-256 of the encoded
/// manifest bytes. Naming an object by its manifest id pins the *exact*
/// byte sequence — a fetched manifest (and every block it names) is
/// verifiable against the id alone, which is what lets the engine ship
/// bag bytes between mutually untrusting processes.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ManifestId(pub BlockId);

impl ManifestId {
    /// Lowercase 64-char hex form (the on-disk manifest file stem).
    pub fn hex(&self) -> String {
        hex32(&self.0)
    }

    /// First 12 hex chars — enough for logs, short enough to read.
    pub fn short(&self) -> String {
        self.hex()[..12].to_string()
    }

    /// Parse a 64-char hex string back into an id. Strictly hex digits
    /// only (`from_str_radix` alone would accept a `+` sign per pair,
    /// silently resolving a mistyped id to a different manifest).
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.len() != 64 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(Error::Storage(format!(
                "manifest id must be 64 hex chars, got {} ('{s}')",
                s.len()
            )));
        }
        let mut id = [0u8; 32];
        for (i, byte) in id.iter_mut().enumerate() {
            *byte = u8::from_str_radix(&s[2 * i..2 * i + 2], 16).map_err(|_| {
                Error::Storage(format!("manifest id has non-hex chars: '{s}'"))
            })?;
        }
        Ok(Self(id))
    }
}

impl fmt::Display for ManifestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

impl fmt::Debug for ManifestId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ManifestId({})", self.short())
    }
}

/// One block reference inside a [`Manifest`]: content address + length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockRef {
    /// SHA-256 of the block bytes.
    pub id: BlockId,
    /// Block length in bytes.
    pub len: u32,
}

/// An object's block list: how `total_len` bytes are split into
/// content-addressed blocks, in order. The encoded form is both the
/// on-disk manifest file and the RPC `ManifestData` payload.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Manifest {
    /// Total object length (Σ block lens; kept explicit so truncation
    /// of the block list is detectable).
    pub total_len: u64,
    /// Blocks in object order.
    pub blocks: Vec<BlockRef>,
}

impl Manifest {
    /// Split `data` into `block_size` chunks and describe them (no I/O).
    pub fn describe(data: &[u8], block_size: usize) -> Self {
        let blocks = if data.is_empty() {
            Vec::new()
        } else {
            data.chunks(block_size)
                .map(|c| BlockRef { id: block_id(c), len: c.len() as u32 })
                .collect()
        };
        Self { total_len: data.len() as u64, blocks }
    }

    /// Serialize: `varint n_blocks ‖ u64 total_len ‖ (id[32] ‖ u32 len)*`
    /// — byte-compatible with the manifests [`BlockStore::put`] has
    /// always written.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::with_capacity(16 + self.blocks.len() * 36);
        w.put_varint(self.blocks.len() as u64);
        w.put_u64(self.total_len);
        for b in &self.blocks {
            w.put_raw(&b.id);
            w.put_u32(b.len);
        }
        w.into_vec()
    }

    /// Decode a [`Manifest::encode`] payload, validating that the block
    /// lengths sum to `total_len`.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        let n = r.get_varint()? as usize;
        let total_len = r.get_u64()?;
        let mut blocks = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let id: BlockId = r.get_raw(32)?.try_into().unwrap();
            blocks.push(BlockRef { id, len: r.get_u32()? });
        }
        let m = Self { total_len, blocks };
        let sum: u64 = m.blocks.iter().map(|b| b.len as u64).sum();
        if sum != m.total_len {
            return Err(Error::Storage(format!(
                "manifest block lengths sum to {sum}, header says {}",
                m.total_len
            )));
        }
        Ok(m)
    }

    /// Content address of this manifest (SHA-256 of [`Manifest::encode`]).
    pub fn id(&self) -> ManifestId {
        ManifestId(block_id(&self.encode()))
    }

    /// Object byte offset where block `index` starts.
    pub fn block_offset(&self, index: usize) -> u64 {
        self.blocks[..index].iter().map(|b| b.len as u64).sum()
    }
}

/// Default block size (4 MiB, HDFS-small because our testbed is small).
pub const DEFAULT_BLOCK_SIZE: usize = 4 * 1024 * 1024;

/// Content-addressed block store with named and content-addressed
/// manifests.
pub struct BlockStore {
    root: PathBuf,
    block_size: usize,
}

impl BlockStore {
    /// Open (or create) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(root.join("blocks"))?;
        std::fs::create_dir_all(root.join("manifests"))?;
        Ok(Self { root, block_size: DEFAULT_BLOCK_SIZE })
    }

    /// Override the content-split block size (min 1 KiB); builder-style.
    pub fn with_block_size(mut self, n: usize) -> Self {
        self.block_size = n.max(1024);
        self
    }

    /// The directory this store lives in.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The block size new objects are split at.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    fn block_path(&self, id: &BlockId) -> PathBuf {
        self.root.join("blocks").join(format!("{}.blk", hex(id)))
    }

    fn manifest_path(&self, name: &str) -> Result<PathBuf> {
        if name.is_empty() || name.contains('/') || name.contains("..") {
            return Err(Error::Storage(format!("bad object name '{name}'")));
        }
        Ok(self.root.join("manifests").join(format!("{name}.mf")))
    }

    /// Write `data` to `path` atomically (temp file + rename), so a
    /// concurrent publisher of identical content can never expose a
    /// half-written block: both racers write their own temp file and the
    /// renames are idempotent (same bytes, same final name).
    fn write_atomic(&self, path: &Path, data: &[u8]) -> Result<()> {
        // pid + per-process counter makes the temp name unique even for
        // same-instant writers in one process (nanos alone can collide
        // on coarse clocks, and two racers sharing a temp file would
        // fail the second rename)
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        std::fs::write(&tmp, data)?;
        std::fs::rename(&tmp, path).map_err(|e| {
            std::fs::remove_file(&tmp).ok();
            Error::Io(e)
        })
    }

    /// Write every block of `manifest` that is not already present.
    fn write_blocks(&self, data: &[u8], manifest: &Manifest) -> Result<()> {
        let mut off = 0usize;
        for b in &manifest.blocks {
            let path = self.block_path(&b.id);
            if !path.exists() {
                self.write_atomic(&path, &data[off..off + b.len as usize])?;
            }
            off += b.len as usize;
        }
        Ok(())
    }

    /// Store `data` under a caller-chosen `name`, splitting into
    /// content-addressed blocks. Identical blocks dedupe.
    pub fn put(&self, name: &str, data: &[u8]) -> Result<()> {
        let path = self.manifest_path(name)?;
        let manifest = Manifest::describe(data, self.block_size);
        self.write_blocks(data, &manifest)?;
        self.write_atomic(&path, &manifest.encode())?;
        Ok(())
    }

    /// Publish `data` as a content-addressed object: blocks are written
    /// (deduped), the manifest is stored under the SHA-256 of its own
    /// bytes, and that [`ManifestId`] is returned alongside the block
    /// list. Publishing identical content from any number of processes
    /// concurrently converges on one set of files (atomic writes +
    /// content-derived names).
    pub fn publish(&self, data: &[u8]) -> Result<(ManifestId, Manifest)> {
        let manifest = Manifest::describe(data, self.block_size);
        self.write_blocks(data, &manifest)?;
        let id = manifest.id();
        let path = self.manifest_path(&id.hex())?;
        if !path.exists() {
            self.write_atomic(&path, &manifest.encode())?;
        }
        Ok((id, manifest))
    }

    /// [`BlockStore::publish`] for a file on disk (the bag-publish
    /// path: `publish_bag(bag_path)` → manifest id the engine ships to
    /// workers instead of the path).
    pub fn publish_bag(&self, path: impl AsRef<Path>) -> Result<(ManifestId, Manifest)> {
        let path = path.as_ref();
        let data = std::fs::read(path)
            .map_err(|e| Error::Storage(format!("publish bag '{}': {e}", path.display())))?;
        self.publish(&data)
    }

    /// Load a published manifest by id, verifying the bytes against the
    /// id (a manifest that does not hash to its own name is corrupt).
    pub fn manifest(&self, id: &ManifestId) -> Result<Manifest> {
        let path = self.manifest_path(&id.hex())?;
        let bytes = std::fs::read(&path).map_err(|e| {
            Error::Storage(format!(
                "manifest {} not readable in store {}: {e}",
                id.short(),
                self.root.display()
            ))
        })?;
        if block_id(&bytes) != id.0 {
            return Err(Error::Storage(format!(
                "manifest {} bytes do not hash to their id — corrupt manifest file",
                id.short()
            )));
        }
        Manifest::decode(&bytes)
    }

    /// Load a published manifest's raw encoded bytes by id, verified
    /// against the id. This is the block-server serving path: the bytes
    /// go on the wire exactly as stored (no decode/re-encode roundtrip).
    pub fn manifest_bytes(&self, id: &ManifestId) -> Result<Vec<u8>> {
        let path = self.manifest_path(&id.hex())?;
        let bytes = std::fs::read(&path).map_err(|e| {
            Error::Storage(format!(
                "manifest {} not readable in store {}: {e}",
                id.short(),
                self.root.display()
            ))
        })?;
        if block_id(&bytes) != id.0 {
            return Err(Error::Storage(format!(
                "manifest {} bytes do not hash to their id — corrupt manifest file",
                id.short()
            )));
        }
        Ok(bytes)
    }

    /// Read and verify one block named by `bref`. `object_offset` is the
    /// block's byte offset inside its object, carried into every error
    /// so corruption reports name both the block id and where in the
    /// object it sits.
    pub fn read_block(&self, bref: &BlockRef, object_offset: u64) -> Result<Vec<u8>> {
        let path = self.block_path(&bref.id);
        let data = std::fs::read(&path).map_err(|e| {
            Error::Storage(format!(
                "block {} (object bytes {object_offset}..{}): {e}",
                hex(&bref.id),
                object_offset + bref.len as u64
            ))
        })?;
        verify_block(&data, bref, object_offset)?;
        Ok(data)
    }

    /// Open a published object as a playable [`BlockChunkStore`]: every
    /// block is read and hash-verified up front, then served zero-copy.
    /// `BagReader`/`BagIndex` run directly on the result.
    pub fn open_object(&self, id: &ManifestId) -> Result<BlockChunkStore> {
        let manifest = self.manifest(id)?;
        let mut blocks = Vec::with_capacity(manifest.blocks.len());
        let mut off = 0u64;
        for b in &manifest.blocks {
            blocks.push(Arc::new(self.read_block(b, off)?));
            off += b.len as u64;
        }
        Ok(BlockChunkStore::new(blocks))
    }

    /// Fetch a named object, verifying every block's hash.
    pub fn get(&self, name: &str) -> Result<Vec<u8>> {
        let mf = std::fs::read(self.manifest_path(name)?)
            .map_err(|e| Error::Storage(format!("object '{name}': {e}")))?;
        let manifest = Manifest::decode(&mf)?;
        let mut out = Vec::with_capacity(manifest.total_len as usize);
        let mut off = 0u64;
        for b in &manifest.blocks {
            out.extend_from_slice(&self.read_block(b, off)?);
            off += b.len as u64;
        }
        if out.len() as u64 != manifest.total_len {
            return Err(Error::Storage(format!(
                "object '{name}' reassembled to {} bytes, manifest said {}",
                out.len(),
                manifest.total_len
            )));
        }
        Ok(out)
    }

    /// List stored object names (named and content-addressed alike).
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for e in std::fs::read_dir(self.root.join("manifests"))? {
            let p = e?.path();
            if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                if p.extension().map(|x| x == "mf").unwrap_or(false) {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// True when an object named `name` exists in the store.
    pub fn exists(&self, name: &str) -> bool {
        self.manifest_path(name).map(|p| p.exists()).unwrap_or(false)
    }

    /// Delete an object's manifest (blocks are left for GC; shared blocks
    /// may be referenced by other manifests).
    pub fn delete(&self, name: &str) -> Result<()> {
        std::fs::remove_file(self.manifest_path(name)?)?;
        Ok(())
    }

    /// Garbage-collect the store: delete every **content-addressed**
    /// manifest not in `live`, then every block file unreachable from
    /// the surviving manifests.
    ///
    /// Named manifests (checkpoints, objects stored with
    /// [`BlockStore::put`]) are implicit GC roots — their stems don't
    /// parse as [`ManifestId`]s and they are never deleted; blocks they
    /// reference survive. Blocks shared between a dead and a live
    /// manifest survive (reachability is computed over the survivors,
    /// not the deletions).
    ///
    /// Exactly one GC may run at a time per store: a `gc.lock` file at
    /// the store root is taken exclusively (`create_new`) and removed on
    /// exit; a concurrent run fails with [`Error::Storage`] naming the
    /// lock. A crashed GC leaves the lock behind — delete it manually
    /// after checking no GC is running (the error says so).
    pub fn gc(&self, live: &[ManifestId]) -> Result<GcStats> {
        let lock_path = self.root.join("gc.lock");
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&lock_path) {
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                return Err(Error::Storage(format!(
                    "gc already running on store {} ({} exists; if no gc is \
                     actually running, a previous run crashed — remove the \
                     lock file and retry)",
                    self.root.display(),
                    lock_path.display()
                )));
            }
            Err(e) => return Err(Error::Io(e)),
        }
        struct Unlock(PathBuf);
        impl Drop for Unlock {
            fn drop(&mut self) {
                std::fs::remove_file(&self.0).ok();
            }
        }
        let _unlock = Unlock(lock_path);

        let mut stats = GcStats::default();
        // Pass 1: drop dead content-addressed manifests (parseable
        // 64-hex stems not in the live set). Named stems are roots.
        let live_set: std::collections::HashSet<[u8; 32]> =
            live.iter().map(|id| id.0).collect();
        let mut survivors = Vec::new();
        for name in self.list()? {
            match ManifestId::parse(&name) {
                Ok(id) if !live_set.contains(&id.0) => {
                    self.delete(&name)?;
                    stats.manifests_deleted += 1;
                }
                _ => survivors.push(name),
            }
        }
        // Pass 2: compute block reachability over the survivors, then
        // sweep unreferenced block files.
        let mut reachable: std::collections::HashSet<[u8; 32]> =
            std::collections::HashSet::new();
        for name in &survivors {
            let bytes = std::fs::read(self.manifest_path(name)?)
                .map_err(|e| Error::Storage(format!("gc: manifest '{name}': {e}")))?;
            for b in &Manifest::decode(&bytes)?.blocks {
                reachable.insert(b.id);
            }
        }
        for e in std::fs::read_dir(self.root.join("blocks"))? {
            let p = e?.path();
            if !p.extension().map(|x| x == "blk").unwrap_or(false) {
                continue;
            }
            let Some(stem) = p.file_stem().and_then(|s| s.to_str()) else { continue };
            let Ok(id) = ManifestId::parse(stem) else { continue };
            if !reachable.contains(&id.0) {
                let len = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
                std::fs::remove_file(&p)?;
                stats.blocks_deleted += 1;
                stats.bytes_reclaimed += len;
            }
        }
        stats.manifests_kept = survivors.len();
        Ok(stats)
    }

    /// Read a published (content-addressed) object back in full,
    /// verifying the manifest bytes against `id` and every block against
    /// its hash — a bit flip anywhere fails loudly with the damaged
    /// block's id and offset instead of returning silently wrong bytes.
    pub fn read_published(&self, id: &ManifestId) -> Result<Vec<u8>> {
        let manifest = self.manifest(id)?;
        let mut out = Vec::with_capacity(manifest.total_len as usize);
        let mut off = 0u64;
        for b in &manifest.blocks {
            out.extend_from_slice(&self.read_block(b, off)?);
            off += b.len as u64;
        }
        Ok(out)
    }

    /// [`BlockStore::gc`] with **root-list objects** honored: every named
    /// object whose name ends in [`ROOTS_SUFFIX`] is read as an encoded
    /// [`ManifestId`] list ([`encode_roots`]) and its ids join the live
    /// set. This is how long-lived registries of published objects (the
    /// fuzz regression corpus, for one) pin their entries across GC runs
    /// without the caller having to re-enumerate them on every sweep:
    /// deleting the root list is the explicit act that releases them.
    pub fn gc_with_roots(&self, live: &[ManifestId]) -> Result<GcStats> {
        let mut all = live.to_vec();
        for name in self.list()? {
            if !name.ends_with(ROOTS_SUFFIX) {
                continue;
            }
            let ids = decode_roots(&self.get(&name)?).map_err(|e| {
                Error::Storage(format!("gc: root list '{name}' is unreadable: {e}"))
            })?;
            all.extend(ids);
        }
        self.gc(&all)
    }
}

/// Name suffix that marks a named object as a GC root list (see
/// [`BlockStore::gc_with_roots`]).
pub const ROOTS_SUFFIX: &str = ".roots";

/// Wire version of the [`encode_roots`] root-list payload.
pub const ROOTS_VERSION: u8 = 1;

/// Encode a [`ManifestId`] list as a root-list object payload:
/// `u8 version ‖ varint n ‖ n × [u8; 32] ‖ u32 crc32(body)`.
pub fn encode_roots(ids: &[ManifestId]) -> Vec<u8> {
    let mut w = crate::util::bytes::ByteWriter::with_capacity(6 + ids.len() * 32);
    w.put_u8(ROOTS_VERSION);
    w.put_varint(ids.len() as u64);
    for id in ids {
        w.put_raw(&id.0);
    }
    let crc = crate::util::crc32::hash(w.as_slice());
    w.put_u32(crc);
    w.into_vec()
}

/// Decode and verify an [`encode_roots`] payload. Truncation, trailing
/// bytes, a CRC mismatch, or an unknown version are all [`Error::Corrupt`]
/// — a damaged root list must fail a GC run, not silently unpin objects.
pub fn decode_roots(buf: &[u8]) -> Result<Vec<ManifestId>> {
    if buf.len() < 4 {
        return Err(Error::Corrupt(format!(
            "root list truncated: {} byte(s), need at least 4",
            buf.len()
        )));
    }
    let (body, tail) = buf.split_at(buf.len() - 4);
    let stored = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    let actual = crate::util::crc32::hash(body);
    if stored != actual {
        return Err(Error::Corrupt(format!(
            "root list CRC mismatch: stored {stored:#010x}, computed {actual:#010x}"
        )));
    }
    let mut r = crate::util::bytes::ByteReader::new(body);
    let version = r.get_u8()?;
    if version != ROOTS_VERSION {
        return Err(Error::Corrupt(format!(
            "unsupported root list version {version} (expected {ROOTS_VERSION})"
        )));
    }
    let n = r.get_varint()? as usize;
    if n > r.remaining() / 32 {
        return Err(Error::Corrupt(format!("root list claims {n} ids")));
    }
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        let mut id = [0u8; 32];
        id.copy_from_slice(r.get_raw(32)?);
        ids.push(ManifestId(id));
    }
    if !r.is_empty() {
        return Err(Error::Corrupt(format!(
            "root list has {} trailing byte(s)",
            r.remaining()
        )));
    }
    Ok(ids)
}

/// What a [`BlockStore::gc`] run deleted and kept.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcStats {
    /// Content-addressed manifests deleted (not in the live set).
    pub manifests_deleted: usize,
    /// Manifests that survived (live + named roots).
    pub manifests_kept: usize,
    /// Block files deleted as unreachable.
    pub blocks_deleted: usize,
    /// Total bytes of deleted block files.
    pub bytes_reclaimed: u64,
}

/// Verify fetched/read block bytes against their [`BlockRef`]: length
/// first (a truncated block file), then the SHA-256 (a bit flip). Both
/// error messages carry the block id and the object byte offset. Shared
/// by the local read path and the RPC fetch path, so a corrupt block is
/// rejected identically wherever it surfaces.
pub fn verify_block(data: &[u8], bref: &BlockRef, object_offset: u64) -> Result<()> {
    if data.len() != bref.len as usize {
        return Err(Error::Storage(format!(
            "block {} at object byte offset {object_offset}: {} bytes on hand, \
             manifest says {} — truncated block?",
            hex(&bref.id),
            data.len(),
            bref.len
        )));
    }
    if block_id(data) != bref.id {
        return Err(Error::Storage(format!(
            "block {} at object byte offset {object_offset}: hash mismatch — \
             content does not match its address",
            hex(&bref.id)
        )));
    }
    Ok(())
}

/// A read-only [`ChunkStore`] over a list of verified, shared blocks —
/// the data plane's read adapter: `BagReader` and `BagIndex` replay a
/// bag straight off content-addressed blocks (local or fetched over
/// RPC) with no contiguous reassembly copy. Blocks are `Arc`-shared
/// with the worker's block cache, so opening the same bag twice costs
/// no memory.
pub struct BlockChunkStore {
    blocks: Vec<Arc<Vec<u8>>>,
    /// Start offset of each block (ascending); `ends[i] = starts[i] + len`.
    starts: Vec<u64>,
    len: u64,
}

impl BlockChunkStore {
    /// Build from blocks in object order (zero-length blocks are
    /// dropped — they carry no bytes and would stall the read walk).
    pub fn new(blocks: Vec<Arc<Vec<u8>>>) -> Self {
        let blocks: Vec<Arc<Vec<u8>>> =
            blocks.into_iter().filter(|b| !b.is_empty()).collect();
        let mut starts = Vec::with_capacity(blocks.len());
        let mut off = 0u64;
        for b in &blocks {
            starts.push(off);
            off += b.len() as u64;
        }
        Self { blocks, starts, len: off }
    }

    /// A single-block view over one shared buffer (the path-cache fast
    /// path: a whole cached bag served zero-copy).
    pub fn from_arc(data: Arc<Vec<u8>>) -> Self {
        Self::new(vec![data])
    }
}

impl ChunkStore for BlockChunkStore {
    fn append(&mut self, _data: &[u8]) -> Result<u64> {
        Err(Error::Storage(
            "content-addressed object is read-only (blocks are immutable)".into(),
        ))
    }

    fn read_at(&mut self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.read_at_into(offset, len, &mut out)?;
        Ok(out)
    }

    fn read_at_into(&mut self, offset: u64, len: usize, out: &mut Vec<u8>) -> Result<()> {
        if offset.checked_add(len as u64).is_none_or(|end| end > self.len) {
            return Err(Error::Corrupt(format!(
                "block object read past end: offset {offset} + {len} > {}",
                self.len
            )));
        }
        out.clear();
        if len == 0 {
            return Ok(());
        }
        // find the block containing `offset`
        let mut i = match self.starts.binary_search(&offset) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        out.reserve(len);
        let mut pos = offset;
        let mut remaining = len;
        while remaining > 0 {
            let block = &self.blocks[i];
            let in_block = (pos - self.starts[i]) as usize;
            let take = remaining.min(block.len() - in_block);
            out.extend_from_slice(&block[in_block..in_block + take]);
            pos += take as u64;
            remaining -= take;
            i += 1;
        }
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len
    }

    fn flush(&mut self) -> Result<()> {
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "blocks"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (BlockStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_test_store_{}_{:x}",
            std::process::id(),
            crate::util::now_nanos()
        ));
        (BlockStore::open(&dir).unwrap(), dir)
    }

    #[test]
    fn gc_keeps_live_and_named_deletes_the_rest() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        // two published objects sharing their first two blocks, one
        // unshared object, one named object
        let mut shared: Vec<u8> = (0..2048).map(|i| (i % 249) as u8).collect();
        let a = shared.clone();
        shared.extend((0..1024).map(|i| (i % 7) as u8));
        let b = shared; // a's blocks + one more
        let dead: Vec<u8> = (0..2048).map(|i| (i % 13) as u8).collect();
        let (id_a, _) = s.publish(&a).unwrap();
        let (id_b, mf_b) = s.publish(&b).unwrap();
        let (id_dead, mf_dead) = s.publish(&dead).unwrap();
        let named: Vec<u8> = (0..1500).map(|i| (i % 11) as u8).collect();
        s.put("keep_me", &named).unwrap();

        // keep b (live) — a dies, but every one of a's blocks is shared
        // with b and must survive; dead's blocks are unshared and go
        let stats = s.gc(&[id_b]).unwrap();
        assert_eq!(stats.manifests_deleted, 2, "a and dead dropped");
        assert_eq!(stats.manifests_kept, 2, "b + named kept");
        assert_eq!(stats.blocks_deleted, mf_dead.blocks.len());
        assert_eq!(
            stats.bytes_reclaimed,
            mf_dead.blocks.iter().map(|x| x.len as u64).sum::<u64>()
        );
        assert!(s.open_object(&id_b).is_ok(), "live object intact");
        assert_eq!(s.get("keep_me").unwrap(), named, "named root intact");
        assert!(s.manifest(&id_a).is_err(), "dead manifest gone");
        assert!(s.manifest(&id_dead).is_err());
        // b's blocks (including those it shared with a) all still read
        for (i, bref) in mf_b.blocks.iter().enumerate() {
            assert!(
                s.read_block(bref, (i * 1024) as u64).is_ok(),
                "shared block {i} must survive a's deletion"
            );
        }
        // idempotent: a second gc with the same live set deletes nothing
        let again = s.gc(&[id_b]).unwrap();
        assert_eq!(again.manifests_deleted, 0);
        assert_eq!(again.blocks_deleted, 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn roots_codec_roundtrips_and_rejects_damage() {
        let ids: Vec<ManifestId> =
            (0u8..5).map(|i| ManifestId([i.wrapping_mul(37); 32])).collect();
        let buf = encode_roots(&ids);
        assert_eq!(decode_roots(&buf).unwrap(), ids);
        assert_eq!(decode_roots(&encode_roots(&[])).unwrap(), vec![]);
        // any truncation is rejected
        for cut in 0..buf.len() {
            assert!(decode_roots(&buf[..cut]).is_err(), "prefix of {cut} bytes");
        }
        // any single bit flip is rejected
        for byte in 0..buf.len() {
            let mut damaged = buf.clone();
            damaged[byte] ^= 0x10;
            assert!(decode_roots(&damaged).is_err(), "flip in byte {byte}");
        }
        // structurally-trailing bytes with a recomputed CRC are rejected
        let mut body = buf[..buf.len() - 4].to_vec();
        body.push(0xEE);
        let crc = crate::util::crc32::hash(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode_roots(&body), Err(Error::Corrupt(_))));
    }

    #[test]
    fn gc_with_roots_pins_listed_objects_until_the_list_is_deleted() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let pinned: Vec<u8> = (0..3000).map(|i| (i % 101) as u8).collect();
        let dead: Vec<u8> = (0..3000).map(|i| (i % 57) as u8).collect();
        let (id_pinned, _) = s.publish(&pinned).unwrap();
        let (id_dead, _) = s.publish(&dead).unwrap();
        s.put("corpus.roots", &encode_roots(&[id_pinned])).unwrap();

        // the root list pins its entry; the unlisted publish dies
        let stats = s.gc_with_roots(&[]).unwrap();
        assert_eq!(stats.manifests_deleted, 1);
        assert!(s.manifest(&id_dead).is_err(), "unlisted object collected");
        assert_eq!(s.read_published(&id_pinned).unwrap(), pinned);

        // a damaged root list fails the GC instead of unpinning
        let mut raw = encode_roots(&[id_pinned]);
        let last = raw.len() - 1;
        raw[last] ^= 0xFF;
        s.put("corpus.roots", &raw).unwrap();
        let err = s.gc_with_roots(&[]).unwrap_err();
        assert!(err.to_string().contains("corpus.roots"), "{err}");
        assert!(
            !dir.join("gc.lock").exists(),
            "failed gc still releases the lock"
        );

        // deleting the root list releases the entry on the next sweep
        s.delete("corpus.roots").unwrap();
        let stats = s.gc_with_roots(&[]).unwrap();
        assert_eq!(stats.manifests_deleted, 1);
        assert!(s.manifest(&id_pinned).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gc_lockfile_refuses_concurrent_runs() {
        let (s, dir) = store();
        s.put("x", b"hello").unwrap();
        std::fs::write(dir.join("gc.lock"), b"").unwrap();
        let err = s.gc(&[]).unwrap_err();
        assert!(err.to_string().contains("already running"), "{err}");
        std::fs::remove_file(dir.join("gc.lock")).unwrap();
        let stats = s.gc(&[]).unwrap();
        assert_eq!(stats.manifests_kept, 1);
        assert!(
            !dir.join("gc.lock").exists(),
            "lock released after a successful run"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn put_get_roundtrip_multiblock() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data: Vec<u8> = (0..10_000).map(|i| (i % 251) as u8).collect();
        s.put("drive_001", &data).unwrap();
        assert_eq!(s.get("drive_001").unwrap(), data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_object_ok() {
        let (s, dir) = store();
        s.put("empty", &[]).unwrap();
        assert!(s.get("empty").unwrap().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn hex_matches_reference() {
        let mut id = [0u8; 32];
        for (i, b) in id.iter_mut().enumerate() {
            *b = (i * 37 % 256) as u8;
        }
        let reference: String = id.iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(hex32(&id), reference);
        assert_eq!(hex32(&[0u8; 32]), "0".repeat(64));
        assert_eq!(hex32(&[0xffu8; 32]), "f".repeat(64));
    }

    #[test]
    fn manifest_id_hex_parse_roundtrip() {
        let id = ManifestId(block_id(b"some object"));
        assert_eq!(ManifestId::parse(&id.hex()).unwrap(), id);
        assert!(ManifestId::parse("abc").is_err());
        assert!(ManifestId::parse(&"g".repeat(64)).is_err());
        // from_str_radix would accept '+1' pairs — parse must not
        assert!(ManifestId::parse(&"+1".repeat(32)).is_err());
        assert!(ManifestId::parse(&" 1".repeat(32)).is_err());
    }

    #[test]
    fn publish_is_content_addressed_and_openable() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data: Vec<u8> = (0..5000).map(|i| (i % 241) as u8).collect();
        let (id, manifest) = s.publish(&data).unwrap();
        assert_eq!(manifest.total_len, 5000);
        assert_eq!(manifest.blocks.len(), 5);
        // the id is the hash of the manifest bytes — re-publishing the
        // same content yields the same id and no new files
        let n_files = std::fs::read_dir(dir.join("blocks")).unwrap().count();
        let (id2, _) = s.publish(&data).unwrap();
        assert_eq!(id, id2);
        assert_eq!(std::fs::read_dir(dir.join("blocks")).unwrap().count(), n_files);
        // open_object reassembles verified bytes
        let mut obj = s.open_object(&id).unwrap();
        assert_eq!(obj.read_at(0, 5000).unwrap(), data);
        assert_eq!(obj.len(), 5000);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_typed_with_id() {
        let (s, dir) = store();
        let id = ManifestId(block_id(b"never published"));
        let err = s.manifest(&id).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Storage(_)), "{msg}");
        assert!(msg.contains(&id.short()), "manifest id lost: {msg}");
        assert!(s.open_object(&id).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_block_file_is_typed_with_id_and_offset() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data = vec![9u8; 3000];
        let (id, manifest) = s.publish(&data).unwrap();
        // truncate the middle block on disk
        let victim = &manifest.blocks[1];
        let path = dir.join("blocks").join(format!("{}.blk", hex(&victim.id)));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(100);
        std::fs::write(&path, bytes).unwrap();
        let err = s.open_object(&id).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Storage(_)), "{msg}");
        assert!(msg.contains("truncated"), "{msg}");
        assert!(msg.contains(&hex(&victim.id)), "block id lost: {msg}");
        assert!(msg.contains("offset 1024"), "object offset lost: {msg}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bit_flipped_block_is_typed_with_id_and_offset() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data: Vec<u8> = (0..3000).map(|i| (i % 201) as u8).collect();
        let (id, manifest) = s.publish(&data).unwrap();
        let victim = &manifest.blocks[2];
        let path = dir.join("blocks").join(format!("{}.blk", hex(&victim.id)));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xff; // same length, different content
        std::fs::write(&path, bytes).unwrap();
        let err = s.open_object(&id).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Storage(_)), "{msg}");
        assert!(msg.contains("hash mismatch"), "{msg}");
        assert!(msg.contains(&hex(&victim.id)), "block id lost: {msg}");
        assert!(msg.contains("offset 2048"), "object offset lost: {msg}");
        // the named-object read path reports the same way
        s.put("named", &data).unwrap();
        assert!(s.get("named").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corrupt_manifest_bytes_rejected_against_id() {
        let (s, dir) = store();
        let (id, _) = s.publish(b"manifest corruption test").unwrap();
        let path = dir.join("manifests").join(format!("{}.mf", id.hex()));
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, bytes).unwrap();
        let err = s.manifest(&id).unwrap_err();
        assert!(err.to_string().contains("hash to their id"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn concurrent_publish_of_identical_content_dedupes() {
        let (s, dir) = store();
        let s = std::sync::Arc::new(s.with_block_size(1024));
        let data: Vec<u8> = (0..8192).map(|i| (i % 239) as u8).collect();
        let ids: Vec<ManifestId> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let s = s.clone();
                    let data = data.clone();
                    scope.spawn(move || s.publish(&data).unwrap().0)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(ids.windows(2).all(|w| w[0] == w[1]), "publishers disagreed on id");
        // 8 distinct blocks, one manifest — no duplicate or leftover temp files
        let block_files: Vec<_> = std::fs::read_dir(dir.join("blocks"))
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert_eq!(block_files.len(), 8, "{block_files:?}");
        assert!(
            block_files.iter().all(|p| p.extension().unwrap() == "blk"),
            "leftover temp files: {block_files:?}"
        );
        let mut obj = s.open_object(&ids[0]).unwrap();
        assert_eq!(obj.read_at(0, data.len()).unwrap(), data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn identical_blocks_dedupe() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data = vec![42u8; 4096]; // 4 identical blocks
        s.put("dup", &data).unwrap();
        let blocks = std::fs::read_dir(dir.join("blocks")).unwrap().count();
        assert_eq!(blocks, 1, "all-same blocks stored once");
        assert_eq!(s.get("dup").unwrap(), data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_and_exists_and_delete() {
        let (s, dir) = store();
        s.put("a", b"1").unwrap();
        s.put("b", b"2").unwrap();
        assert_eq!(s.list().unwrap(), vec!["a", "b"]);
        assert!(s.exists("a"));
        s.delete("a").unwrap();
        assert!(!s.exists("a"));
        assert_eq!(s.list().unwrap(), vec!["b"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn path_traversal_rejected() {
        let (s, dir) = store();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.put("a/b", b"x").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn block_chunk_store_reads_across_boundaries() {
        let data: Vec<u8> = (0..4000).map(|i| (i % 251) as u8).collect();
        let blocks: Vec<Arc<Vec<u8>>> =
            data.chunks(1000).map(|c| Arc::new(c.to_vec())).collect();
        let mut store = BlockChunkStore::new(blocks);
        assert_eq!(store.len(), 4000);
        assert_eq!(store.backend(), "blocks");
        // read spanning two boundaries
        assert_eq!(store.read_at(900, 2200).unwrap(), &data[900..3100]);
        assert_eq!(store.read_at(0, 4000).unwrap(), data);
        assert_eq!(store.read_at(3999, 1).unwrap(), &data[3999..]);
        assert!(store.read_at(3999, 2).is_err());
        assert!(store.read_at(u64::MAX, 2).is_err(), "offset wrap must not panic");
        assert!(store.append(b"x").is_err(), "read-only");
        // single-arc fast path
        let mut one = BlockChunkStore::from_arc(Arc::new(data.clone()));
        assert_eq!(one.read_at(10, 100).unwrap(), &data[10..110]);
    }

    #[test]
    fn manifest_codec_roundtrips_and_validates() {
        let data: Vec<u8> = (0..2500).map(|i| (i % 7) as u8).collect();
        let m = Manifest::describe(&data, 1000);
        assert_eq!(m.blocks.len(), 3);
        assert_eq!(m.block_offset(2), 2000);
        let back = Manifest::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.id(), m.id());
        // total_len mismatch rejected
        let mut bad = m.clone();
        bad.total_len += 1;
        assert!(Manifest::decode(&bad.encode()).is_err());
        // empty manifest ok
        let empty = Manifest::describe(&[], 1000);
        assert_eq!(Manifest::decode(&empty.encode()).unwrap(), empty);
    }
}
