//! DFS-lite — the platform's HDFS stand-in (paper Fig 3's storage tier).
//!
//! A [`BlockStore`] is a directory of content-addressed, hash-verified
//! blocks plus named manifests mapping a logical path to its block list.
//! Blocks are addressed by SHA-256 — NOT CRC32: bag records embed their
//! own CRC32, and `CRC(m ‖ CRC(m))` is a constant residue, so distinct
//! bags can share a whole-file CRC32 (a real collision our integration
//! suite caught). A cryptographic hash makes dedupe sound.
//! It gives the engine the two HDFS behaviours the paper relies on:
//! durable binary outputs (`RDD[Bytes] → HDFS`) and chunked re-reads, with
//! corruption detection on every read. Replication across machines is out
//! of scope (single-box testbed); the API is shaped so a replicated
//! implementation could slot in.

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::path::{Path, PathBuf};

/// Content address of a block: SHA-256 digest (from `util::sha256`; the
/// offline crate set has no `sha2`).
fn block_id(data: &[u8]) -> [u8; 32] {
    crate::util::sha256::digest(data)
}

fn hex(id: &[u8; 32]) -> String {
    id.iter().map(|b| format!("{b:02x}")).collect()
}

/// Default block size (4 MiB, HDFS-small because our testbed is small).
pub const DEFAULT_BLOCK_SIZE: usize = 4 * 1024 * 1024;

/// Content-addressed block store with named manifests.
pub struct BlockStore {
    root: PathBuf,
    block_size: usize,
}

impl BlockStore {
    /// Open (or create) a store rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(root.join("blocks"))?;
        std::fs::create_dir_all(root.join("manifests"))?;
        Ok(Self { root, block_size: DEFAULT_BLOCK_SIZE })
    }

    /// Override the content-split block size (min 1 KiB); builder-style.
    pub fn with_block_size(mut self, n: usize) -> Self {
        self.block_size = n.max(1024);
        self
    }

    fn block_path(&self, id: &[u8; 32]) -> PathBuf {
        self.root.join("blocks").join(format!("{}.blk", hex(id)))
    }

    fn manifest_path(&self, name: &str) -> Result<PathBuf> {
        if name.is_empty() || name.contains('/') || name.contains("..") {
            return Err(Error::Storage(format!("bad object name '{name}'")));
        }
        Ok(self.root.join("manifests").join(format!("{name}.mf")))
    }

    /// Store `data` under `name`, splitting into CRC-tagged blocks.
    /// Blocks are content-addressed by CRC, so identical chunks dedupe.
    pub fn put(&self, name: &str, data: &[u8]) -> Result<()> {
        let mut manifest = ByteWriter::new();
        let chunks: Vec<&[u8]> = if data.is_empty() {
            vec![]
        } else {
            data.chunks(self.block_size).collect()
        };
        manifest.put_varint(chunks.len() as u64);
        manifest.put_u64(data.len() as u64);
        for chunk in chunks {
            let id = block_id(chunk);
            let path = self.block_path(&id);
            if !path.exists() {
                std::fs::write(&path, chunk)?;
            }
            manifest.put_raw(&id);
            manifest.put_u32(chunk.len() as u32);
        }
        std::fs::write(self.manifest_path(name)?, manifest.into_vec())?;
        Ok(())
    }

    /// Fetch an object, verifying every block's CRC.
    pub fn get(&self, name: &str) -> Result<Vec<u8>> {
        let mf = std::fs::read(self.manifest_path(name)?)
            .map_err(|e| Error::Storage(format!("object '{name}': {e}")))?;
        let mut r = ByteReader::new(&mf);
        let n_blocks = r.get_varint()? as usize;
        let total = r.get_u64()? as usize;
        let mut out = Vec::with_capacity(total);
        for _ in 0..n_blocks {
            let id: [u8; 32] = r.get_raw(32)?.try_into().unwrap();
            let len = r.get_u32()? as usize;
            let block = std::fs::read(self.block_path(&id))
                .map_err(|e| Error::Storage(format!("block {}: {e}", hex(&id))))?;
            if block.len() != len {
                return Err(Error::Storage(format!(
                    "block {} length {} != manifest {len}",
                    hex(&id),
                    block.len()
                )));
            }
            if block_id(&block) != id {
                return Err(Error::Storage(format!("block {} hash mismatch", hex(&id))));
            }
            out.extend_from_slice(&block);
        }
        if out.len() != total {
            return Err(Error::Storage(format!(
                "object '{name}' reassembled to {} bytes, manifest said {total}",
                out.len()
            )));
        }
        Ok(out)
    }

    /// List stored object names.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for e in std::fs::read_dir(self.root.join("manifests"))? {
            let p = e?.path();
            if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                if p.extension().map(|x| x == "mf").unwrap_or(false) {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// True when an object named `name` exists in the store.
    pub fn exists(&self, name: &str) -> bool {
        self.manifest_path(name).map(|p| p.exists()).unwrap_or(false)
    }

    /// Delete an object's manifest (blocks are left for GC; shared blocks
    /// may be referenced by other manifests).
    pub fn delete(&self, name: &str) -> Result<()> {
        std::fs::remove_file(self.manifest_path(name)?)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (BlockStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "av_simd_test_store_{}_{:x}",
            std::process::id(),
            crate::util::now_nanos()
        ));
        (BlockStore::open(&dir).unwrap(), dir)
    }

    #[test]
    fn put_get_roundtrip_multiblock() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data: Vec<u8> = (0..10_000).map(|i| (i % 251) as u8).collect();
        s.put("drive_001", &data).unwrap();
        assert_eq!(s.get("drive_001").unwrap(), data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn empty_object_ok() {
        let (s, dir) = store();
        s.put("empty", &[]).unwrap();
        assert!(s.get("empty").unwrap().is_empty());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data = vec![7u8; 3000];
        s.put("obj", &data).unwrap();
        // corrupt one block on disk
        let block = std::fs::read_dir(dir.join("blocks"))
            .unwrap()
            .next()
            .unwrap()
            .unwrap()
            .path();
        let mut b = std::fs::read(&block).unwrap();
        b[0] ^= 0xff;
        std::fs::write(&block, b).unwrap();
        assert!(matches!(s.get("obj"), Err(Error::Storage(_))));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn identical_blocks_dedupe() {
        let (s, dir) = store();
        let s = s.with_block_size(1024);
        let data = vec![42u8; 4096]; // 4 identical blocks
        s.put("dup", &data).unwrap();
        let blocks = std::fs::read_dir(dir.join("blocks")).unwrap().count();
        assert_eq!(blocks, 1, "all-same blocks stored once");
        assert_eq!(s.get("dup").unwrap(), data);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn list_and_exists_and_delete() {
        let (s, dir) = store();
        s.put("a", b"1").unwrap();
        s.put("b", b"2").unwrap();
        assert_eq!(s.list().unwrap(), vec!["a", "b"]);
        assert!(s.exists("a"));
        s.delete("a").unwrap();
        assert!(!s.exists("a"));
        assert_eq!(s.list().unwrap(), vec!["b"]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn path_traversal_rejected() {
        let (s, dir) = store();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.put("a/b", b"x").is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
