//! Per-pixel segmentation through the AOT segmenter artifact — the
//! paper's §2.3 "deep-learning based segmentation tasks" workload.

use crate::error::Result;
use crate::msg::Image;
use crate::perception::classify::{pack_image, BATCH};
use crate::runtime::{thread_runtime, CompiledModel};
use std::cell::RefCell;
use std::rc::Rc;

/// Segmentation label set (must match `model.py::SEG_CLASSES` order).
pub const SEG_CLASSES: [&str; 4] = ["road", "vehicle", "pedestrian", "background"];
const SIZE: usize = 32;

/// Segmentation result: per-pixel class map + class pixel histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct SegResult {
    /// 32*32 class indices, row-major.
    pub class_map: Vec<u8>,
    /// Pixel counts per class.
    pub histogram: [u32; 4],
}

/// Batched segmenter.
///
/// Like [`crate::perception::Classifier`], the packed-tensor and logits
/// staging buffers live in the segmenter (interior mutability) and are
/// reused across every call instead of reallocating per frame.
pub struct Segmenter {
    b1: Rc<CompiledModel>,
    b8: Rc<CompiledModel>,
    input: RefCell<Vec<f32>>,
    logits: RefCell<Vec<f32>>,
}

/// Per-pixel logits (`[32*32*4]`) for one frame → class map + histogram.
fn interpret_seg(logits: &[f32]) -> SegResult {
    let mut class_map = Vec::with_capacity(SIZE * SIZE);
    let mut histogram = [0u32; 4];
    for px in logits.chunks_exact(4) {
        let mut best = 0u8;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in px.iter().enumerate() {
            if v > best_v {
                best = i as u8;
                best_v = v;
            }
        }
        histogram[best as usize] += 1;
        class_map.push(best);
    }
    SegResult { class_map, histogram }
}

impl Segmenter {
    /// Load the segmenter artifacts from `artifact_dir`.
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let rt = thread_runtime(artifact_dir)?;
        Ok(Self {
            b1: rt.model("segmenter_b1")?,
            b8: rt.model("segmenter_b8")?,
            input: RefCell::new(Vec::new()),
            logits: RefCell::new(Vec::new()),
        })
    }

    /// Segment one image (resized to 32×32).
    pub fn segment(&self, img: &Image) -> Result<SegResult> {
        Ok(self.segment_batch(std::slice::from_ref(img))?.remove(0))
    }

    /// Segment a batch of images: the batch-8 artifact takes full
    /// groups, batch-1 the ragged tail. Results are bit-identical for
    /// every grouping of the same frames — `segmenter_b8` is seeded
    /// from the same family name as `segmenter_b1`, so batch row *i*
    /// computes exactly the single-frame kernel on frame *i* (asserted
    /// by the property suite).
    pub fn segment_batch(&self, images: &[Image]) -> Result<Vec<SegResult>> {
        const ROW: usize = SIZE * SIZE * 4;
        let mut out = Vec::with_capacity(images.len());
        let mut input = self.input.borrow_mut();
        let mut logits = self.logits.borrow_mut();
        let mut i = 0;
        while i + BATCH <= images.len() {
            input.clear();
            for img in &images[i..i + BATCH] {
                pack_image(img, &mut input)?;
            }
            self.b8.run_f32_into(&input, &mut logits)?;
            for b in 0..BATCH {
                out.push(interpret_seg(&logits[b * ROW..(b + 1) * ROW]));
            }
            i += BATCH;
        }
        for img in &images[i..] {
            input.clear();
            pack_image(img, &mut input)?;
            self.b1.run_f32_into(&input, &mut logits)?;
            out.push(interpret_seg(&logits));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    }

    #[test]
    fn segment_produces_full_map() {
        let s = Segmenter::load(&artifact_dir()).unwrap();
        let res = s.segment(&Image::synthetic(32, 32, 3)).unwrap();
        assert_eq!(res.class_map.len(), 32 * 32);
        assert_eq!(res.histogram.iter().sum::<u32>(), 1024);
        assert!(res.class_map.iter().all(|&c| c < 4));
    }

    #[test]
    fn histogram_matches_map() {
        let s = Segmenter::load(&artifact_dir()).unwrap();
        let res = s.segment(&Image::synthetic(64, 64, 8)).unwrap();
        let mut hist = [0u32; 4];
        for &c in &res.class_map {
            hist[c as usize] += 1;
        }
        assert_eq!(hist, res.histogram);
    }

    #[test]
    fn batch_path_matches_single_path_exactly() {
        let s = Segmenter::load(&artifact_dir()).unwrap();
        for n in [1usize, 3, 8, 11] {
            let imgs: Vec<Image> =
                (0..n).map(|i| Image::synthetic(32, 32, i as u64)).collect();
            let batched = s.segment_batch(&imgs).unwrap();
            assert_eq!(batched.len(), n);
            for (i, img) in imgs.iter().enumerate() {
                let single = s.segment(img).unwrap();
                assert_eq!(single, batched[i], "n={n} frame {i}");
            }
        }
    }
}
