//! Per-pixel segmentation through the AOT segmenter artifact — the
//! paper's §2.3 "deep-learning based segmentation tasks" workload.

use crate::error::Result;
use crate::msg::Image;
use crate::perception::classify::pack_image;
use crate::runtime::{thread_runtime, CompiledModel};
use std::rc::Rc;

/// Segmentation label set (must match `model.py::SEG_CLASSES` order).
pub const SEG_CLASSES: [&str; 4] = ["road", "vehicle", "pedestrian", "background"];
const SIZE: usize = 32;

/// Segmentation result: per-pixel class map + class pixel histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct SegResult {
    /// 32*32 class indices, row-major.
    pub class_map: Vec<u8>,
    /// Pixel counts per class.
    pub histogram: [u32; 4],
}

/// Batched segmenter.
pub struct Segmenter {
    b1: Rc<CompiledModel>,
}

impl Segmenter {
    /// Load the segmenter artifact from `artifact_dir`.
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let rt = thread_runtime(artifact_dir)?;
        Ok(Self { b1: rt.model("segmenter_b1")? })
    }

    /// Segment one image (resized to 32×32).
    pub fn segment(&self, img: &Image) -> Result<SegResult> {
        let mut input = Vec::with_capacity(SIZE * SIZE * 3);
        pack_image(img, &mut input)?;
        let logits = self.b1.run_f32(&input)?; // [32*32*4]
        let mut class_map = Vec::with_capacity(SIZE * SIZE);
        let mut histogram = [0u32; 4];
        for px in logits.chunks_exact(4) {
            let mut best = 0u8;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in px.iter().enumerate() {
                if v > best_v {
                    best = i as u8;
                    best_v = v;
                }
            }
            histogram[best as usize] += 1;
            class_map.push(best);
        }
        Ok(SegResult { class_map, histogram })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    }

    #[test]
    fn segment_produces_full_map() {
        let s = Segmenter::load(&artifact_dir()).unwrap();
        let res = s.segment(&Image::synthetic(32, 32, 3)).unwrap();
        assert_eq!(res.class_map.len(), 32 * 32);
        assert_eq!(res.histogram.iter().sum::<u32>(), 1024);
        assert!(res.class_map.iter().all(|&c| c < 4));
    }

    #[test]
    fn histogram_matches_map() {
        let s = Segmenter::load(&artifact_dir()).unwrap();
        let res = s.segment(&Image::synthetic(64, 64, 8)).unwrap();
        let mut hist = [0u32; 4];
        for &c in &res.class_map {
            hist[c as usize] += 1;
        }
        assert_eq!(hist, res.histogram);
    }
}
