//! Perception applications — the simulation workloads the Spark driver
//! launches (paper Fig 3): image recognition, segmentation, LiDAR
//! localization. All deep-learning compute executes AOT-compiled
//! JAX/Pallas artifacts through PJRT; Python never runs here.
//!
//! [`register_perception_ops`] / [`register_perception_logics`] plug
//! these into the engine's operator registry and the BinPipedRDD child.

pub mod classify;
pub mod lidar_odom;
pub mod segment;

pub use classify::{Classifier, ClassResult, BATCH, CLASSES};
pub use lidar_odom::{
    descriptor_similarity, icp_2d, icp_uses_grid, scan_descriptor, Transform2D,
    GRID_MIN_POINTS,
};
pub use segment::{SegResult, Segmenter, SEG_CLASSES};

use crate::engine::OpRegistry;
use crate::error::Result;
use crate::msg::{Image, Message, PointCloud};
use crate::pipe::{LogicRegistry, PipeItem};
use std::cell::RefCell;

thread_local! {
    static TL_CLASSIFIER: RefCell<Option<Classifier>> = const { RefCell::new(None) };
    static TL_SEGMENTER: RefCell<Option<Segmenter>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's (lazily-created) classifier.
pub fn with_classifier<T>(
    artifact_dir: &str,
    f: impl FnOnce(&Classifier) -> Result<T>,
) -> Result<T> {
    TL_CLASSIFIER.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(Classifier::load(artifact_dir)?);
        }
        f(slot.as_ref().unwrap())
    })
}

/// Run `f` with this thread's (lazily-created) segmenter.
pub fn with_segmenter<T>(
    artifact_dir: &str,
    f: impl FnOnce(&Segmenter) -> Result<T>,
) -> Result<T> {
    TL_SEGMENTER.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(Segmenter::load(artifact_dir)?);
        }
        f(slot.as_ref().unwrap())
    })
}

/// Engine operators backed by the PJRT runtime. Registered by default in
/// `SimContext` builds (and in worker `main`).
pub fn register_perception_ops(reg: &OpRegistry) {
    // Image records in → DetectionArray records out (batched inside).
    reg.register("classify_images", |ctx, _p, records| {
        let images: Result<Vec<Image>> = records.iter().map(|r| Image::decode(r)).collect();
        let images = images?;
        with_classifier(&ctx.artifact_dir, |c| {
            let results = c.classify(&images)?;
            Ok(images
                .iter()
                .zip(results)
                .map(|(img, r)| {
                    crate::msg::DetectionArray {
                        header: img.header.clone(),
                        detections: vec![crate::msg::Detection {
                            class_id: r.class_id,
                            label: r.label.to_string(),
                            score: r.score,
                            bbox: [0.0, 0.0, img.width as f32, img.height as f32],
                        }],
                    }
                    .encode()
                })
                .collect())
        })
    });

    // Image records → per-image dominant segmentation class (u8 record).
    reg.register("segment_images", |ctx, _p, records| {
        let images: Result<Vec<Image>> = records.iter().map(|r| Image::decode(r)).collect();
        let images = images?;
        with_segmenter(&ctx.artifact_dir, |s| {
            Ok(s.segment_batch(&images)?
                .into_iter()
                .map(|seg| {
                    let dominant = (0..4u8)
                        .max_by_key(|&c| seg.histogram[c as usize])
                        .unwrap();
                    vec![dominant]
                })
                .collect())
        })
    });

    // PointCloud records → 64-f32 descriptor records.
    reg.register("lidar_descriptors", |ctx, _p, records| {
        records
            .iter()
            .map(|r| {
                let pc = PointCloud::decode(r)?;
                let d = scan_descriptor(&ctx.artifact_dir, &pc)?;
                let mut w = crate::util::bytes::ByteWriter::new();
                w.put_f32_slice(&d);
                Ok(w.into_vec())
            })
            .collect()
    });
}

/// BinPipedRDD user logics backed by PJRT (run inside the child process;
/// artifact dir comes from `AV_SIMD_ARTIFACTS`, set by the parent op).
pub fn register_perception_logics(reg: &mut LogicRegistry) {
    // The paper's "detecting pedestrians given the binary sensor
    // readings" example: images in, label strings out.
    reg.register("classify", |items| {
        let dir = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let mut images = Vec::new();
        for item in &items {
            match item {
                PipeItem::Bytes(b) => images.push(Image::decode(b)?),
                PipeItem::File { content, .. } => images.push(Image::decode(content)?),
                _ => {}
            }
        }
        with_classifier(&dir, |c| {
            let results = c.classify(&images)?;
            Ok(results
                .into_iter()
                .map(|r| PipeItem::Str(format!("{}:{:.3}", r.label, r.score)))
                .collect())
        })
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{OpCall, TaskCtx};

    fn artifact_dir() -> String {
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    }

    #[test]
    fn classify_op_end_to_end() {
        let reg = OpRegistry::with_builtins();
        register_perception_ops(&reg);
        let ctx = TaskCtx::new(0, artifact_dir());
        let records: Vec<Vec<u8>> =
            (0..5).map(|i| Image::synthetic(32, 32, i).encode()).collect();
        let out = reg
            .apply_chain(&ctx, &[OpCall::new("classify_images", vec![])], records)
            .unwrap();
        assert_eq!(out.len(), 5);
        for r in out {
            let det = crate::msg::DetectionArray::decode(&r).unwrap();
            assert_eq!(det.detections.len(), 1);
        }
    }

    #[test]
    fn segment_op_end_to_end() {
        let reg = OpRegistry::with_builtins();
        register_perception_ops(&reg);
        let ctx = TaskCtx::new(0, artifact_dir());
        let records = vec![Image::synthetic(32, 32, 0).encode()];
        let out = reg
            .apply_chain(&ctx, &[OpCall::new("segment_images", vec![])], records)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0][0] < 4);
    }

    #[test]
    fn lidar_op_end_to_end() {
        let reg = OpRegistry::with_builtins();
        register_perception_ops(&reg);
        let ctx = TaskCtx::new(0, artifact_dir());
        let records = vec![PointCloud::synthetic(256, 1).encode()];
        let out = reg
            .apply_chain(&ctx, &[OpCall::new("lidar_descriptors", vec![])], records)
            .unwrap();
        let mut r = crate::util::bytes::ByteReader::new(&out[0]);
        assert_eq!(r.get_f32_vec().unwrap().len(), 64);
    }

    #[test]
    fn classify_logic_in_process() {
        let mut reg = LogicRegistry::with_builtins();
        register_perception_logics(&mut reg);
        std::env::set_var("AV_SIMD_ARTIFACTS", artifact_dir());
        let f = reg.get("classify").unwrap();
        let out = f(vec![PipeItem::Bytes(Image::synthetic(32, 32, 2).encode())]).unwrap();
        assert_eq!(out.len(), 1);
        match &out[0] {
            PipeItem::Str(s) => assert!(s.contains(':'), "{s}"),
            other => panic!("unexpected {other:?}"),
        }
    }
}
