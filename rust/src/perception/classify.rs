//! Image recognition through the AOT classifier artifact — the paper's
//! "object recognition algorithms that consume image data" (Fig 3) and
//! the workload of the §2.3 compute-demand analysis and Fig 7 scalability
//! experiment.

use crate::error::{Error, Result};
use crate::msg::{Detection, DetectionArray, Image};
use crate::runtime::{thread_runtime, CompiledModel};
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::OnceLock;

/// Label set — must match `python/compile/model.py::CLASSES`.
pub const CLASSES: [&str; 8] = [
    "vehicle",
    "pedestrian",
    "cyclist",
    "traffic_light",
    "sign",
    "barrier",
    "road",
    "background",
];

/// Model input side (images are resized to this).
pub const INPUT_SIZE: usize = 32;

/// Frames packed per batched runtime call (matches the `_b8` artifacts).
pub const BATCH: usize = 8;

/// Batched image classifier over the PJRT runtime (thread-local).
///
/// The packed-tensor and logits staging buffers live in the classifier
/// (interior mutability) and are reused across every call — a replay
/// slice classifies thousands of frames through one pair of
/// allocations instead of one `Vec<f32>` per frame.
pub struct Classifier {
    b1: Rc<CompiledModel>,
    b8: Rc<CompiledModel>,
    input: RefCell<Vec<f32>>,
    logits: RefCell<Vec<f32>>,
}

/// One classification result.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassResult {
    /// Predicted class index into [`CLASSES`].
    pub class_id: u32,
    /// Human-readable class label.
    pub label: &'static str,
    /// Softmax score of the predicted class.
    pub score: f32,
    /// Raw per-class logits.
    pub logits: Vec<f32>,
}

impl Classifier {
    /// Load from this thread's runtime rooted at `artifact_dir`.
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let rt = thread_runtime(artifact_dir)?;
        Ok(Self {
            b1: rt.model("classifier_b1")?,
            b8: rt.model("classifier_b8")?,
            input: RefCell::new(Vec::new()),
            logits: RefCell::new(Vec::new()),
        })
    }

    /// Classify a batch of images (any sizes; resized to 32×32).
    /// Uses the batch-8 artifact for full groups and batch-1 for the
    /// tail. Results are bit-identical for every grouping of the same
    /// frames: the runtime seeds batch variants from the family name,
    /// so `classifier_b8` row *i* computes exactly `classifier_b1` on
    /// row *i* (asserted by the property suite).
    pub fn classify(&self, images: &[Image]) -> Result<Vec<ClassResult>> {
        let mut out = Vec::with_capacity(images.len());
        let mut input = self.input.borrow_mut();
        let mut logits = self.logits.borrow_mut();
        let mut i = 0;
        while i + BATCH <= images.len() {
            input.clear();
            for img in &images[i..i + BATCH] {
                pack_image(img, &mut input)?;
            }
            self.b8.run_f32_into(&input, &mut logits)?;
            for b in 0..BATCH {
                out.push(interpret_logits(&logits[b * 8..(b + 1) * 8]));
            }
            i += BATCH;
        }
        for img in &images[i..] {
            input.clear();
            pack_image(img, &mut input)?;
            self.b1.run_f32_into(&input, &mut logits)?;
            out.push(interpret_logits(&logits));
        }
        Ok(out)
    }

    /// Classify and wrap as a bus message.
    pub fn detect(&self, img: &Image) -> Result<DetectionArray> {
        let r = self.classify(std::slice::from_ref(img))?.remove(0);
        Ok(DetectionArray {
            header: img.header.clone(),
            detections: vec![Detection {
                class_id: r.class_id,
                label: r.label.to_string(),
                score: r.score,
                bbox: [0.0, 0.0, img.width as f32, img.height as f32],
            }],
        })
    }
}

/// `v / 255.0` for every byte value, precomputed once. The resample
/// path historically divided per channel; the table stores exactly
/// those quotients, so packed tensors are byte-identical to the
/// division loop while the hot path does table loads only. (The
/// model-native fast path multiplies by `1.0 / 255.0` instead — also
/// historical; each path keeps its own rounding so outputs never move.)
fn norm_lut() -> &'static [f32; 256] {
    static LUT: OnceLock<[f32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0f32; 256];
        for (b, v) in t.iter_mut().enumerate() {
            *v = b as f32 / 255.0;
        }
        t
    })
}

/// Resize (nearest-neighbour) + normalize an image into `out` as NHWC
/// f32 (appends `32*32*3` values; callers reuse `out` across frames by
/// clearing it between packs). The resample loop walks one source-row
/// slice per output row — per-pixel indexing into the full frame (a
/// bounds check per channel) is gone, and normalization is a table
/// load. Output bytes are identical to the original per-pixel loop.
pub fn pack_image(img: &Image, out: &mut Vec<f32>) -> Result<()> {
    img.validate()?;
    let (w, h) = (img.width as usize, img.height as usize);
    if w == 0 || h == 0 {
        return Err(Error::Runtime("cannot classify empty image".into()));
    }
    let bpp = img.format.bytes_per_pixel();
    // Fast path (perf pass): model-native RGB frames skip the resample
    // loop — one bulk normalize instead of 32*32 bounds-checked pushes.
    if w == INPUT_SIZE && h == INPUT_SIZE && bpp == 3 {
        out.extend(img.data.iter().map(|&b| b as f32 * (1.0 / 255.0)));
        return Ok(());
    }
    let lut = norm_lut();
    out.reserve(INPUT_SIZE * INPUT_SIZE * 3);
    for y in 0..INPUT_SIZE {
        let sy = y * h / INPUT_SIZE;
        // one bounds-checked slice per output row; `validate()` above
        // guarantees `data.len() == w * h * bpp`, so the row exists
        let row = &img.data[sy * w * bpp..(sy + 1) * w * bpp];
        for x in 0..INPUT_SIZE {
            let sx = x * w / INPUT_SIZE;
            match bpp {
                3 => {
                    let px = &row[sx * 3..sx * 3 + 3];
                    out.push(lut[px[0] as usize]);
                    out.push(lut[px[1] as usize]);
                    out.push(lut[px[2] as usize]);
                }
                _ => {
                    let v = lut[row[sx * bpp] as usize];
                    out.extend_from_slice(&[v, v, v]);
                }
            }
        }
    }
    Ok(())
}

fn interpret_logits(logits: &[f32]) -> ClassResult {
    let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    // softmax score of the argmax
    let m = best_v;
    let denom: f32 = logits.iter().map(|&v| (v - m).exp()).sum();
    ClassResult {
        class_id: best as u32,
        label: CLASSES[best],
        score: 1.0 / denom,
        logits: logits.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    }

    #[test]
    fn classify_batch_sizes() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        for n in [1usize, 3, 8, 11] {
            let imgs: Vec<Image> =
                (0..n).map(|i| Image::synthetic(32, 32, i as u64)).collect();
            let res = c.classify(&imgs).unwrap();
            assert_eq!(res.len(), n);
            for r in &res {
                assert!((r.class_id as usize) < CLASSES.len());
                assert!(r.score > 0.0 && r.score <= 1.0);
                assert_eq!(r.logits.len(), 8);
            }
        }
    }

    #[test]
    fn batch_path_matches_single_path() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let imgs: Vec<Image> = (0..8).map(|i| Image::synthetic(32, 32, i)).collect();
        let batched = c.classify(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            let single = c.classify(std::slice::from_ref(img)).unwrap().remove(0);
            assert_eq!(single.class_id, batched[i].class_id, "image {i}");
            for (a, b) in single.logits.iter().zip(&batched[i].logits) {
                assert!((a - b).abs() < 1e-4, "image {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn pack_image_reused_scratch_yields_identical_bytes() {
        // Satellite: the staging buffer is reused across a slice — packing
        // the same frame repeatedly through one scratch Vec must produce
        // bitwise-identical tensors (both resample and native paths).
        for (w, h) in [(64u32, 48u32), (32, 32), (17, 93)] {
            let img = Image::synthetic(w, h, 7);
            let mut scratch = Vec::new();
            pack_image(&img, &mut scratch).unwrap();
            let first: Vec<u32> = scratch.iter().map(|v| v.to_bits()).collect();
            for _ in 0..3 {
                scratch.clear();
                pack_image(&img, &mut scratch).unwrap();
                let again: Vec<u32> = scratch.iter().map(|v| v.to_bits()).collect();
                assert_eq!(first, again, "{w}x{h}");
            }
        }
    }

    #[test]
    fn resizes_arbitrary_input() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(64, 48, 5);
        let res = c.classify(std::slice::from_ref(&img)).unwrap();
        assert_eq!(res.len(), 1);
    }

    #[test]
    fn detect_wraps_as_message() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(32, 32, 1);
        let det = c.detect(&img).unwrap();
        assert_eq!(det.detections.len(), 1);
        assert_eq!(det.header, img.header);
    }

    #[test]
    fn deterministic_results() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(32, 32, 9);
        let a = c.classify(std::slice::from_ref(&img)).unwrap();
        let b = c.classify(std::slice::from_ref(&img)).unwrap();
        assert_eq!(a, b);
    }
}
