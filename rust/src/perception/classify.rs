//! Image recognition through the AOT classifier artifact — the paper's
//! "object recognition algorithms that consume image data" (Fig 3) and
//! the workload of the §2.3 compute-demand analysis and Fig 7 scalability
//! experiment.

use crate::error::{Error, Result};
use crate::msg::{Detection, DetectionArray, Image};
use crate::runtime::{thread_runtime, CompiledModel};
use std::rc::Rc;

/// Label set — must match `python/compile/model.py::CLASSES`.
pub const CLASSES: [&str; 8] = [
    "vehicle",
    "pedestrian",
    "cyclist",
    "traffic_light",
    "sign",
    "barrier",
    "road",
    "background",
];

/// Model input side (images are resized to this).
pub const INPUT_SIZE: usize = 32;

/// Batched image classifier over the PJRT runtime (thread-local).
pub struct Classifier {
    b1: Rc<CompiledModel>,
    b8: Rc<CompiledModel>,
}

/// One classification result.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassResult {
    /// Predicted class index into [`CLASSES`].
    pub class_id: u32,
    /// Human-readable class label.
    pub label: &'static str,
    /// Softmax score of the predicted class.
    pub score: f32,
    /// Raw per-class logits.
    pub logits: Vec<f32>,
}

impl Classifier {
    /// Load from this thread's runtime rooted at `artifact_dir`.
    pub fn load(artifact_dir: &str) -> Result<Self> {
        let rt = thread_runtime(artifact_dir)?;
        Ok(Self { b1: rt.model("classifier_b1")?, b8: rt.model("classifier_b8")? })
    }

    /// Classify a batch of images (any sizes; resized to 32×32).
    /// Uses the batch-8 artifact for full groups and batch-1 for the tail.
    pub fn classify(&self, images: &[Image]) -> Result<Vec<ClassResult>> {
        let mut out = Vec::with_capacity(images.len());
        let row = INPUT_SIZE * INPUT_SIZE * 3;
        let mut i = 0;
        while i + 8 <= images.len() {
            let mut input = Vec::with_capacity(8 * row);
            for img in &images[i..i + 8] {
                pack_image(img, &mut input)?;
            }
            let logits = self.b8.run_f32(&input)?;
            for b in 0..8 {
                out.push(interpret_logits(&logits[b * 8..(b + 1) * 8]));
            }
            i += 8;
        }
        for img in &images[i..] {
            let mut input = Vec::with_capacity(row);
            pack_image(img, &mut input)?;
            let logits = self.b1.run_f32(&input)?;
            out.push(interpret_logits(&logits));
        }
        Ok(out)
    }

    /// Classify and wrap as a bus message.
    pub fn detect(&self, img: &Image) -> Result<DetectionArray> {
        let r = self.classify(std::slice::from_ref(img))?.remove(0);
        Ok(DetectionArray {
            header: img.header.clone(),
            detections: vec![Detection {
                class_id: r.class_id,
                label: r.label.to_string(),
                score: r.score,
                bbox: [0.0, 0.0, img.width as f32, img.height as f32],
            }],
        })
    }
}

/// Resize (nearest-neighbour) + normalize an image into `out` as NHWC f32.
pub fn pack_image(img: &Image, out: &mut Vec<f32>) -> Result<()> {
    img.validate()?;
    let (w, h) = (img.width as usize, img.height as usize);
    if w == 0 || h == 0 {
        return Err(Error::Runtime("cannot classify empty image".into()));
    }
    let bpp = img.format.bytes_per_pixel();
    // Fast path (perf pass): model-native RGB frames skip the resample
    // loop — one bulk normalize instead of 32*32 bounds-checked pushes.
    if w == INPUT_SIZE && h == INPUT_SIZE && bpp == 3 {
        out.extend(img.data.iter().map(|&b| b as f32 * (1.0 / 255.0)));
        return Ok(());
    }
    for y in 0..INPUT_SIZE {
        let sy = y * h / INPUT_SIZE;
        for x in 0..INPUT_SIZE {
            let sx = x * w / INPUT_SIZE;
            let o = (sy * w + sx) * bpp;
            match bpp {
                3 => {
                    out.push(img.data[o] as f32 / 255.0);
                    out.push(img.data[o + 1] as f32 / 255.0);
                    out.push(img.data[o + 2] as f32 / 255.0);
                }
                _ => {
                    let v = img.data[o] as f32 / 255.0;
                    out.extend_from_slice(&[v, v, v]);
                }
            }
        }
    }
    Ok(())
}

fn interpret_logits(logits: &[f32]) -> ClassResult {
    let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    // softmax score of the argmax
    let m = best_v;
    let denom: f32 = logits.iter().map(|&v| (v - m).exp()).sum();
    ClassResult {
        class_id: best as u32,
        label: CLASSES[best],
        score: 1.0 / denom,
        logits: logits.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    }

    #[test]
    fn classify_batch_sizes() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        for n in [1usize, 3, 8, 11] {
            let imgs: Vec<Image> =
                (0..n).map(|i| Image::synthetic(32, 32, i as u64)).collect();
            let res = c.classify(&imgs).unwrap();
            assert_eq!(res.len(), n);
            for r in &res {
                assert!((r.class_id as usize) < CLASSES.len());
                assert!(r.score > 0.0 && r.score <= 1.0);
                assert_eq!(r.logits.len(), 8);
            }
        }
    }

    #[test]
    fn batch_path_matches_single_path() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let imgs: Vec<Image> = (0..8).map(|i| Image::synthetic(32, 32, i)).collect();
        let batched = c.classify(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            let single = c.classify(std::slice::from_ref(img)).unwrap().remove(0);
            assert_eq!(single.class_id, batched[i].class_id, "image {i}");
            for (a, b) in single.logits.iter().zip(&batched[i].logits) {
                assert!((a - b).abs() < 1e-4, "image {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn resizes_arbitrary_input() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(64, 48, 5);
        let res = c.classify(std::slice::from_ref(&img)).unwrap();
        assert_eq!(res.len(), 1);
    }

    #[test]
    fn detect_wraps_as_message() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(32, 32, 1);
        let det = c.detect(&img).unwrap();
        assert_eq!(det.detections.len(), 1);
        assert_eq!(det.header, img.header);
    }

    #[test]
    fn deterministic_results() {
        let c = Classifier::load(&artifact_dir()).unwrap();
        let img = Image::synthetic(32, 32, 9);
        let a = c.classify(std::slice::from_ref(&img)).unwrap();
        let b = c.classify(std::slice::from_ref(&img)).unwrap();
        assert_eq!(a, b);
    }
}
