//! LiDAR localization — the paper's "localization algorithms that
//! consume LiDAR raw data" (Fig 3).
//!
//! Two pieces: a pure-Rust planar ICP (point-to-point, used as the
//! odometry estimator in the playback pipeline) and a PJRT-backed scan
//! descriptor (PointNet-lite artifact) used for loop-closure-style scan
//! matching.
//!
//! Perf pass: correspondence search runs over a spatial grid built once
//! per destination cloud ([`CorrGrid`]) instead of an O(src×dst) scan
//! per iteration, and the alignment/cosine reductions use explicit
//! lane-chunked accumulators. The grid search is *exact* — it returns
//! the same correspondence index as the brute-force scan, ties broken
//! by lowest point index (property-tested); the pre-pass kernels are
//! kept as `_reference` bench baselines.

use crate::error::{Error, Result};
use crate::msg::PointCloud;
use crate::runtime::thread_runtime;

/// Planar rigid transform (dx, dy, dtheta).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Transform2D {
    /// Translation along x (m).
    pub dx: f64,
    /// Translation along y (m).
    pub dy: f64,
    /// Rotation (rad, CCW).
    pub dtheta: f64,
}

impl Transform2D {
    /// Apply the transform to a point.
    pub fn apply(&self, x: f64, y: f64) -> (f64, f64) {
        let (s, c) = self.dtheta.sin_cos();
        (c * x - s * y + self.dx, s * x + c * y + self.dy)
    }

    /// Compose: self ∘ other (apply other first).
    pub fn compose(&self, other: &Transform2D) -> Transform2D {
        let (s, c) = self.dtheta.sin_cos();
        Transform2D {
            dx: self.dx + c * other.dx - s * other.dy,
            dy: self.dy + s * other.dx + c * other.dy,
            dtheta: self.dtheta + other.dtheta,
        }
    }
}

/// Minimum destination-cloud size for the grid correspondence path;
/// below this the brute-force scan wins (grid build cost dominates).
pub const GRID_MIN_POINTS: usize = 32;

/// True when [`icp_2d`] uses the spatial-grid correspondence search for
/// a destination cloud of `dst_points` points (recorded as the `icp`
/// trace-span detail: `grid` vs `brute`).
pub fn icp_uses_grid(dst_points: usize) -> bool {
    dst_points >= GRID_MIN_POINTS
}

/// Spatial grid over a destination cloud for exact nearest-neighbour
/// queries. Cells are dense (row-major `Vec`, no hashing) and hold
/// point indices in ascending order; [`CorrGrid::nearest`] expands
/// rings of cells outward from the query cell and stops only once the
/// ring's lower distance bound *strictly* exceeds the best hit, so
/// every cell that could hold an equally-near point is visited and the
/// lowest-index tie wins — exactly the brute-force scan's semantics.
#[doc(hidden)]
pub struct CorrGrid<'a> {
    pts: &'a [(f64, f64)],
    cells: Vec<Vec<u32>>,
    nx: usize,
    ny: usize,
    min_x: f64,
    min_y: f64,
    cx: f64,
    cy: f64,
}

impl<'a> CorrGrid<'a> {
    /// Bucket `pts` (non-empty) into a grid sized for ~1 point/cell.
    pub fn build(pts: &'a [(f64, f64)]) -> Self {
        let (mut min_x, mut min_y) = (f64::INFINITY, f64::INFINITY);
        let (mut max_x, mut max_y) = (f64::NEG_INFINITY, f64::NEG_INFINITY);
        for &(x, y) in pts {
            min_x = min_x.min(x);
            min_y = min_y.min(y);
            max_x = max_x.max(x);
            max_y = max_y.max(y);
        }
        let w = (max_x - min_x).max(0.0);
        let h = (max_y - min_y).max(0.0);
        // target cell edge ≈ sqrt(area / n); degenerate extents (a
        // point or an axis-aligned line) collapse to a single row/col
        let cell = (w * h / pts.len() as f64).sqrt();
        let cell = if cell.is_finite() && cell > 1e-12 { cell } else { w.max(h).max(1.0) };
        let nx = (((w / cell).floor() as usize) + 1).clamp(1, 512);
        let ny = (((h / cell).floor() as usize) + 1).clamp(1, 512);
        let cx = if w > 0.0 { w / nx as f64 } else { 1.0 };
        let cy = if h > 0.0 { h / ny as f64 } else { 1.0 };
        let mut cells = vec![Vec::new(); nx * ny];
        for (i, &(x, y)) in pts.iter().enumerate() {
            let ix = (((x - min_x) / cx).floor() as i64).clamp(0, nx as i64 - 1) as usize;
            let iy = (((y - min_y) / cy).floor() as i64).clamp(0, ny as i64 - 1) as usize;
            cells[iy * nx + ix].push(i as u32); // ascending by construction
        }
        CorrGrid { pts, cells, nx, ny, min_x, min_y, cx, cy }
    }

    /// Exact nearest-neighbour index of `p` in the bucketed cloud
    /// (lowest index on distance ties). `p` may lie outside the grid's
    /// bounding box — the query cell is clamped, which only widens the
    /// ring bound.
    pub fn nearest(&self, p: (f64, f64)) -> usize {
        let qx = (((p.0 - self.min_x) / self.cx).floor() as i64).clamp(0, self.nx as i64 - 1);
        let qy = (((p.1 - self.min_y) / self.cy).floor() as i64).clamp(0, self.ny as i64 - 1);
        let (nxi, nyi) = (self.nx as i64, self.ny as i64);
        let min_cell = self.cx.min(self.cy);
        let mut best_idx = usize::MAX;
        let mut best_d2 = f64::INFINITY;
        let rmax = nxi.max(nyi);
        for r in 0..=rmax {
            if best_idx != usize::MAX {
                // any point in ring r is ≥ (r-1) whole cells away; stop
                // only on a STRICT bound so distance ties are still found
                let lb = (r - 1).max(0) as f64 * min_cell;
                if lb * lb > best_d2 {
                    break;
                }
            }
            let mut visit = |cxi: i64, cyi: i64| {
                if cxi < 0 || cyi < 0 || cxi >= nxi || cyi >= nyi {
                    return;
                }
                for &idx in &self.cells[cyi as usize * self.nx + cxi as usize] {
                    let d = d2(p, self.pts[idx as usize]);
                    let idx = idx as usize;
                    if d < best_d2 || (d == best_d2 && idx < best_idx) {
                        best_d2 = d;
                        best_idx = idx;
                    }
                }
            };
            if r == 0 {
                visit(qx, qy);
            } else {
                for x in (qx - r)..=(qx + r) {
                    visit(x, qy - r);
                    visit(x, qy + r);
                }
                for y in (qy - r + 1)..=(qy + r - 1) {
                    visit(qx - r, y);
                    visit(qx + r, y);
                }
            }
        }
        best_idx
    }
}

/// Brute-force lowest-index nearest neighbour — the small-cloud path
/// and the property-test baseline the grid must match exactly.
#[doc(hidden)]
pub fn brute_nearest(pts: &[(f64, f64)], p: (f64, f64)) -> usize {
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (i, &q) in pts.iter().enumerate() {
        let d = d2(p, q);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

fn tree4(v: [f64; 4]) -> f64 {
    (v[0] + v[1]) + (v[2] + v[3])
}

fn tree8(v: [f32; 8]) -> f32 {
    ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
}

/// 4-lane chunked centroid sums over correspondence pairs, returned as
/// means `(mx, my, qx, qy)`.
fn centroids(pairs: &[((f64, f64), (f64, f64))]) -> (f64, f64, f64, f64) {
    let (mut mx, mut my, mut qx, mut qy) = ([0f64; 4], [0f64; 4], [0f64; 4], [0f64; 4]);
    let mut it = pairs.chunks_exact(4);
    for ch in it.by_ref() {
        for (l, &((px, py), (dxp, dyp))) in ch.iter().enumerate() {
            mx[l] += px;
            my[l] += py;
            qx[l] += dxp;
            qy[l] += dyp;
        }
    }
    for &((px, py), (dxp, dyp)) in it.remainder() {
        mx[0] += px;
        my[0] += py;
        qx[0] += dxp;
        qy[0] += dyp;
    }
    let n = pairs.len() as f64;
    (tree4(mx) / n, tree4(my) / n, tree4(qx) / n, tree4(qy) / n)
}

/// 4-lane chunked cross-covariance terms `(sxx, sxy)` about the means.
fn cross_cov(
    pairs: &[((f64, f64), (f64, f64))],
    (mx, my, qx, qy): (f64, f64, f64, f64),
) -> (f64, f64) {
    let (mut sxx, mut sxy) = ([0f64; 4], [0f64; 4]);
    let mut it = pairs.chunks_exact(4);
    for ch in it.by_ref() {
        for (l, &((px, py), (dxp, dyp))) in ch.iter().enumerate() {
            let (ax, ay) = (px - mx, py - my);
            let (bx, by) = (dxp - qx, dyp - qy);
            sxx[l] += ax * bx + ay * by;
            sxy[l] += ax * by - ay * bx;
        }
    }
    for &((px, py), (dxp, dyp)) in it.remainder() {
        let (ax, ay) = (px - mx, py - my);
        let (bx, by) = (dxp - qx, dyp - qy);
        sxx[0] += ax * bx + ay * by;
        sxy[0] += ax * by - ay * bx;
    }
    (tree4(sxx), tree4(sxy))
}

fn clouds_to_xy(pc: &PointCloud) -> Vec<(f64, f64)> {
    (0..pc.num_points())
        .map(|i| {
            let (x, y, _, _) = pc.point(i);
            (x as f64, y as f64)
        })
        .collect()
}

/// Point-to-point ICP in the plane (z ignored). Returns the transform
/// that maps `src` onto `dst`.
///
/// Correspondences come from an exact spatial-grid search (built once
/// over `dst`, reused across iterations) when the destination cloud has
/// at least [`GRID_MIN_POINTS`] points, else from the brute scan — both
/// return identical indices, so the path choice never changes the
/// estimate. The alignment reductions use 4-lane chunked accumulators;
/// [`icp_2d_reference`] keeps the pre-pass sequential kernel.
pub fn icp_2d(src: &PointCloud, dst: &PointCloud, iterations: usize) -> Result<Transform2D> {
    if src.num_points() < 3 || dst.num_points() < 3 {
        return Err(Error::Sim("icp needs >= 3 points per scan".into()));
    }
    let dst_pts = clouds_to_xy(dst);
    let mut cur = clouds_to_xy(src);
    let grid =
        if icp_uses_grid(dst_pts.len()) { Some(CorrGrid::build(&dst_pts)) } else { None };
    let mut total = Transform2D::default();
    let mut pairs: Vec<((f64, f64), (f64, f64))> = Vec::with_capacity(cur.len());

    for _ in 0..iterations {
        pairs.clear();
        match &grid {
            Some(g) => pairs.extend(cur.iter().map(|&p| (p, dst_pts[g.nearest(p)]))),
            None => {
                pairs.extend(cur.iter().map(|&p| (p, dst_pts[brute_nearest(&dst_pts, p)])))
            }
        }
        // closed-form 2D rigid alignment (Umeyama / SVD-free for 2D)
        let means = centroids(&pairs);
        let (mx, my, qx, qy) = means;
        let (sxx, sxy) = cross_cov(&pairs, means);
        let theta = sxy.atan2(sxx);
        let (s, c) = theta.sin_cos();
        let step = Transform2D {
            dx: qx - (c * mx - s * my),
            dy: qy - (s * mx + c * my),
            dtheta: theta,
        };
        for p in &mut cur {
            *p = step.apply(p.0, p.1);
        }
        total = step.compose(&total);
        if step.dx.abs() < 1e-9 && step.dy.abs() < 1e-9 && step.dtheta.abs() < 1e-9 {
            break;
        }
    }
    Ok(total)
}

/// Pre-pass ICP kernel (per-iteration brute scan, sequential sums) —
/// kept as the bench baseline for `speedup_perception_pass`.
#[doc(hidden)]
pub fn icp_2d_reference(
    src: &PointCloud,
    dst: &PointCloud,
    iterations: usize,
) -> Result<Transform2D> {
    if src.num_points() < 3 || dst.num_points() < 3 {
        return Err(Error::Sim("icp needs >= 3 points per scan".into()));
    }
    let dst_pts = clouds_to_xy(dst);
    let mut cur = clouds_to_xy(src);
    let mut total = Transform2D::default();

    for _ in 0..iterations {
        let pairs: Vec<((f64, f64), (f64, f64))> = cur
            .iter()
            .map(|&p| {
                let q = dst_pts
                    .iter()
                    .min_by(|a, b| d2(p, **a).partial_cmp(&d2(p, **b)).unwrap())
                    .unwrap();
                (p, *q)
            })
            .collect();
        let n = pairs.len() as f64;
        let (mut mx, mut my, mut qx, mut qy) = (0.0, 0.0, 0.0, 0.0);
        for ((px, py), (dxp, dyp)) in &pairs {
            mx += px;
            my += py;
            qx += dxp;
            qy += dyp;
        }
        mx /= n;
        my /= n;
        qx /= n;
        qy /= n;
        let (mut sxx, mut sxy) = (0.0, 0.0);
        for ((px, py), (dxp, dyp)) in &pairs {
            let (ax, ay) = (px - mx, py - my);
            let (bx, by) = (dxp - qx, dyp - qy);
            sxx += ax * bx + ay * by;
            sxy += ax * by - ay * bx;
        }
        let theta = sxy.atan2(sxx);
        let (s, c) = theta.sin_cos();
        let step = Transform2D {
            dx: qx - (c * mx - s * my),
            dy: qy - (s * mx + c * my),
            dtheta: theta,
        };
        for p in &mut cur {
            *p = step.apply(p.0, p.1);
        }
        total = step.compose(&total);
        if step.dx.abs() < 1e-9 && step.dy.abs() < 1e-9 && step.dtheta.abs() < 1e-9 {
            break;
        }
    }
    Ok(total)
}

fn d2(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)
}

/// PJRT-backed scan descriptor (PointNet-lite artifact).
pub fn scan_descriptor(artifact_dir: &str, pc: &PointCloud) -> Result<Vec<f32>> {
    let rt = thread_runtime(artifact_dir)?;
    let m = rt.model("lidar_feat_b1")?;
    let n_model = m.sig.in_dims[1]; // points the artifact expects
    let mut input = vec![0f32; n_model * 4];
    // truncate / zero-pad the scan to the artifact's point count
    let n = pc.num_points().min(n_model);
    input[..n * 4].copy_from_slice(&pc.points[..n * 4]);
    m.run_f32(&input)
}

fn sumsq_8lane(v: &[f32]) -> f32 {
    let mut acc = [0f32; 8];
    let mut it = v.chunks_exact(8);
    for ch in it.by_ref() {
        for (l, &x) in ch.iter().enumerate() {
            acc[l] += x * x;
        }
    }
    let mut tail = 0f32;
    for &x in it.remainder() {
        tail += x * x;
    }
    tree8(acc) + tail
}

fn dot_8lane(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
        i += 8;
    }
    let mut tail = 0f32;
    for k in i..n {
        tail += a[k] * b[k];
    }
    tree8(acc) + tail
}

/// Cosine similarity between two descriptors (scan-match score), via
/// 8-lane chunked dot/norm accumulators;
/// [`descriptor_similarity_reference`] keeps the sequential reduction.
pub fn descriptor_similarity(a: &[f32], b: &[f32]) -> f32 {
    let dot = dot_8lane(a, b);
    let na = sumsq_8lane(a).sqrt();
    let nb = sumsq_8lane(b).sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

/// Pre-pass sequential cosine similarity — bench baseline.
#[doc(hidden)]
pub fn descriptor_similarity_reference(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Header;

    fn ring(n: usize, tf: &Transform2D) -> PointCloud {
        let mut points = Vec::with_capacity(n * 4);
        for k in 0..n {
            let ang = k as f64 / n as f64 * std::f64::consts::TAU;
            // non-circular shape (ellipse + bump) so rotation is observable
            let r = 10.0 + 2.0 * (3.0 * ang).cos();
            let (x, y) = (r * ang.cos(), r * ang.sin());
            let (x, y) = tf.apply(x, y);
            points.extend_from_slice(&[x as f32, y as f32, 0.0, 1.0]);
        }
        PointCloud { header: Header::default(), points }
    }

    #[test]
    fn icp_recovers_translation() {
        let src = ring(90, &Transform2D::default());
        let truth = Transform2D { dx: 0.4, dy: -0.25, dtheta: 0.0 };
        let dst = ring(90, &truth);
        let est = icp_2d(&src, &dst, 30).unwrap();
        assert!((est.dx - truth.dx).abs() < 0.05, "{est:?}");
        assert!((est.dy - truth.dy).abs() < 0.05, "{est:?}");
    }

    #[test]
    fn icp_recovers_small_rotation() {
        // A scattered (non-curve) cloud: rotation is observable because
        // points cannot slide along a tangent direction (no aperture
        // ambiguity like a smooth ring has).
        let mut rng = crate::util::prng::Prng::new(7);
        let mut points = Vec::new();
        for _ in 0..150 {
            let x = rng.range_f64(-10.0, 10.0);
            let y = rng.range_f64(-10.0, 10.0);
            points.extend_from_slice(&[x as f32, y as f32, 0.0, 1.0]);
        }
        let src = PointCloud { header: Header::default(), points: points.clone() };
        let truth = Transform2D { dx: 0.1, dy: 0.1, dtheta: 0.05 };
        let moved: Vec<f32> = points
            .chunks_exact(4)
            .flat_map(|p| {
                let (x, y) = truth.apply(p[0] as f64, p[1] as f64);
                [x as f32, y as f32, p[2], p[3]]
            })
            .collect();
        let dst = PointCloud { header: Header::default(), points: moved };
        let est = icp_2d(&src, &dst, 40).unwrap();
        assert!((est.dtheta - truth.dtheta).abs() < 0.02, "{est:?}");
        assert!((est.dx - truth.dx).abs() < 0.1, "{est:?}");
    }

    #[test]
    fn icp_identity_for_same_scan() {
        let s = ring(60, &Transform2D::default());
        let est = icp_2d(&s, &s, 10).unwrap();
        assert!(est.dx.abs() < 1e-6 && est.dy.abs() < 1e-6 && est.dtheta.abs() < 1e-6);
    }

    #[test]
    fn icp_rejects_tiny_scans() {
        let s = PointCloud { header: Header::default(), points: vec![1.0; 8] };
        assert!(icp_2d(&s, &s, 5).is_err());
    }

    #[test]
    fn icp_matches_reference_estimate() {
        // Same correspondences by construction; only the float-sum
        // association differs, so estimates agree to tight tolerance on
        // both sides of the grid threshold.
        for n in [20usize, 90] {
            let src = ring(n, &Transform2D::default());
            let truth = Transform2D { dx: 0.3, dy: -0.2, dtheta: 0.01 };
            let dst = ring(n, &truth);
            let a = icp_2d(&src, &dst, 25).unwrap();
            let b = icp_2d_reference(&src, &dst, 25).unwrap();
            assert!((a.dx - b.dx).abs() < 1e-6, "n={n} {a:?} vs {b:?}");
            assert!((a.dy - b.dy).abs() < 1e-6, "n={n} {a:?} vs {b:?}");
            assert!((a.dtheta - b.dtheta).abs() < 1e-6, "n={n} {a:?} vs {b:?}");
        }
    }

    #[test]
    fn grid_nearest_matches_brute_nearest() {
        let mut rng = crate::util::prng::Prng::new(11);
        let pts: Vec<(f64, f64)> = (0..200)
            .map(|_| (rng.range_f64(-40.0, 40.0), rng.range_f64(-40.0, 40.0)))
            .collect();
        let grid = CorrGrid::build(&pts);
        // in-box, out-of-box, and exactly-on-a-point queries
        let mut queries: Vec<(f64, f64)> = (0..300)
            .map(|_| (rng.range_f64(-60.0, 60.0), rng.range_f64(-60.0, 60.0)))
            .collect();
        queries.extend(pts.iter().take(20).copied());
        for q in queries {
            assert_eq!(grid.nearest(q), brute_nearest(&pts, q), "query {q:?}");
        }
    }

    #[test]
    fn grid_nearest_breaks_ties_by_lowest_index() {
        // Duplicate points and a query equidistant from two lattice
        // points: the brute scan returns the first minimum; the grid
        // must agree even when the tie spans cells.
        let pts =
            vec![(1.0, 0.0), (-1.0, 0.0), (1.0, 0.0), (0.0, 5.0), (0.0, -5.0), (3.0, 3.0)];
        let grid = CorrGrid::build(&pts);
        for q in [(0.0, 0.0), (1.0, 0.0), (0.0, 0.5)] {
            assert_eq!(grid.nearest(q), brute_nearest(&pts, q), "query {q:?}");
        }
    }

    #[test]
    fn grid_handles_degenerate_extents() {
        // collinear and single-location clouds must not break the grid
        let line: Vec<(f64, f64)> = (0..50).map(|i| (i as f64, 2.0)).collect();
        let g = CorrGrid::build(&line);
        assert_eq!(g.nearest((10.2, 7.0)), brute_nearest(&line, (10.2, 7.0)));
        let dup = vec![(4.0, 4.0); 40];
        let g = CorrGrid::build(&dup);
        assert_eq!(g.nearest((0.0, 0.0)), 0);
    }

    #[test]
    fn transform_compose_and_apply() {
        let a = Transform2D { dx: 1.0, dy: 0.0, dtheta: std::f64::consts::FRAC_PI_2 };
        let b = Transform2D { dx: 0.0, dy: 2.0, dtheta: 0.0 };
        let ab = a.compose(&b); // apply b then a
        let (x, y) = ab.apply(1.0, 0.0);
        // b: (1,0)->(1,2); a: rotate 90° -> (-2,1) then +1 x -> (-1,1)
        assert!((x - -1.0).abs() < 1e-9 && (y - 1.0).abs() < 1e-9, "({x},{y})");
    }

    #[test]
    fn descriptors_similar_for_similar_scans() {
        let dir = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let a = PointCloud::synthetic(256, 1);
        let b = PointCloud::synthetic(256, 1);
        let c = PointCloud::synthetic(256, 999);
        let da = scan_descriptor(&dir, &a).unwrap();
        let db = scan_descriptor(&dir, &b).unwrap();
        let dc = scan_descriptor(&dir, &c).unwrap();
        assert!(descriptor_similarity(&da, &db) > 0.999, "same scan ≈ identical");
        assert!(
            descriptor_similarity(&da, &dc) < descriptor_similarity(&da, &db),
            "different scan less similar"
        );
    }

    #[test]
    fn chunked_similarity_close_to_reference() {
        let mut rng = crate::util::prng::Prng::new(3);
        for len in [1usize, 7, 8, 64, 100] {
            let a: Vec<f32> =
                (0..len).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            let b: Vec<f32> =
                (0..len).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
            let fast = descriptor_similarity(&a, &b);
            let slow = descriptor_similarity_reference(&a, &b);
            assert!((fast - slow).abs() < 1e-5, "len={len}: {fast} vs {slow}");
        }
    }
}
