//! LiDAR localization — the paper's "localization algorithms that
//! consume LiDAR raw data" (Fig 3).
//!
//! Two pieces: a pure-Rust planar ICP (point-to-point, used as the
//! odometry estimator in the playback pipeline) and a PJRT-backed scan
//! descriptor (PointNet-lite artifact) used for loop-closure-style scan
//! matching.

use crate::error::{Error, Result};
use crate::msg::PointCloud;
use crate::runtime::thread_runtime;

/// Planar rigid transform (dx, dy, dtheta).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Transform2D {
    /// Translation along x (m).
    pub dx: f64,
    /// Translation along y (m).
    pub dy: f64,
    /// Rotation (rad, CCW).
    pub dtheta: f64,
}

impl Transform2D {
    /// Apply the transform to a point.
    pub fn apply(&self, x: f64, y: f64) -> (f64, f64) {
        let (s, c) = self.dtheta.sin_cos();
        (c * x - s * y + self.dx, s * x + c * y + self.dy)
    }

    /// Compose: self ∘ other (apply other first).
    pub fn compose(&self, other: &Transform2D) -> Transform2D {
        let (s, c) = self.dtheta.sin_cos();
        Transform2D {
            dx: self.dx + c * other.dx - s * other.dy,
            dy: self.dy + s * other.dx + c * other.dy,
            dtheta: self.dtheta + other.dtheta,
        }
    }
}

/// Point-to-point ICP in the plane (z ignored). Returns the transform
/// that maps `src` onto `dst`.
pub fn icp_2d(src: &PointCloud, dst: &PointCloud, iterations: usize) -> Result<Transform2D> {
    if src.num_points() < 3 || dst.num_points() < 3 {
        return Err(Error::Sim("icp needs >= 3 points per scan".into()));
    }
    let dst_pts: Vec<(f64, f64)> = (0..dst.num_points())
        .map(|i| {
            let (x, y, _, _) = dst.point(i);
            (x as f64, y as f64)
        })
        .collect();
    let mut cur: Vec<(f64, f64)> = (0..src.num_points())
        .map(|i| {
            let (x, y, _, _) = src.point(i);
            (x as f64, y as f64)
        })
        .collect();
    let mut total = Transform2D::default();

    for _ in 0..iterations {
        // nearest-neighbour correspondence (brute force; scans are small)
        let pairs: Vec<((f64, f64), (f64, f64))> = cur
            .iter()
            .map(|&p| {
                let q = dst_pts
                    .iter()
                    .min_by(|a, b| {
                        d2(p, **a).partial_cmp(&d2(p, **b)).unwrap()
                    })
                    .unwrap();
                (p, *q)
            })
            .collect();
        // closed-form 2D rigid alignment (Umeyama / SVD-free for 2D)
        let n = pairs.len() as f64;
        let (mut mx, mut my, mut qx, mut qy) = (0.0, 0.0, 0.0, 0.0);
        for ((px, py), (dxp, dyp)) in &pairs {
            mx += px;
            my += py;
            qx += dxp;
            qy += dyp;
        }
        mx /= n;
        my /= n;
        qx /= n;
        qy /= n;
        let (mut sxx, mut sxy) = (0.0, 0.0);
        for ((px, py), (dxp, dyp)) in &pairs {
            let (ax, ay) = (px - mx, py - my);
            let (bx, by) = (dxp - qx, dyp - qy);
            sxx += ax * bx + ay * by;
            sxy += ax * by - ay * bx;
        }
        let theta = sxy.atan2(sxx);
        let (s, c) = theta.sin_cos();
        let step = Transform2D {
            dx: qx - (c * mx - s * my),
            dy: qy - (s * mx + c * my),
            dtheta: theta,
        };
        for p in &mut cur {
            *p = step.apply(p.0, p.1);
        }
        total = step.compose(&total);
        if step.dx.abs() < 1e-9 && step.dy.abs() < 1e-9 && step.dtheta.abs() < 1e-9 {
            break;
        }
    }
    Ok(total)
}

fn d2(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)
}

/// PJRT-backed scan descriptor (PointNet-lite artifact).
pub fn scan_descriptor(artifact_dir: &str, pc: &PointCloud) -> Result<Vec<f32>> {
    let rt = thread_runtime(artifact_dir)?;
    let m = rt.model("lidar_feat_b1")?;
    let n_model = m.sig.in_dims[1]; // points the artifact expects
    let mut input = vec![0f32; n_model * 4];
    // truncate / zero-pad the scan to the artifact's point count
    let n = pc.num_points().min(n_model);
    input[..n * 4].copy_from_slice(&pc.points[..n * 4]);
    m.run_f32(&input)
}

/// Cosine similarity between two descriptors (scan-match score).
pub fn descriptor_similarity(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Header;

    fn ring(n: usize, tf: &Transform2D) -> PointCloud {
        let mut points = Vec::with_capacity(n * 4);
        for k in 0..n {
            let ang = k as f64 / n as f64 * std::f64::consts::TAU;
            // non-circular shape (ellipse + bump) so rotation is observable
            let r = 10.0 + 2.0 * (3.0 * ang).cos();
            let (x, y) = (r * ang.cos(), r * ang.sin());
            let (x, y) = tf.apply(x, y);
            points.extend_from_slice(&[x as f32, y as f32, 0.0, 1.0]);
        }
        PointCloud { header: Header::default(), points }
    }

    #[test]
    fn icp_recovers_translation() {
        let src = ring(90, &Transform2D::default());
        let truth = Transform2D { dx: 0.4, dy: -0.25, dtheta: 0.0 };
        let dst = ring(90, &truth);
        let est = icp_2d(&src, &dst, 30).unwrap();
        assert!((est.dx - truth.dx).abs() < 0.05, "{est:?}");
        assert!((est.dy - truth.dy).abs() < 0.05, "{est:?}");
    }

    #[test]
    fn icp_recovers_small_rotation() {
        // A scattered (non-curve) cloud: rotation is observable because
        // points cannot slide along a tangent direction (no aperture
        // ambiguity like a smooth ring has).
        let mut rng = crate::util::prng::Prng::new(7);
        let mut points = Vec::new();
        for _ in 0..150 {
            let x = rng.range_f64(-10.0, 10.0);
            let y = rng.range_f64(-10.0, 10.0);
            points.extend_from_slice(&[x as f32, y as f32, 0.0, 1.0]);
        }
        let src = PointCloud { header: Header::default(), points: points.clone() };
        let truth = Transform2D { dx: 0.1, dy: 0.1, dtheta: 0.05 };
        let moved: Vec<f32> = points
            .chunks_exact(4)
            .flat_map(|p| {
                let (x, y) = truth.apply(p[0] as f64, p[1] as f64);
                [x as f32, y as f32, p[2], p[3]]
            })
            .collect();
        let dst = PointCloud { header: Header::default(), points: moved };
        let est = icp_2d(&src, &dst, 40).unwrap();
        assert!((est.dtheta - truth.dtheta).abs() < 0.02, "{est:?}");
        assert!((est.dx - truth.dx).abs() < 0.1, "{est:?}");
    }

    #[test]
    fn icp_identity_for_same_scan() {
        let s = ring(60, &Transform2D::default());
        let est = icp_2d(&s, &s, 10).unwrap();
        assert!(est.dx.abs() < 1e-6 && est.dy.abs() < 1e-6 && est.dtheta.abs() < 1e-6);
    }

    #[test]
    fn icp_rejects_tiny_scans() {
        let s = PointCloud { header: Header::default(), points: vec![1.0; 8] };
        assert!(icp_2d(&s, &s, 5).is_err());
    }

    #[test]
    fn transform_compose_and_apply() {
        let a = Transform2D { dx: 1.0, dy: 0.0, dtheta: std::f64::consts::FRAC_PI_2 };
        let b = Transform2D { dx: 0.0, dy: 2.0, dtheta: 0.0 };
        let ab = a.compose(&b); // apply b then a
        let (x, y) = ab.apply(1.0, 0.0);
        // b: (1,0)->(1,2); a: rotate 90° -> (-2,1) then +1 x -> (-1,1)
        assert!((x - -1.0).abs() < 1e-9 && (y - 1.0).abs() < 1e-9, "({x},{y})");
    }

    #[test]
    fn descriptors_similar_for_similar_scans() {
        let dir = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let a = PointCloud::synthetic(256, 1);
        let b = PointCloud::synthetic(256, 1);
        let c = PointCloud::synthetic(256, 999);
        let da = scan_descriptor(&dir, &a).unwrap();
        let db = scan_descriptor(&dir, &b).unwrap();
        let dc = scan_descriptor(&dir, &c).unwrap();
        assert!(descriptor_similarity(&da, &db) > 0.999, "same scan ≈ identical");
        assert!(
            descriptor_similarity(&da, &dc) < descriptor_similarity(&da, &db),
            "different scan less similar"
        );
    }
}
