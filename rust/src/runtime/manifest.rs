//! Artifact manifest parsing.
//!
//! `aot.py` writes `artifacts/manifest.txt` with one line per artifact:
//! ```text
//! classifier_b8 8 32 32 3 -> 8 8
//! ```
//! (name, input dims, `->`, output dims). The Rust runtime uses it to
//! validate input shapes without parsing HLO.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Shape signature of one artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSig {
    /// Artifact name (e.g. `classifier_b8`).
    pub name: String,
    /// Input tensor dimensions.
    pub in_dims: Vec<usize>,
    /// Output tensor dimensions.
    pub out_dims: Vec<usize>,
}

impl ModelSig {
    /// Batch dimension (leading input dim).
    pub fn batch(&self) -> usize {
        *self.in_dims.first().unwrap_or(&1)
    }

    /// Input elements per batch row.
    pub fn in_elems_per_row(&self) -> usize {
        self.in_dims.iter().skip(1).product()
    }

    /// Output elements per batch row.
    pub fn out_elems_per_row(&self) -> usize {
        self.out_dims.iter().skip(1).product()
    }
}

/// Parsed manifest: artifact name → signature.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    models: BTreeMap<String, ModelSig>,
}

impl Manifest {
    /// Load and parse `manifest.txt` from `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Runtime(format!(
                "manifest {}: {e} (run `make artifacts`)",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Parse manifest text (`name: in_dims -> out_dims` lines).
    pub fn parse(text: &str) -> Result<Self> {
        let mut models = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (head, tail) = line.split_once("->").ok_or_else(|| {
                Error::Runtime(format!("manifest line {}: missing '->'", i + 1))
            })?;
            let mut head_it = head.split_whitespace();
            let name = head_it
                .next()
                .ok_or_else(|| Error::Runtime(format!("manifest line {}: empty", i + 1)))?
                .to_string();
            let in_dims = parse_dims(head_it, i)?;
            let out_dims = parse_dims(tail.split_whitespace(), i)?;
            if in_dims.is_empty() || out_dims.is_empty() {
                return Err(Error::Runtime(format!(
                    "manifest line {}: empty dims for {name}",
                    i + 1
                )));
            }
            // Zero dims would make the runtime's row arithmetic index out
            // of bounds; a shape with a 0 is always a manifest bug.
            if in_dims.iter().chain(&out_dims).any(|&d| d == 0) {
                return Err(Error::Runtime(format!(
                    "manifest line {}: zero dim in shape for {name}",
                    i + 1
                )));
            }
            models.insert(name.clone(), ModelSig { name, in_dims, out_dims });
        }
        Ok(Self { models })
    }

    /// Look up an artifact signature (actionable error when missing).
    pub fn get(&self, name: &str) -> Result<&ModelSig> {
        self.models.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "unknown artifact '{name}' (manifest has: {})",
                self.names().join(", ")
            ))
        })
    }

    /// All artifact names.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }
}

fn parse_dims<'a>(
    it: impl Iterator<Item = &'a str>,
    line: usize,
) -> Result<Vec<usize>> {
    it.map(|t| {
        t.parse::<usize>()
            .map_err(|_| Error::Runtime(format!("manifest line {}: bad dim '{t}'", line + 1)))
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_well_formed() {
        let m = Manifest::parse(
            "classifier_b8 8 32 32 3 -> 8 8\nlidar_feat_b1 1 256 4 -> 1 64\n",
        )
        .unwrap();
        let sig = m.get("classifier_b8").unwrap();
        assert_eq!(sig.in_dims, vec![8, 32, 32, 3]);
        assert_eq!(sig.out_dims, vec![8, 8]);
        assert_eq!(sig.batch(), 8);
        assert_eq!(sig.in_elems_per_row(), 32 * 32 * 3);
        assert_eq!(sig.out_elems_per_row(), 8);
        assert_eq!(m.names(), vec!["classifier_b8", "lidar_feat_b1"]);
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = Manifest::parse("# header\n\nx 1 2 -> 1\n").unwrap();
        assert!(m.get("x").is_ok());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("no_arrow 1 2 3\n").is_err());
        assert!(Manifest::parse("bad_dim 1 x -> 1\n").is_err());
        assert!(Manifest::parse("empty_out 1 ->\n").is_err());
        assert!(Manifest::parse("zero_in 0 4 -> 1 2\n").is_err());
        assert!(Manifest::parse("zero_out 1 4 -> 1 0\n").is_err());
    }

    #[test]
    fn unknown_lookup_lists_known() {
        let m = Manifest::parse("a 1 -> 1\n").unwrap();
        let err = m.get("b").unwrap_err();
        assert!(err.to_string().contains("manifest has: a"));
    }
}
