//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust hot path.
//!
//! The compile path is Python (`python/compile/aot.py`, build time only);
//! this module is the run path: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One [`ModelRuntime`] per process caches compiled executables by
//! artifact name; [`ModelPool`] hands out per-thread handles.

pub mod manifest;

pub use manifest::{Manifest, ModelSig};

use crate::error::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A compiled model executable + its I/O signature.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub sig: ModelSig,
}

impl CompiledModel {
    /// Execute on a flat f32 input of the signature's input shape.
    /// Returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        let expect: usize = self.sig.in_dims.iter().product();
        if input.len() != expect {
            return Err(Error::Runtime(format!(
                "model '{}' expects {expect} f32 inputs ({:?}), got {}",
                self.sig.name,
                self.sig.in_dims,
                input.len()
            )));
        }
        let dims: Vec<i64> = self.sig.in_dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Output element count.
    pub fn out_len(&self) -> usize {
        self.sig.out_dims.iter().product()
    }
}

/// Process-wide PJRT client + executable cache.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<CompiledModel>>>,
}

impl ModelRuntime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let artifact_dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&artifact_dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifact_dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile (or fetch cached) a model by artifact name, e.g.
    /// `"classifier_b8"`.
    pub fn model(&self, name: &str) -> Result<Rc<CompiledModel>> {
        if let Some(m) = self.cache.borrow().get(name) {
            return Ok(m.clone());
        }
        let sig = self.manifest.get(name)?.clone();
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Runtime(format!("bad artifact path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(|e| {
            Error::Runtime(format!(
                "load artifact {path_str}: {e} (run `make artifacts`?)"
            ))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let model = Rc::new(CompiledModel { exe, sig });
        self.cache.borrow_mut().insert(name.to_string(), model.clone());
        Ok(model)
    }
}

// PJRT handles in the `xla` crate are Rc-based (not Send/Sync), so the
// runtime is per-thread: each executor thread (local mode) or worker
// process (standalone mode) owns one client + executable cache — the
// same one-runtime-per-executor layout Spark workers have.
thread_local! {
    static THREAD_RT: RefCell<Option<(String, Rc<ModelRuntime>)>> = const { RefCell::new(None) };
}

/// Get (or initialize) this thread's runtime rooted at `artifact_dir`.
/// Re-rooting the same thread at a different directory is an error.
pub fn thread_runtime(artifact_dir: &str) -> Result<Rc<ModelRuntime>> {
    THREAD_RT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some((root, rt)) = slot.as_ref() {
            if root != artifact_dir {
                return Err(Error::Runtime(format!(
                    "thread runtime already rooted at '{root}', asked for '{artifact_dir}'"
                )));
            }
            return Ok(rt.clone());
        }
        let rt = Rc::new(ModelRuntime::new(artifact_dir)?);
        *slot = Some((artifact_dir.to_string(), rt.clone()));
        Ok(rt)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        // tests run from the crate root; artifacts/ is built by `make artifacts`
        let d = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        assert!(
            std::path::Path::new(&d).join("manifest.txt").exists(),
            "artifacts missing — run `make artifacts` first"
        );
        d
    }

    #[test]
    fn load_and_run_classifier() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b1").unwrap();
        assert_eq!(m.sig.in_dims, vec![1, 32, 32, 3]);
        assert_eq!(m.sig.out_dims, vec![1, 8]);
        let input = vec![0.5f32; 32 * 32 * 3];
        let out = m.run_f32(&input).unwrap();
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batch8_runs_and_differs_across_rows() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b8").unwrap();
        let n = 8 * 32 * 32 * 3;
        let input: Vec<f32> = (0..n).map(|i| (i % 255) as f32 / 255.0).collect();
        let out = m.run_f32(&input).unwrap();
        assert_eq!(out.len(), 64);
        // different rows see different pixels → logits differ
        assert_ne!(&out[0..8], &out[8..16]);
    }

    #[test]
    fn executables_are_cached() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let a = rt.model("lidar_feat_b1").unwrap();
        let b = rt.model("lidar_feat_b1").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn thread_runtime_is_cached_and_root_checked() {
        let dir = artifact_dir();
        let a = thread_runtime(&dir).unwrap();
        let b = thread_runtime(&dir).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert!(thread_runtime("/other/root").is_err());
    }

    #[test]
    fn wrong_input_len_is_error() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b1").unwrap();
        let err = m.run_f32(&[0.0; 7]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn unknown_model_is_error() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        assert!(rt.model("nonexistent_b4").is_err());
    }

    #[test]
    fn segmenter_per_pixel_output() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("segmenter_b1").unwrap();
        let out = m.run_f32(&vec![0.3; 32 * 32 * 3]).unwrap();
        assert_eq!(out.len(), 32 * 32 * 4);
    }

    #[test]
    fn lidar_descriptor_runs() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("lidar_feat_b1").unwrap();
        let out = m.run_f32(&vec![0.1; 256 * 4]).unwrap();
        assert_eq!(out.len(), 64);
    }
}
