//! Model runtime: execute the AOT model signatures from the Rust hot
//! path.
//!
//! The paper's platform runs DNN compute through PJRT-loaded HLO
//! artifacts (compile path: `python/compile/aot.py`, build time only).
//! The offline crate set has no PJRT bindings, so this module executes a
//! **deterministic reference network** per manifest entry instead: a
//! seeded random-projection + tanh layer with exactly the manifest's
//! input/output shapes. The call surface (`ModelRuntime`,
//! [`CompiledModel::run_f32`], [`thread_runtime`]) is identical to the
//! PJRT path, and the substitution preserves every property the platform
//! relies on:
//!
//! * deterministic across threads, processes and cluster backends
//!   (bitwise — fixed f32 evaluation order, weights derived from the
//!   model family name only);
//! * batch variants agree with single-row variants row-for-row
//!   (`classifier_b8` row *i* == `classifier_b1` on row *i*);
//! * outputs depend on every input element (input-sensitive logits).

pub mod manifest;

pub use manifest::{Manifest, ModelSig};

use crate::error::{Error, Result};
use crate::util::prng::Prng;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Weight-table size for the reference projection (two coprime tables
/// keep the effective weight matrix non-degenerate without storing
/// in_dims × out_dims floats per model).
const TAB_A: usize = 521;
const TAB_B: usize = 263;

/// A loaded model: manifest signature + reference-network weights.
pub struct CompiledModel {
    /// Manifest signature the model was loaded against.
    pub sig: ModelSig,
    wa: Vec<f32>,
    wb: Vec<f32>,
}

/// Batch variants of one model (`classifier_b1`, `classifier_b8`) must
/// compute the same function per row, so weights are seeded from the
/// family name with the `_b<N>` suffix stripped.
fn family(name: &str) -> &str {
    match name.rsplit_once("_b") {
        Some((fam, suffix)) if !suffix.is_empty() && suffix.bytes().all(|b| b.is_ascii_digit()) => {
            fam
        }
        _ => name,
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl CompiledModel {
    fn new(sig: ModelSig) -> Self {
        let mut rng = Prng::new(fnv1a(family(&sig.name)));
        let wa = (0..TAB_A).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let wb = (0..TAB_B).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        Self { sig, wa, wb }
    }

    /// Execute on a flat f32 input of the signature's input shape.
    /// Returns the flat f32 output.
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.run_f32_into(input, &mut out)?;
        Ok(out)
    }

    /// [`CompiledModel::run_f32`] writing into a caller-owned logits
    /// buffer (cleared first) so per-frame pipelines reuse one
    /// allocation across calls.
    ///
    /// The projection `acc_j = B[j mod |B|] + Σ_i x_i · A[(31i + j)
    /// mod |A|] · B[(i + 7j) mod |B|]` is evaluated 4 output lanes at a
    /// time: each lane keeps its own accumulator and its own pair of
    /// incrementally-maintained table indices (step +31 mod |A|, +1 mod
    /// |B| as `i` advances — no division in the inner loop), and every
    /// lane adds its terms in ascending-`i` order exactly as the scalar
    /// loop does. Lanes are *independent outputs*, so the blocking
    /// cannot reassociate any sum: outputs are bitwise identical to
    /// [`CompiledModel::run_f32_reference`] (asserted by tests and the
    /// perception property suite).
    pub fn run_f32_into(&self, input: &[f32], out: &mut Vec<f32>) -> Result<()> {
        let expect: usize = self.sig.in_dims.iter().product();
        if input.len() != expect {
            return Err(Error::Runtime(format!(
                "model '{}' expects {expect} f32 inputs ({:?}), got {}",
                self.sig.name,
                self.sig.in_dims,
                input.len()
            )));
        }
        let batch = self.sig.batch().max(1);
        let in_row = self.sig.in_elems_per_row().max(1);
        let out_row = self.sig.out_elems_per_row().max(1);
        out.clear();
        out.reserve(batch * out_row);
        let wa = &self.wa[..TAB_A];
        let wb = &self.wb[..TAB_B];
        const LANES: usize = 4;
        for r in 0..batch {
            let row = &input[r * in_row..(r + 1) * in_row];
            let mut j = 0usize;
            while j + LANES <= out_row {
                let mut acc = [0f32; LANES];
                let mut ia = [0usize; LANES];
                let mut ib = [0usize; LANES];
                for l in 0..LANES {
                    acc[l] = wb[(j + l) % TAB_B];
                    ia[l] = (j + l) % TAB_A;
                    ib[l] = (j + l).wrapping_mul(7) % TAB_B;
                }
                for &x in row {
                    for l in 0..LANES {
                        acc[l] += x * wa[ia[l]] * wb[ib[l]];
                        // steps are < table size, so one conditional
                        // subtract replaces the modulo
                        ia[l] += 31;
                        if ia[l] >= TAB_A {
                            ia[l] -= TAB_A;
                        }
                        ib[l] += 1;
                        if ib[l] >= TAB_B {
                            ib[l] -= TAB_B;
                        }
                    }
                }
                for a in acc {
                    out.push((a * 0.25).tanh());
                }
                j += LANES;
            }
            // scalar tail for out_row % LANES (same incremental indices)
            while j < out_row {
                let mut acc = wb[j % TAB_B];
                let mut ia = j % TAB_A;
                let mut ib = j.wrapping_mul(7) % TAB_B;
                for &x in row {
                    acc += x * wa[ia] * wb[ib];
                    ia += 31;
                    if ia >= TAB_A {
                        ia -= TAB_A;
                    }
                    ib += 1;
                    if ib >= TAB_B {
                        ib -= TAB_B;
                    }
                }
                out.push((acc * 0.25).tanh());
                j += 1;
            }
        }
        Ok(())
    }

    /// The pre-optimization scalar kernel: one output at a time, table
    /// indices recomputed with a modulo per element. Kept (not
    /// `cfg(test)`) as the `bench_engine` baseline for the
    /// `speedup_perception_pass` fact and as the bit-identity oracle
    /// for the lane-blocked [`CompiledModel::run_f32`].
    #[doc(hidden)]
    pub fn run_f32_reference(&self, input: &[f32]) -> Result<Vec<f32>> {
        let expect: usize = self.sig.in_dims.iter().product();
        if input.len() != expect {
            return Err(Error::Runtime(format!(
                "model '{}' expects {expect} f32 inputs ({:?}), got {}",
                self.sig.name,
                self.sig.in_dims,
                input.len()
            )));
        }
        let batch = self.sig.batch().max(1);
        let in_row = self.sig.in_elems_per_row().max(1);
        let out_row = self.sig.out_elems_per_row().max(1);
        let mut out = Vec::with_capacity(batch * out_row);
        for r in 0..batch {
            let row = &input[r * in_row..(r + 1) * in_row];
            for j in 0..out_row {
                // acc = Σ_i x_i · A[(31·i + j) mod |A|] · B[(i + 7·j) mod |B|]
                // — a dense pseudo-random projection evaluated in a fixed
                // order so results are bitwise reproducible everywhere.
                let mut acc = self.wb[j % TAB_B];
                for (i, &x) in row.iter().enumerate() {
                    let a = self.wa[(i.wrapping_mul(31).wrapping_add(j)) % TAB_A];
                    let b = self.wb[(i.wrapping_add(j.wrapping_mul(7))) % TAB_B];
                    acc += x * a * b;
                }
                out.push((acc * 0.25).tanh());
            }
        }
        Ok(out)
    }

    /// Output element count.
    pub fn out_len(&self) -> usize {
        self.sig.out_dims.iter().product()
    }
}

/// Process-wide model cache rooted at one artifact directory.
pub struct ModelRuntime {
    artifact_dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<CompiledModel>>>,
}

impl ModelRuntime {
    /// Read the artifact manifest and prepare the executable cache.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let artifact_dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&artifact_dir.join("manifest.txt"))?;
        Ok(Self { artifact_dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Directory the runtime was rooted at.
    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load (or fetch cached) a model by artifact name, e.g.
    /// `"classifier_b8"`.
    pub fn model(&self, name: &str) -> Result<Rc<CompiledModel>> {
        if let Some(m) = self.cache.borrow().get(name) {
            return Ok(m.clone());
        }
        let sig = self.manifest.get(name)?.clone();
        let model = Rc::new(CompiledModel::new(sig));
        self.cache.borrow_mut().insert(name.to_string(), model.clone());
        Ok(model)
    }
}

// Model handles are Rc-based (matching the PJRT bindings they stand in
// for), so the runtime is per-thread: each executor thread (local mode)
// or worker process (standalone mode) owns one cache — the same
// one-runtime-per-executor layout Spark workers have.
thread_local! {
    static THREAD_RT: RefCell<Option<(String, Rc<ModelRuntime>)>> = const { RefCell::new(None) };
}

/// Get (or initialize) this thread's runtime rooted at `artifact_dir`.
/// Re-rooting the same thread at a different directory is an error.
pub fn thread_runtime(artifact_dir: &str) -> Result<Rc<ModelRuntime>> {
    THREAD_RT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some((root, rt)) = slot.as_ref() {
            if root != artifact_dir {
                return Err(Error::Runtime(format!(
                    "thread runtime already rooted at '{root}', asked for '{artifact_dir}'"
                )));
            }
            return Ok(rt.clone());
        }
        let rt = Rc::new(ModelRuntime::new(artifact_dir)?);
        *slot = Some((artifact_dir.to_string(), rt.clone()));
        Ok(rt)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        let d = std::env::var("AV_SIMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        assert!(
            std::path::Path::new(&d).join("manifest.txt").exists(),
            "artifacts/manifest.txt missing from the checkout"
        );
        d
    }

    #[test]
    fn load_and_run_classifier() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b1").unwrap();
        assert_eq!(m.sig.in_dims, vec![1, 32, 32, 3]);
        assert_eq!(m.sig.out_dims, vec![1, 8]);
        let input = vec![0.5f32; 32 * 32 * 3];
        let out = m.run_f32(&input).unwrap();
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batch8_runs_and_differs_across_rows() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b8").unwrap();
        let n = 8 * 32 * 32 * 3;
        let input: Vec<f32> = (0..n).map(|i| (i % 255) as f32 / 255.0).collect();
        let out = m.run_f32(&input).unwrap();
        assert_eq!(out.len(), 64);
        // different rows see different pixels → logits differ
        assert_ne!(&out[0..8], &out[8..16]);
    }

    #[test]
    fn batch_variant_matches_single_variant_exactly() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let b1 = rt.model("classifier_b1").unwrap();
        let b8 = rt.model("classifier_b8").unwrap();
        let row = 32 * 32 * 3;
        let input: Vec<f32> = (0..8 * row).map(|i| ((i * 37) % 251) as f32 / 251.0).collect();
        let batched = b8.run_f32(&input).unwrap();
        for r in 0..8 {
            let single = b1.run_f32(&input[r * row..(r + 1) * row]).unwrap();
            assert_eq!(single, batched[r * 8..(r + 1) * 8], "row {r}");
        }
    }

    #[test]
    fn executables_are_cached() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let a = rt.model("lidar_feat_b1").unwrap();
        let b = rt.model("lidar_feat_b1").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn thread_runtime_is_cached_and_root_checked() {
        let dir = artifact_dir();
        let a = thread_runtime(&dir).unwrap();
        let b = thread_runtime(&dir).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert!(thread_runtime("/other/root").is_err());
    }

    #[test]
    fn wrong_input_len_is_error() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b1").unwrap();
        let err = m.run_f32(&[0.0; 7]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn unknown_model_is_error() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        assert!(rt.model("nonexistent_b4").is_err());
    }

    #[test]
    fn segmenter_per_pixel_output() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("segmenter_b1").unwrap();
        let out = m.run_f32(&vec![0.3; 32 * 32 * 3]).unwrap();
        assert_eq!(out.len(), 32 * 32 * 4);
    }

    #[test]
    fn lidar_descriptor_runs() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("lidar_feat_b1").unwrap();
        let out = m.run_f32(&vec![0.1; 256 * 4]).unwrap();
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn lane_blocked_kernel_matches_reference_bitwise() {
        // The perf-pass contract: the 4-lane incremental-index kernel
        // must be bit-identical to the scalar modulo kernel for every
        // manifest model (covers out_row % 4 == 0 and the scalar tail).
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        for name in
            ["classifier_b1", "classifier_b8", "segmenter_b1", "segmenter_b8", "lidar_feat_b1"]
        {
            let m = rt.model(name).unwrap();
            let n: usize = m.sig.in_dims.iter().product();
            let input: Vec<f32> =
                (0..n).map(|i| ((i * 131 + 17) % 509) as f32 / 509.0 - 0.5).collect();
            let fast = m.run_f32(&input).unwrap();
            let slow = m.run_f32_reference(&input).unwrap();
            assert_eq!(fast, slow, "{name}: lane-blocked kernel diverged");
        }
    }

    #[test]
    fn run_into_reuses_buffer_and_matches() {
        let rt = ModelRuntime::new(artifact_dir()).unwrap();
        let m = rt.model("classifier_b1").unwrap();
        let a: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 97) as f32 / 97.0).collect();
        let b: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 89) as f32 / 89.0).collect();
        let mut buf = Vec::new();
        m.run_f32_into(&a, &mut buf).unwrap();
        assert_eq!(buf, m.run_f32(&a).unwrap());
        // second call clears and refills — no stale logits
        m.run_f32_into(&b, &mut buf).unwrap();
        assert_eq!(buf, m.run_f32(&b).unwrap());
    }

    #[test]
    fn family_strips_batch_suffix_only() {
        assert_eq!(family("classifier_b8"), "classifier");
        assert_eq!(family("classifier_b1"), "classifier");
        assert_eq!(family("lidar_feat_b1"), "lidar_feat");
        assert_eq!(family("weird_bx"), "weird_bx");
        assert_eq!(family("plain"), "plain");
    }
}
