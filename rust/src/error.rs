//! Unified error type for the platform.
//!
//! Every layer (bag, bus, engine, pipe, runtime, …) reports through
//! [`Error`]; `Result<T>` is the crate-wide result alias.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified platform error.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure (disk, pipe, socket).
    Io(std::io::Error),
    /// Malformed or truncated on-wire / on-disk data.
    Corrupt(String),
    /// Bag format violation (bad magic, CRC mismatch, unknown record).
    BagFormat(String),
    /// Pub/sub bus failure (unknown topic, closed subscriber, type clash).
    Bus(String),
    /// Distributed engine failure (scheduling, task, worker loss).
    Engine(String),
    /// RPC transport death: the remote end hung up or the connection
    /// died mid-frame. Distinguished from [`Error::Engine`] so the
    /// dispatch layer can classify worker loss by type instead of by
    /// matching error-message substrings.
    Transport(String),
    /// BinPipedRDD child-process failure.
    Pipe(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Configuration parse or validation failure.
    Config(String),
    /// Storage (DFS-lite / block store) failure.
    Storage(String),
    /// Simulation-layer failure (scenario, dynamics, verdict).
    Sim(String),
    /// Anything else.
    Other(String),
}

impl Error {
    /// Short machine-readable category tag, used by metrics and logs.
    pub fn category(&self) -> &'static str {
        match self {
            Error::Io(_) => "io",
            Error::Corrupt(_) => "corrupt",
            Error::BagFormat(_) => "bag",
            Error::Bus(_) => "bus",
            Error::Engine(_) => "engine",
            Error::Transport(_) => "transport",
            Error::Pipe(_) => "pipe",
            Error::Runtime(_) => "runtime",
            Error::Config(_) => "config",
            Error::Storage(_) => "storage",
            Error::Sim(_) => "sim",
            Error::Other(_) => "other",
        }
    }

    /// True when retrying the same operation may succeed (used by the
    /// engine's task-retry policy).
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::Io(_) | Error::Engine(_) | Error::Pipe(_) | Error::Transport(_)
        )
    }

    /// True when this error means the underlying connection is dead
    /// (socket I/O failure, peer hang-up, or a frame cut off mid-read)
    /// rather than a per-request failure on a healthy transport. The
    /// standalone feeder uses this to decide between retrying one task
    /// and declaring the whole worker lost.
    pub fn is_transport_death(&self) -> bool {
        matches!(self, Error::Io(_) | Error::Transport(_))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Corrupt(m) => write!(f, "corrupt data: {m}"),
            Error::BagFormat(m) => write!(f, "bag format: {m}"),
            Error::Bus(m) => write!(f, "bus: {m}"),
            Error::Engine(m) => write!(f, "engine: {m}"),
            Error::Transport(m) => write!(f, "transport: {m}"),
            Error::Pipe(m) => write!(f, "pipe: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Storage(m) => write!(f, "storage: {m}"),
            Error::Sim(m) => write!(f, "sim: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error::Other(m)
    }
}

/// Convenience constructors used across the crate.
#[macro_export]
macro_rules! err {
    ($kind:ident, $($arg:tt)*) => {
        $crate::error::Error::$kind(format!($($arg)*))
    };
}

/// `bail!(Kind, "...")` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($kind:ident, $($arg:tt)*) => {
        return Err($crate::err!($kind, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn categories_are_stable() {
        assert_eq!(Error::Bus("x".into()).category(), "bus");
        assert_eq!(
            Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x")).category(),
            "io"
        );
    }

    #[test]
    fn retryability() {
        assert!(Error::Engine("worker lost".into()).is_retryable());
        assert!(Error::Transport("hung up".into()).is_retryable());
        assert!(!Error::BagFormat("bad magic".into()).is_retryable());
    }

    #[test]
    fn transport_death_is_typed_not_textual() {
        // the classification must not depend on message wording
        assert!(Error::Transport("anything at all".into()).is_transport_death());
        assert!(Error::Io(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "x"))
            .is_transport_death());
        // a worker-side task error travels over a healthy transport
        assert!(!Error::Engine("remote task 3 failed: boom".into()).is_transport_death());
        assert_eq!(Error::Transport("x".into()).category(), "transport");
    }

    #[test]
    fn display_includes_message() {
        let e = Error::Pipe("child exited 1".into());
        assert!(e.to_string().contains("child exited 1"));
    }

    #[test]
    fn macros_compile() {
        fn f() -> crate::error::Result<()> {
            bail!(Sim, "ttc {} below {}", 0.4, 1.0);
        }
        let e = f().unwrap_err();
        assert_eq!(e.category(), "sim");
        assert!(e.to_string().contains("ttc 0.4"));
    }
}
