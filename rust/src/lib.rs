//! # av-simd — Distributed Simulation Platform for Autonomous Driving
//!
//! Production-shaped reproduction of Tang et al., *Distributed Simulation
//! Platform for Autonomous Driving* (CS.DC 2017): a Spark-like
//! distributed compute engine ([`engine`]) orchestrating ROS-like playback
//! simulators ([`bus`], [`bag`]) over binary sensor data, with the paper's
//! `BinPipedRDD` binary pipe bridge ([`pipe`]) and `MemoryChunkedFile`
//! in-memory bag cache ([`bag::MemoryChunkedFile`]). Perception compute is
//! AOT-compiled JAX/Pallas executed through PJRT ([`runtime`],
//! [`perception`]); Python never runs on the simulation path.
//!
//! See `docs/ARCHITECTURE.md` for the layer map and wire-format specs,
//! `docs/OPERATIONS.md` for running multi-host fleets, `DESIGN.md` for
//! the paper → module inventory and `EXPERIMENTS.md` for reproduced
//! figures.
#![warn(missing_docs)]

pub mod bag;
pub mod bus;
pub mod cli;
pub mod config;
pub mod datagen;
pub mod engine;
pub mod error;
pub mod msg;
pub mod metrics;
pub mod perception;
pub mod pipe;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod util;

pub use error::{Error, Result};

/// Operator registry with every operator this binary knows: engine
/// built-ins + PJRT-backed perception ops. Drivers and workers both use
/// this, so op names resolve identically across processes.
pub fn full_op_registry() -> engine::OpRegistry {
    let reg = engine::OpRegistry::with_builtins();
    perception::register_perception_ops(&reg);
    sim::register_sim_ops(&reg);
    reg
}

/// User-logic registry with every BinPipedRDD logic this binary knows
/// (built-ins + perception). Used by the `user-logic` child mode.
pub fn full_logic_registry() -> pipe::LogicRegistry {
    let mut reg = pipe::LogicRegistry::with_builtins();
    perception::register_perception_logics(&mut reg);
    reg
}
