//! Typed message definitions — the platform's equivalent of ROS message
//! types (`sensor_msgs/Image`, `sensor_msgs/PointCloud2`, …).
//!
//! Every message has a stable type name (used by bag connection records
//! and bus topic typing) and a versioned binary wire codec built on
//! [`crate::util::bytes`]. Decoding rejects version/type mismatches.

pub mod header;
pub mod sensor;
pub mod state;

pub use header::{Header, Time};
pub use sensor::{CompressedImage, Image, Imu, PixelFormat, PointCloud};
pub use state::{ControlCommand, Detection, DetectionArray, Pose, Twist};

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};

/// Wire codec version for all message types.
pub const MSG_CODEC_VERSION: u8 = 1;

/// A message that can cross the bag/bus/pipe boundary.
pub trait Message: Sized + Send + 'static {
    /// Stable fully-qualified type name, e.g. `"av/sensor/Image"`.
    const TYPE_NAME: &'static str;

    /// Append the body (no envelope) to `w`.
    fn encode_body(&self, w: &mut ByteWriter);

    /// Parse the body from `r`.
    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self>;

    /// Encode with the standard envelope: codec version + type name.
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::with_capacity(64);
        w.put_u8(MSG_CODEC_VERSION);
        w.put_str(Self::TYPE_NAME);
        self.encode_body(&mut w);
        w.into_vec()
    }

    /// Decode, checking envelope version and type name.
    fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(buf);
        let ver = r.get_u8()?;
        if ver != MSG_CODEC_VERSION {
            return Err(Error::Corrupt(format!(
                "message codec version {ver}, expected {MSG_CODEC_VERSION}"
            )));
        }
        let ty = r.get_str()?;
        if ty != Self::TYPE_NAME {
            return Err(Error::Corrupt(format!(
                "message type '{ty}', expected '{}'",
                Self::TYPE_NAME
            )));
        }
        let msg = Self::decode_body(&mut r)?;
        if !r.is_empty() {
            return Err(Error::Corrupt(format!(
                "{} trailing bytes after {ty}",
                r.remaining()
            )));
        }
        Ok(msg)
    }
}

/// Peek the type name of an encoded message without fully decoding it.
pub fn peek_type(buf: &[u8]) -> Result<String> {
    let mut r = ByteReader::new(buf);
    let _ = r.get_u8()?;
    r.get_str()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peek_matches_encode() {
        let img = Image::synthetic(4, 4, 0);
        let buf = img.encode();
        assert_eq!(peek_type(&buf).unwrap(), Image::TYPE_NAME);
    }

    #[test]
    fn wrong_type_rejected() {
        let img = Image::synthetic(2, 2, 0);
        let buf = img.encode();
        assert!(Imu::decode(&buf).is_err());
    }

    #[test]
    fn bad_version_rejected() {
        let img = Image::synthetic(2, 2, 0);
        let mut buf = img.encode();
        buf[0] = 99;
        assert!(Image::decode(&buf).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let img = Image::synthetic(2, 2, 0);
        let mut buf = img.encode();
        buf.push(0);
        assert!(Image::decode(&buf).is_err());
    }
}
