//! Sensor message types: camera images, LiDAR point clouds, IMU samples —
//! the payloads the paper's simulator plays back from bags.

use super::header::{Header, Time};
use super::Message;
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::prng::Prng;

/// Pixel layouts the platform understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PixelFormat {
    /// 8-bit RGB, row-major, 3 bytes/pixel.
    Rgb8,
    /// 8-bit grayscale.
    Mono8,
}

impl PixelFormat {
    /// Bytes per pixel for this format.
    pub fn bytes_per_pixel(self) -> usize {
        match self {
            PixelFormat::Rgb8 => 3,
            PixelFormat::Mono8 => 1,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            PixelFormat::Rgb8 => 0,
            PixelFormat::Mono8 => 1,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(PixelFormat::Rgb8),
            1 => Ok(PixelFormat::Mono8),
            other => Err(Error::Corrupt(format!("unknown pixel format {other}"))),
        }
    }
}

/// Raw camera frame (`sensor_msgs/Image` analogue).
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    /// Standard header.
    pub header: Header,
    /// Frame width (px).
    pub width: u32,
    /// Frame height (px).
    pub height: u32,
    /// Pixel layout of `data`.
    pub format: PixelFormat,
    /// Row-major pixel data, `height * width * bpp` bytes.
    pub data: Vec<u8>,
}

impl Image {
    /// Deterministic synthetic frame (used by tests and datagen).
    pub fn synthetic(width: u32, height: u32, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut data = vec![0u8; (width * height * 3) as usize];
        rng.fill_bytes(&mut data);
        Self {
            header: Header::new(seed, Time::from_nanos(seed.wrapping_mul(1000)), "camera"),
            width,
            height,
            format: PixelFormat::Rgb8,
            data,
        }
    }

    /// Consistency check between declared shape and payload size.
    pub fn validate(&self) -> Result<()> {
        let expect = self.width as usize * self.height as usize * self.format.bytes_per_pixel();
        if self.data.len() != expect {
            return Err(Error::Corrupt(format!(
                "image {}x{} expects {expect} bytes, has {}",
                self.width,
                self.height,
                self.data.len()
            )));
        }
        Ok(())
    }

    /// Convert to normalized f32 RGB in [0,1], NHWC layout for the
    /// perception runtime.
    pub fn to_f32_rgb(&self) -> Vec<f32> {
        match self.format {
            PixelFormat::Rgb8 => self.data.iter().map(|&b| b as f32 / 255.0).collect(),
            PixelFormat::Mono8 => self
                .data
                .iter()
                .flat_map(|&b| {
                    let v = b as f32 / 255.0;
                    [v, v, v]
                })
                .collect(),
        }
    }
}

impl Message for Image {
    const TYPE_NAME: &'static str = "av/sensor/Image";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        w.put_u32(self.width);
        w.put_u32(self.height);
        w.put_u8(self.format.to_u8());
        w.put_bytes(&self.data);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        let img = Self {
            header: Header::decode(r)?,
            width: r.get_u32()?,
            height: r.get_u32()?,
            format: PixelFormat::from_u8(r.get_u8()?)?,
            data: r.get_bytes_vec()?,
        };
        img.validate()?;
        Ok(img)
    }
}

/// JPEG-less "compressed" image: LZ-compressed RGB (`util::lz`; the
/// offline crate set has no `flate2`). Exists so bags can exercise the
/// compression path like `sensor_msgs/CompressedImage`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedImage {
    /// Standard header.
    pub header: Header,
    /// Frame width (px).
    pub width: u32,
    /// Frame height (px).
    pub height: u32,
    /// LZ-compressed RGB payload.
    pub payload: Vec<u8>,
}

impl CompressedImage {
    /// Compress a raw RGB image.
    pub fn compress(img: &Image) -> Result<Self> {
        if img.format != PixelFormat::Rgb8 {
            return Err(Error::Corrupt(
                "CompressedImage::compress expects an Rgb8 image".into(),
            ));
        }
        img.validate()?;
        Ok(Self {
            header: img.header.clone(),
            width: img.width,
            height: img.height,
            payload: crate::util::lz::compress(&img.data),
        })
    }

    /// Decompress back to a raw RGB image.
    pub fn decompress(&self) -> Result<Image> {
        let expect = self.width as usize * self.height as usize * 3;
        let data = crate::util::lz::decompress(&self.payload, expect)?;
        let img = Image {
            header: self.header.clone(),
            width: self.width,
            height: self.height,
            format: PixelFormat::Rgb8,
            data,
        };
        img.validate()?;
        Ok(img)
    }
}

impl Message for CompressedImage {
    const TYPE_NAME: &'static str = "av/sensor/CompressedImage";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        w.put_u32(self.width);
        w.put_u32(self.height);
        w.put_bytes(&self.payload);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self {
            header: Header::decode(r)?,
            width: r.get_u32()?,
            height: r.get_u32()?,
            payload: r.get_bytes_vec()?,
        })
    }
}

/// LiDAR scan as a flat XYZI point list (`sensor_msgs/PointCloud2`
/// analogue, fixed schema: x,y,z,intensity f32).
#[derive(Debug, Clone, PartialEq)]
pub struct PointCloud {
    /// Standard header.
    pub header: Header,
    /// len = 4 * num_points: [x0,y0,z0,i0, x1,...]
    pub points: Vec<f32>,
}

impl PointCloud {
    /// Number of XYZI points.
    pub fn num_points(&self) -> usize {
        self.points.len() / 4
    }

    /// Check the flat layout (length divisible by 4).
    pub fn validate(&self) -> Result<()> {
        if self.points.len() % 4 != 0 {
            return Err(Error::Corrupt(format!(
                "point cloud length {} not a multiple of 4",
                self.points.len()
            )));
        }
        Ok(())
    }

    /// (x, y, z, intensity) of point `i`.
    pub fn point(&self, i: usize) -> (f32, f32, f32, f32) {
        let o = i * 4;
        (self.points[o], self.points[o + 1], self.points[o + 2], self.points[o + 3])
    }

    /// Deterministic synthetic scan on a ring (tests / datagen).
    pub fn synthetic(n: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut points = Vec::with_capacity(n * 4);
        for k in 0..n {
            let ang = k as f32 / n as f32 * std::f32::consts::TAU;
            let r = 10.0 + rng.next_f32() * 2.0;
            points.extend_from_slice(&[
                r * ang.cos(),
                r * ang.sin(),
                rng.next_f32() * 2.0 - 1.0,
                rng.next_f32(),
            ]);
        }
        Self {
            header: Header::new(seed, Time::from_nanos(seed.wrapping_mul(1000)), "lidar"),
            points,
        }
    }
}

impl Message for PointCloud {
    const TYPE_NAME: &'static str = "av/sensor/PointCloud";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        w.put_f32_slice(&self.points);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        let pc = Self { header: Header::decode(r)?, points: r.get_f32_vec()? };
        pc.validate()?;
        Ok(pc)
    }
}

/// IMU sample: linear acceleration + angular velocity.
#[derive(Debug, Clone, PartialEq)]
pub struct Imu {
    /// Standard header.
    pub header: Header,
    /// Linear acceleration (m/s², xyz).
    pub accel: [f32; 3],
    /// Angular velocity (rad/s, xyz).
    pub gyro: [f32; 3],
}

impl Message for Imu {
    const TYPE_NAME: &'static str = "av/sensor/Imu";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        for v in self.accel.iter().chain(self.gyro.iter()) {
            w.put_f32(*v);
        }
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        let header = Header::decode(r)?;
        let mut vals = [0f32; 6];
        for v in &mut vals {
            *v = r.get_f32()?;
        }
        Ok(Self {
            header,
            accel: [vals[0], vals[1], vals[2]],
            gyro: [vals[3], vals[4], vals[5]],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_roundtrip() {
        let img = Image::synthetic(8, 6, 3);
        let buf = img.encode();
        assert_eq!(Image::decode(&buf).unwrap(), img);
    }

    #[test]
    fn image_shape_mismatch_rejected() {
        let mut img = Image::synthetic(4, 4, 0);
        img.data.pop();
        let mut w = ByteWriter::new();
        w.put_u8(super::super::MSG_CODEC_VERSION);
        w.put_str(Image::TYPE_NAME);
        img.encode_body(&mut w);
        assert!(Image::decode(&w.into_vec()).is_err());
    }

    #[test]
    fn image_to_f32_normalizes() {
        let img = Image {
            header: Header::default(),
            width: 1,
            height: 1,
            format: PixelFormat::Rgb8,
            data: vec![0, 128, 255],
        };
        let f = img.to_f32_rgb();
        assert_eq!(f.len(), 3);
        assert!(f[0] == 0.0 && (f[1] - 128.0 / 255.0).abs() < 1e-6 && f[2] == 1.0);
    }

    #[test]
    fn mono_to_f32_replicates_channels() {
        let img = Image {
            header: Header::default(),
            width: 2,
            height: 1,
            format: PixelFormat::Mono8,
            data: vec![255, 0],
        };
        assert_eq!(img.to_f32_rgb(), vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn compressed_image_roundtrip() {
        let img = Image::synthetic(16, 16, 1);
        let c = CompressedImage::compress(&img).unwrap();
        let back = c.decompress().unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn pointcloud_roundtrip_and_access() {
        let pc = PointCloud::synthetic(128, 9);
        assert_eq!(pc.num_points(), 128);
        let buf = pc.encode();
        let back = PointCloud::decode(&buf).unwrap();
        assert_eq!(back, pc);
        let (x, y, _z, i) = pc.point(0);
        assert!(x.is_finite() && y.is_finite() && (0.0..=1.0).contains(&i));
    }

    #[test]
    fn pointcloud_ragged_rejected() {
        let pc = PointCloud {
            header: Header::default(),
            points: vec![1.0, 2.0, 3.0],
        };
        assert!(pc.validate().is_err());
    }

    #[test]
    fn imu_roundtrip() {
        let imu = Imu {
            header: Header::new(1, Time::from_nanos(5), "imu"),
            accel: [0.1, -0.2, 9.8],
            gyro: [0.01, 0.0, -0.03],
        };
        let buf = imu.encode();
        assert_eq!(Imu::decode(&buf).unwrap(), imu);
    }
}
