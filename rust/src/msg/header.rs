//! Message header: timestamp + sequence + frame, mirroring
//! `std_msgs/Header`. Bag playback ordering and the sim clock are driven
//! by [`Time`].

use crate::error::Result;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Nanosecond-resolution timestamp (like `ros::Time`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time {
    /// Nanoseconds since the bag epoch.
    pub nanos: u64,
}

impl Time {
    /// Time zero.
    pub const ZERO: Time = Time { nanos: 0 };

    /// Time from nanoseconds.
    pub fn from_nanos(nanos: u64) -> Self {
        Self { nanos }
    }

    /// Time from seconds (saturating at 0 for negatives).
    pub fn from_secs_f64(secs: f64) -> Self {
        Self { nanos: (secs.max(0.0) * 1e9) as u64 }
    }

    /// Seconds as `f64`.
    pub fn as_secs_f64(self) -> f64 {
        self.nanos as f64 / 1e9
    }

    /// `self - other`, clamped at zero.
    pub fn saturating_sub(self, other: Time) -> std::time::Duration {
        std::time::Duration::from_nanos(self.nanos.saturating_sub(other.nanos))
    }

    /// `self + d` nanoseconds.
    pub fn add_nanos(self, d: u64) -> Time {
        Time { nanos: self.nanos + d }
    }
}

/// Standard message header.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Header {
    /// Monotonic per-publisher sequence number.
    pub seq: u64,
    /// Acquisition / publication timestamp.
    pub stamp: Time,
    /// Coordinate frame id ("base_link", "camera", "lidar", …).
    pub frame_id: String,
}

impl Header {
    /// Header with sequence number, stamp and frame id.
    pub fn new(seq: u64, stamp: Time, frame_id: impl Into<String>) -> Self {
        Self { seq, stamp, frame_id: frame_id.into() }
    }

    /// Append the wire encoding to `w`.
    pub fn encode(&self, w: &mut ByteWriter) {
        w.put_u64(self.seq);
        w.put_u64(self.stamp.nanos);
        w.put_str(&self.frame_id);
    }

    /// Decode a header from `r`.
    pub fn decode(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self {
            seq: r.get_u64()?,
            stamp: Time::from_nanos(r.get_u64()?),
            frame_id: r.get_str()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions() {
        let t = Time::from_secs_f64(1.5);
        assert_eq!(t.nanos, 1_500_000_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-9);
        assert_eq!(Time::from_secs_f64(-3.0), Time::ZERO);
    }

    #[test]
    fn time_ordering() {
        assert!(Time::from_nanos(5) < Time::from_nanos(6));
        let d = Time::from_nanos(10).saturating_sub(Time::from_nanos(4));
        assert_eq!(d.as_nanos(), 6);
        let z = Time::from_nanos(4).saturating_sub(Time::from_nanos(10));
        assert_eq!(z.as_nanos(), 0);
    }

    #[test]
    fn header_roundtrip() {
        let h = Header::new(7, Time::from_nanos(123), "camera");
        let mut w = ByteWriter::new();
        h.encode(&mut w);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(Header::decode(&mut r).unwrap(), h);
    }
}
