//! Vehicle-state and perception-output message types: poses, twists,
//! control commands, detections — what the decision/control modules under
//! test consume and produce.

use super::header::Header;
use super::Message;
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};

/// 2D pose + heading (the platform's planar world).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Pose {
    /// x position (m).
    pub x: f64,
    /// y position (m).
    pub y: f64,
    /// Heading in radians, CCW from +x.
    pub yaw: f64,
}

impl Pose {
    /// Euclidean distance to `other`.
    pub fn distance(&self, other: &Pose) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// Stamped pose message.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PoseStamped {
    /// Standard header.
    pub header: Header,
    /// The pose.
    pub pose: Pose,
}

impl Message for PoseStamped {
    const TYPE_NAME: &'static str = "av/state/PoseStamped";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        w.put_f64(self.pose.x);
        w.put_f64(self.pose.y);
        w.put_f64(self.pose.yaw);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self {
            header: Header::decode(r)?,
            pose: Pose { x: r.get_f64()?, y: r.get_f64()?, yaw: r.get_f64()? },
        })
    }
}

/// Linear + angular velocity.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Twist {
    /// Forward speed, m/s.
    pub v: f64,
    /// Yaw rate, rad/s.
    pub omega: f64,
}

impl Message for Twist {
    const TYPE_NAME: &'static str = "av/state/Twist";

    fn encode_body(&self, w: &mut ByteWriter) {
        w.put_f64(self.v);
        w.put_f64(self.omega);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self { v: r.get_f64()?, omega: r.get_f64()? })
    }
}

/// Control command from the controller under test.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ControlCommand {
    /// Longitudinal acceleration command, m/s² (negative = brake).
    pub accel: f64,
    /// Front-wheel steering angle, rad.
    pub steer: f64,
}

impl ControlCommand {
    /// Clamp to physical actuator limits.
    pub fn clamped(self) -> Self {
        Self {
            accel: self.accel.clamp(-8.0, 3.0),
            steer: self.steer.clamp(-0.6, 0.6),
        }
    }
}

impl Message for ControlCommand {
    const TYPE_NAME: &'static str = "av/state/ControlCommand";

    fn encode_body(&self, w: &mut ByteWriter) {
        w.put_f64(self.accel);
        w.put_f64(self.steer);
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        Ok(Self { accel: r.get_f64()?, steer: r.get_f64()? })
    }
}

/// One detected object in image or world coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Class index into the perception label set.
    pub class_id: u32,
    /// Class label (denormalized for log readability).
    pub label: String,
    /// Confidence in [0, 1].
    pub score: f32,
    /// Bounding box (x, y, w, h) in pixels, or world extent.
    pub bbox: [f32; 4],
}

/// Detections for one frame.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DetectionArray {
    /// Standard header.
    pub header: Header,
    /// Detections in this frame.
    pub detections: Vec<Detection>,
}

impl Message for DetectionArray {
    const TYPE_NAME: &'static str = "av/perception/DetectionArray";

    fn encode_body(&self, w: &mut ByteWriter) {
        self.header.encode(w);
        w.put_varint(self.detections.len() as u64);
        for d in &self.detections {
            w.put_u32(d.class_id);
            w.put_str(&d.label);
            w.put_f32(d.score);
            for v in d.bbox {
                w.put_f32(v);
            }
        }
    }

    fn decode_body(r: &mut ByteReader<'_>) -> Result<Self> {
        let header = Header::decode(r)?;
        let n = r.get_varint()? as usize;
        if n > 1_000_000 {
            return Err(Error::Corrupt(format!("absurd detection count {n}")));
        }
        let mut detections = Vec::with_capacity(n);
        for _ in 0..n {
            let class_id = r.get_u32()?;
            let label = r.get_str()?;
            let score = r.get_f32()?;
            let mut bbox = [0f32; 4];
            for v in &mut bbox {
                *v = r.get_f32()?;
            }
            detections.push(Detection { class_id, label, score, bbox });
        }
        Ok(Self { header, detections })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::header::Time;

    #[test]
    fn pose_distance() {
        let a = Pose { x: 0.0, y: 0.0, yaw: 0.0 };
        let b = Pose { x: 3.0, y: 4.0, yaw: 1.0 };
        assert!((a.distance(&b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn pose_stamped_roundtrip() {
        let p = PoseStamped {
            header: Header::new(3, Time::from_nanos(77), "map"),
            pose: Pose { x: 1.5, y: -2.5, yaw: 0.25 },
        };
        assert_eq!(PoseStamped::decode(&p.encode()).unwrap(), p);
    }

    #[test]
    fn twist_and_control_roundtrip() {
        let t = Twist { v: 11.1, omega: -0.3 };
        assert_eq!(Twist::decode(&t.encode()).unwrap(), t);
        let c = ControlCommand { accel: -2.0, steer: 0.1 };
        assert_eq!(ControlCommand::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn control_clamps_to_actuator_limits() {
        let c = ControlCommand { accel: -99.0, steer: 9.0 }.clamped();
        assert_eq!(c.accel, -8.0);
        assert_eq!(c.steer, 0.6);
    }

    #[test]
    fn detection_array_roundtrip() {
        let d = DetectionArray {
            header: Header::new(1, Time::from_nanos(9), "camera"),
            detections: vec![
                Detection {
                    class_id: 2,
                    label: "pedestrian".into(),
                    score: 0.93,
                    bbox: [10.0, 20.0, 30.0, 40.0],
                },
                Detection {
                    class_id: 0,
                    label: "vehicle".into(),
                    score: 0.5,
                    bbox: [0.0; 4],
                },
            ],
        };
        assert_eq!(DetectionArray::decode(&d.encode()).unwrap(), d);
    }

    #[test]
    fn empty_detection_array_ok() {
        let d = DetectionArray::default();
        assert_eq!(DetectionArray::decode(&d.encode()).unwrap(), d);
    }
}
