//! Bag → bus playback ("rosbag play", paper §2.1) and bus → bag recording
//! glue. The play node walks a bag reader and republishes every message
//! onto the live broker, pacing against a [`SimClock`].

use super::clock::{Pace, SimClock};
use super::Broker;
use crate::bag::{BagReader, ChunkStore};
use crate::error::Result;
use std::time::Instant;

/// Options for [`play_bag`].
#[derive(Debug, Clone)]
pub struct PlayOptions {
    /// Pace (free-run for batch simulation, rate for interactive).
    pub pace: Pace,
    /// Only these topics (None = all).
    pub topics: Option<Vec<String>>,
}

impl Default for PlayOptions {
    fn default() -> Self {
        Self { pace: Pace::FreeRun, topics: None }
    }
}

/// Play a bag onto a broker. Topics are auto-advertised from the bag's
/// connection records; returns the number of messages published.
///
/// Publishing uses the raw path (payloads are already encoded in the
/// bag), so playback does not re-encode — the hot loop is: read chunk,
/// split messages, fan out.
pub fn play_bag<S: ChunkStore>(
    reader: &mut BagReader<S>,
    broker: &Broker,
    clock: &SimClock,
    opts: &PlayOptions,
) -> Result<u64> {
    // Pre-register every connection's topic with its recorded type so
    // type checking applies to live subscribers.
    for conn in reader.connections().to_vec() {
        broker_register(broker, &conn.topic, &conn.type_name)?;
    }
    let (bag_start, _) = match reader.time_range() {
        Some(r) => r,
        None => return Ok(0),
    };
    let wall_start = Instant::now();
    let topic_refs: Option<Vec<&str>> = opts
        .topics
        .as_ref()
        .map(|v| v.iter().map(|s| s.as_str()).collect());
    let mut published = 0u64;
    reader.for_each(topic_refs.as_deref(), |m| {
        clock.pace_for(bag_start, wall_start, m.time);
        broker_publish_raw(broker, &m.topic, m.data)?;
        published += 1;
        Ok(())
    })?;
    Ok(published)
}

// Raw-bytes access into Broker internals, kept here so Broker's public
// surface stays typed.
fn broker_register(broker: &Broker, topic: &str, type_name: &str) -> Result<()> {
    broker.check_type(topic, type_name)
}

fn broker_publish_raw(broker: &Broker, topic: &str, payload: Vec<u8>) -> Result<()> {
    broker.publish_raw(topic, payload).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bag::{BagWriter, Compression, MemoryChunkedFile};
    use crate::bus::QoS;
    use crate::msg::{Image, Time};
    use std::time::Duration;

    fn bag_with_frames(n: u64) -> MemoryChunkedFile {
        let mut w = BagWriter::new(MemoryChunkedFile::new(), Compression::None, 1 << 16).unwrap();
        for i in 0..n {
            w.write("/camera", Time::from_nanos(i * 1_000_000), &Image::synthetic(8, 8, i))
                .unwrap();
        }
        w.finish().unwrap()
    }

    #[test]
    fn playback_reaches_subscribers() {
        let store = bag_with_frames(10);
        let mut reader = BagReader::open(store).unwrap();
        let broker = Broker::new();
        let sub = broker.subscribe::<Image>("/camera", QoS::lossless(64)).unwrap();
        let clock = SimClock::new(Pace::FreeRun);
        let n = play_bag(&mut reader, &broker, &clock, &PlayOptions::default()).unwrap();
        assert_eq!(n, 10);
        let mut got = 0;
        while let Some(Ok(img)) = sub.recv_timeout(Duration::from_millis(200)) {
            assert_eq!(img.width, 8);
            got += 1;
            if got == 10 {
                break;
            }
        }
        assert_eq!(got, 10);
        // clock advanced to the last stamp
        assert_eq!(clock.now(), Time::from_nanos(9 * 1_000_000));
    }

    #[test]
    fn playback_respects_topic_filter() {
        let mut w =
            BagWriter::new(MemoryChunkedFile::new(), Compression::None, 1 << 16).unwrap();
        w.write("/camera", Time::from_nanos(0), &Image::synthetic(4, 4, 0)).unwrap();
        w.write("/camera2", Time::from_nanos(1), &Image::synthetic(4, 4, 1)).unwrap();
        let store = w.finish().unwrap();
        let mut reader = BagReader::open(store).unwrap();
        let broker = Broker::new();
        let clock = SimClock::new(Pace::FreeRun);
        let opts = PlayOptions { pace: Pace::FreeRun, topics: Some(vec!["/camera2".into()]) };
        let n = play_bag(&mut reader, &broker, &clock, &opts).unwrap();
        assert_eq!(n, 1);
    }

    #[test]
    fn empty_bag_plays_zero() {
        let w = BagWriter::new(MemoryChunkedFile::new(), Compression::None, 1 << 16).unwrap();
        let store = w.finish().unwrap();
        let mut reader = BagReader::open(store).unwrap();
        let broker = Broker::new();
        let clock = SimClock::new(Pace::FreeRun);
        assert_eq!(play_bag(&mut reader, &broker, &clock, &PlayOptions::default()).unwrap(), 0);
    }
}
