//! Simulation clock for bag playback.
//!
//! Bag playback can run "as fast as possible" (rate = ∞, the batch
//! simulation mode the paper's Spark workers use) or paced against wall
//! time at a rate multiplier like `rosbag play -r`.

use crate::msg::Time;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Playback pacing mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pace {
    /// No sleeping — replay as fast as the consumers can go.
    FreeRun,
    /// Real-time multiplier (1.0 = recorded speed).
    Rate(f64),
}

/// Shared simulation clock: tracks "now" in bag time.
#[derive(Clone)]
pub struct SimClock {
    now_nanos: Arc<AtomicU64>,
    pace: Pace,
}

impl SimClock {
    /// Clock starting at time zero with the given pacing.
    pub fn new(pace: Pace) -> Self {
        Self { now_nanos: Arc::new(AtomicU64::new(0)), pace }
    }

    /// Current simulation time.
    pub fn now(&self) -> Time {
        Time::from_nanos(self.now_nanos.load(Ordering::Acquire))
    }

    /// Advance sim time to `t` (monotonic; earlier times are ignored).
    pub fn advance_to(&self, t: Time) {
        self.now_nanos.fetch_max(t.nanos, Ordering::AcqRel);
    }

    /// The pacing mode this clock was created with.
    pub fn pace(&self) -> Pace {
        self.pace
    }

    /// Sleep as needed so that message stamped `msg_time` (relative to
    /// `bag_start`) is released on schedule given the pace and the wall
    /// clock `wall_start` of playback. FreeRun never sleeps.
    pub fn pace_for(&self, bag_start: Time, wall_start: Instant, msg_time: Time) {
        if let Pace::Rate(r) = self.pace {
            if r <= 0.0 {
                return;
            }
            let bag_elapsed = msg_time.saturating_sub(bag_start).as_secs_f64();
            let target_wall = bag_elapsed / r;
            let actual_wall = wall_start.elapsed().as_secs_f64();
            if target_wall > actual_wall {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    target_wall - actual_wall,
                ));
            }
        }
        self.advance_to(msg_time);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let c = SimClock::new(Pace::FreeRun);
        c.advance_to(Time::from_nanos(100));
        c.advance_to(Time::from_nanos(50)); // ignored
        assert_eq!(c.now(), Time::from_nanos(100));
    }

    #[test]
    fn free_run_does_not_sleep() {
        let c = SimClock::new(Pace::FreeRun);
        let t = Instant::now();
        c.pace_for(Time::ZERO, Instant::now(), Time::from_secs_f64(100.0));
        assert!(t.elapsed().as_millis() < 50);
        assert_eq!(c.now(), Time::from_secs_f64(100.0));
    }

    #[test]
    fn rate_paces_playback() {
        let c = SimClock::new(Pace::Rate(10.0)); // 10x speed
        let wall = Instant::now();
        // message 0.2s into the bag should release at ~20ms wall
        c.pace_for(Time::ZERO, wall, Time::from_secs_f64(0.2));
        let el = wall.elapsed().as_millis();
        assert!(el >= 15, "released too early: {el}ms");
        assert!(el < 200, "released too late: {el}ms");
    }

    #[test]
    fn shared_view_across_clones() {
        let c = SimClock::new(Pace::FreeRun);
        let c2 = c.clone();
        c.advance_to(Time::from_nanos(7));
        assert_eq!(c2.now(), Time::from_nanos(7));
    }
}
