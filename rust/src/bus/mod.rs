//! In-process pub/sub message bus — the platform's ROS analogue (§2).
//!
//! The paper's architecture runs one ROS graph per Spark worker: functional
//! modules are *nodes*, they `advertise` publishers and `subscribe`
//! subscribers on named, typed *topics*, and a rosbag play node feeds them
//! recorded sensor data. This module provides exactly that graph:
//!
//! * [`Broker`] — the message pool: topic registry with type checking.
//! * [`Node`] — a named participant that creates publishers/subscribers.
//! * [`Publisher<M>`] / [`Subscriber<M>`] — typed endpoints; payloads are
//!   encoded once and fanned out as `Arc<[u8]>`.
//! * QoS: bounded subscriber queues with configurable overflow policy
//!   (drop-oldest like ROS, or block for lossless pipelines).
//! * [`SimClock`] — playback clock for bag-driven time.

pub mod clock;
pub mod node;
pub mod player;

pub use clock::SimClock;
pub use node::Node;
pub use player::{play_bag, PlayOptions};

use crate::error::{Error, Result};
use crate::msg::Message;
use std::collections::{HashMap, VecDeque};
use std::marker::PhantomData;
use std::sync::{Arc, Condvar, Mutex};

/// Queue overflow behaviour for a subscriber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the oldest queued message (sensor-style, ROS default).
    DropOldest,
    /// Block the publisher until space frees (lossless pipelines).
    Block,
}

/// Subscriber quality-of-service.
#[derive(Debug, Clone, Copy)]
pub struct QoS {
    /// Max queued messages per subscriber.
    pub depth: usize,
    /// What happens when the queue is full.
    pub overflow: OverflowPolicy,
}

impl Default for QoS {
    fn default() -> Self {
        Self { depth: 64, overflow: OverflowPolicy::DropOldest }
    }
}

impl QoS {
    /// Blocking QoS: publishers wait instead of dropping.
    pub fn lossless(depth: usize) -> Self {
        Self { depth, overflow: OverflowPolicy::Block }
    }

    /// Sensor QoS: oldest messages are dropped on overflow.
    pub fn sensor(depth: usize) -> Self {
        Self { depth, overflow: OverflowPolicy::DropOldest }
    }
}

/// A raw published sample: encoded payload shared across subscribers.
type Sample = Arc<Vec<u8>>;

struct SubQueue {
    q: Mutex<SubQueueState>,
    cv: Condvar,
    qos: QoS,
}

struct SubQueueState {
    buf: VecDeque<Sample>,
    closed: bool,
    dropped: u64,
}

impl SubQueue {
    fn new(qos: QoS) -> Self {
        Self {
            q: Mutex::new(SubQueueState { buf: VecDeque::new(), closed: false, dropped: 0 }),
            cv: Condvar::new(),
            qos,
        }
    }

    fn push(&self, s: Sample) {
        let mut g = self.q.lock().unwrap();
        if g.closed {
            return;
        }
        match self.qos.overflow {
            OverflowPolicy::DropOldest => {
                if g.buf.len() >= self.qos.depth {
                    g.buf.pop_front();
                    g.dropped += 1;
                }
                g.buf.push_back(s);
            }
            OverflowPolicy::Block => {
                while g.buf.len() >= self.qos.depth && !g.closed {
                    g = self.cv.wait(g).unwrap();
                }
                if !g.closed {
                    g.buf.push_back(s);
                }
            }
        }
        self.cv.notify_all();
    }

    fn pop_timeout(&self, timeout: std::time::Duration) -> Option<Sample> {
        let mut g = self.q.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(s) = g.buf.pop_front() {
                self.cv.notify_all();
                return Some(s);
            }
            if g.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g2, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
            if res.timed_out() && g.buf.is_empty() {
                return None;
            }
        }
    }

    fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

struct Topic {
    type_name: String,
    subs: Vec<Arc<SubQueue>>,
    publish_count: u64,
}

/// The message pool: topic registry + fan-out.
#[derive(Clone, Default)]
pub struct Broker {
    topics: Arc<Mutex<HashMap<String, Topic>>>,
}

impl Broker {
    /// Empty broker with no topics.
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn check_type(&self, topic: &str, type_name: &str) -> Result<()> {
        let mut g = self.topics.lock().unwrap();
        match g.get(topic) {
            Some(t) if t.type_name != type_name => Err(Error::Bus(format!(
                "topic '{topic}' is {} but endpoint wants {type_name}",
                t.type_name
            ))),
            Some(_) => Ok(()),
            None => {
                g.insert(
                    topic.to_string(),
                    Topic { type_name: type_name.to_string(), subs: Vec::new(), publish_count: 0 },
                );
                Ok(())
            }
        }
    }

    /// Advertise a typed publisher on `topic`.
    pub fn advertise<M: Message>(&self, topic: &str) -> Result<Publisher<M>> {
        self.check_type(topic, M::TYPE_NAME)?;
        Ok(Publisher { broker: self.clone(), topic: topic.to_string(), _m: PhantomData })
    }

    /// Subscribe with QoS; returns a typed receiving endpoint.
    pub fn subscribe<M: Message>(&self, topic: &str, qos: QoS) -> Result<Subscriber<M>> {
        self.check_type(topic, M::TYPE_NAME)?;
        let q = Arc::new(SubQueue::new(qos));
        self.topics
            .lock()
            .unwrap()
            .get_mut(topic)
            .expect("registered above")
            .subs
            .push(q.clone());
        Ok(Subscriber { queue: q, _m: PhantomData })
    }

    pub(crate) fn publish_raw(&self, topic: &str, payload: Vec<u8>) -> Result<usize> {
        let subs: Vec<Arc<SubQueue>> = {
            let mut g = self.topics.lock().unwrap();
            let t = g
                .get_mut(topic)
                .ok_or_else(|| Error::Bus(format!("publish to unknown topic '{topic}'")))?;
            t.publish_count += 1;
            t.subs.clone()
        };
        let sample: Sample = Arc::new(payload);
        for s in &subs {
            s.push(sample.clone());
        }
        Ok(subs.len())
    }

    /// Topics currently known, with type and publish count.
    pub fn topic_info(&self) -> Vec<(String, String, u64)> {
        let g = self.topics.lock().unwrap();
        let mut v: Vec<_> = g
            .iter()
            .map(|(k, t)| (k.clone(), t.type_name.clone(), t.publish_count))
            .collect();
        v.sort();
        v
    }

    /// Close every subscriber queue (graph shutdown).
    pub fn shutdown(&self) {
        let g = self.topics.lock().unwrap();
        for t in g.values() {
            for s in &t.subs {
                s.close();
            }
        }
    }
}

/// Typed publishing endpoint.
pub struct Publisher<M: Message> {
    broker: Broker,
    topic: String,
    _m: PhantomData<M>,
}

impl<M: Message> Publisher<M> {
    /// Publish a message; returns the number of subscribers reached.
    pub fn publish(&self, msg: &M) -> Result<usize> {
        self.broker.publish_raw(&self.topic, msg.encode())
    }

    /// The topic this publisher writes to.
    pub fn topic(&self) -> &str {
        &self.topic
    }
}

/// Typed subscribing endpoint.
pub struct Subscriber<M: Message> {
    queue: Arc<SubQueue>,
    _m: PhantomData<M>,
}

impl<M: Message> Subscriber<M> {
    /// Blocking receive with timeout. `None` on timeout or closed-empty.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<Result<M>> {
        self.queue.pop_timeout(timeout).map(|s| M::decode(&s))
    }

    /// Non-blocking poll.
    pub fn try_recv(&self) -> Option<Result<M>> {
        self.queue
            .pop_timeout(std::time::Duration::ZERO)
            .map(|s| M::decode(&s))
    }

    /// Messages dropped due to queue overflow (QoS accounting).
    pub fn dropped(&self) -> u64 {
        self.queue.q.lock().unwrap().dropped
    }
}

impl<M: Message> Drop for Subscriber<M> {
    fn drop(&mut self) {
        self.queue.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::{Image, Imu};
    use std::time::Duration;

    #[test]
    fn pub_sub_roundtrip() {
        let b = Broker::new();
        let sub = b.subscribe::<Image>("/camera", QoS::default()).unwrap();
        let pb = b.advertise::<Image>("/camera").unwrap();
        let img = Image::synthetic(4, 4, 1);
        assert_eq!(pb.publish(&img).unwrap(), 1);
        let got = sub.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(got, img);
    }

    #[test]
    fn type_mismatch_rejected() {
        let b = Broker::new();
        let _p = b.advertise::<Image>("/camera").unwrap();
        assert!(b.subscribe::<Imu>("/camera", QoS::default()).is_err());
        assert!(b.advertise::<Imu>("/camera").is_err());
    }

    #[test]
    fn fanout_to_multiple_subscribers() {
        let b = Broker::new();
        let s1 = b.subscribe::<Imu>("/imu", QoS::default()).unwrap();
        let s2 = b.subscribe::<Imu>("/imu", QoS::default()).unwrap();
        let p = b.advertise::<Imu>("/imu").unwrap();
        let m = Imu {
            header: Default::default(),
            accel: [1.0, 2.0, 3.0],
            gyro: [0.0; 3],
        };
        assert_eq!(p.publish(&m).unwrap(), 2);
        assert!(s1.recv_timeout(Duration::from_millis(100)).is_some());
        assert!(s2.recv_timeout(Duration::from_millis(100)).is_some());
    }

    #[test]
    fn drop_oldest_overflow() {
        let b = Broker::new();
        let s = b
            .subscribe::<Imu>("/imu", QoS { depth: 2, overflow: OverflowPolicy::DropOldest })
            .unwrap();
        let p = b.advertise::<Imu>("/imu").unwrap();
        for i in 0..5 {
            let m = Imu {
                header: crate::msg::Header::new(i, Default::default(), "imu"),
                accel: [i as f32; 3],
                gyro: [0.0; 3],
            };
            p.publish(&m).unwrap();
        }
        assert_eq!(s.dropped(), 3);
        let first = s.recv_timeout(Duration::from_millis(10)).unwrap().unwrap();
        assert_eq!(first.header.seq, 3, "oldest were dropped");
    }

    #[test]
    fn blocking_qos_is_lossless() {
        let b = Broker::new();
        let s = b.subscribe::<Imu>("/imu", QoS::lossless(2)).unwrap();
        let p = b.advertise::<Imu>("/imu").unwrap();
        let t = std::thread::spawn(move || {
            for i in 0..20 {
                let m = Imu {
                    header: crate::msg::Header::new(i, Default::default(), "imu"),
                    accel: [0.0; 3],
                    gyro: [0.0; 3],
                };
                p.publish(&m).unwrap();
            }
        });
        let mut got = 0;
        while let Some(Ok(_)) = s.recv_timeout(Duration::from_millis(500)) {
            got += 1;
            if got == 20 {
                break;
            }
        }
        t.join().unwrap();
        assert_eq!(got, 20);
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn publish_without_topic_errors() {
        let b = Broker::new();
        assert!(b.publish_raw("/ghost", vec![1]).is_err());
    }

    #[test]
    fn recv_timeout_expires() {
        let b = Broker::new();
        let s = b.subscribe::<Imu>("/imu", QoS::default()).unwrap();
        let t = std::time::Instant::now();
        assert!(s.recv_timeout(Duration::from_millis(30)).is_none());
        assert!(t.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn topic_info_lists_counts() {
        let b = Broker::new();
        let p = b.advertise::<Imu>("/imu").unwrap();
        let m = Imu { header: Default::default(), accel: [0.0; 3], gyro: [0.0; 3] };
        p.publish(&m).unwrap();
        p.publish(&m).unwrap();
        let info = b.topic_info();
        assert_eq!(info.len(), 1);
        assert_eq!(info[0], ("/imu".to_string(), Imu::TYPE_NAME.to_string(), 2));
    }
}
