//! [`Node`] — a named participant in the bus graph, the platform's
//! `ros::NodeHandle`. Functional modules (perception, decision, control,
//! bag play/record) each own a node; the node remembers its endpoints for
//! introspection (`rosnode info` analogue).

use super::{Broker, Publisher, QoS, Subscriber};
use crate::error::Result;
use crate::msg::Message;
use std::sync::{Arc, Mutex};

/// Endpoint descriptor for introspection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EndpointInfo {
    /// Topic name.
    pub topic: String,
    /// Message type on the topic.
    pub type_name: String,
    /// Whether this endpoint publishes or subscribes.
    pub kind: EndpointKind,
}

/// Which side of a topic an endpoint is on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EndpointKind {
    /// The endpoint publishes.
    Publisher,
    /// The endpoint subscribes.
    Subscriber,
}

/// A named bus participant.
pub struct Node {
    name: String,
    broker: Broker,
    endpoints: Arc<Mutex<Vec<EndpointInfo>>>,
}

impl Node {
    /// A node named `name` on `broker`.
    pub fn new(broker: &Broker, name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            broker: broker.clone(),
            endpoints: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// The node's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Advertise a typed publisher (records the endpoint).
    pub fn advertise<M: Message>(&self, topic: &str) -> Result<Publisher<M>> {
        let p = self.broker.advertise::<M>(topic)?;
        self.endpoints.lock().unwrap().push(EndpointInfo {
            topic: topic.to_string(),
            type_name: M::TYPE_NAME.to_string(),
            kind: EndpointKind::Publisher,
        });
        Ok(p)
    }

    /// Subscribe with explicit QoS.
    pub fn subscribe<M: Message>(&self, topic: &str, qos: QoS) -> Result<Subscriber<M>> {
        let s = self.broker.subscribe::<M>(topic, qos)?;
        self.endpoints.lock().unwrap().push(EndpointInfo {
            topic: topic.to_string(),
            type_name: M::TYPE_NAME.to_string(),
            kind: EndpointKind::Subscriber,
        });
        Ok(s)
    }

    /// Subscribe with default QoS.
    pub fn subscribe_default<M: Message>(&self, topic: &str) -> Result<Subscriber<M>> {
        self.subscribe::<M>(topic, QoS::default())
    }

    /// This node's registered endpoints.
    pub fn endpoints(&self) -> Vec<EndpointInfo> {
        self.endpoints.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::Imu;
    use std::time::Duration;

    #[test]
    fn node_tracks_endpoints() {
        let b = Broker::new();
        let n = Node::new(&b, "perception");
        let _s = n.subscribe_default::<Imu>("/imu").unwrap();
        let _p = n.advertise::<Imu>("/imu_filtered").unwrap();
        let eps = n.endpoints();
        assert_eq!(eps.len(), 2);
        assert_eq!(eps[0].kind, EndpointKind::Subscriber);
        assert_eq!(eps[1].kind, EndpointKind::Publisher);
        assert_eq!(n.name(), "perception");
    }

    #[test]
    fn nodes_communicate_through_broker() {
        let b = Broker::new();
        let sensor = Node::new(&b, "sensor");
        let fusion = Node::new(&b, "fusion");
        let sub = fusion.subscribe_default::<Imu>("/imu").unwrap();
        let pb = sensor.advertise::<Imu>("/imu").unwrap();
        let m = Imu { header: Default::default(), accel: [1.0; 3], gyro: [2.0; 3] };
        pb.publish(&m).unwrap();
        assert_eq!(sub.recv_timeout(Duration::from_secs(1)).unwrap().unwrap(), m);
    }
}
