//! `BagIndex` — a footer-independent scan of an AVBAG into a time/topic
//! index with replay cut points.
//!
//! The distributed bag-replay subsystem (`sim::replay`) partitions a
//! recorded drive by time slice, exactly the paper's data-playback
//! model. Planning those slices needs facts the reader's footer index
//! does not carry: per-topic message counts, per-topic time spans, the
//! largest inter-message gap per topic (which bounds the warm-up prefix
//! a slice needs before its perception state has converged), and
//! balanced cut points over the global timeline.
//!
//! `BagIndex::scan` walks the record stream from the top of the file —
//! it never trusts the footer — so it doubles as the bag *validator*:
//! a chunk with zero messages, a record that extends past the end of
//! the file (the classic truncated-trailing-chunk corruption), CRC
//! damage, or an unknown record type all surface as typed
//! [`Error::BagFormat`] errors naming the byte offset.

use super::chunked_file::ChunkStore;
use super::format::{self, ChunkInfo};
use crate::error::{Error, Result};
use crate::msg::Time;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

/// Per-topic facts gathered by a [`BagIndex::scan`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicIndex {
    /// Messages recorded on the topic.
    pub messages: u64,
    /// Message type on the topic (from its connection record).
    pub type_name: String,
    /// Earliest message timestamp.
    pub first: Time,
    /// Latest message timestamp.
    pub last: Time,
    /// Largest gap between consecutive messages (time order), in nanos.
    /// Zero for topics with fewer than two messages. An overlapping
    /// replay slice whose warm-up prefix is at least this long is
    /// guaranteed to see the predecessor of its first in-window message.
    pub max_gap: u64,
}

/// Time/topic index of one bag, built by scanning every chunk.
///
/// Unlike [`super::BagReader`] (which reads the footer index), a
/// `BagIndex` re-derives everything from the record stream, holding all
/// message timestamps (8 bytes per message) — the price of exact,
/// chunk-layout-independent cut points.
#[derive(Debug, Clone, PartialEq)]
pub struct BagIndex {
    /// Chunk records in file order, re-derived from the scan (offsets
    /// and stored lengths verified against the actual bytes).
    pub chunks: Vec<ChunkInfo>,
    /// Per-topic index, keyed by topic name.
    pub topics: BTreeMap<String, TopicIndex>,
    /// Total messages in the bag.
    pub messages: u64,
    /// Every message timestamp in the bag, sorted ascending (nanos).
    pub times: Vec<u64>,
    /// Bytes scanned (the bag's total size).
    pub bytes: u64,
}

impl BagIndex {
    /// Scan a bag from any [`ChunkStore`]. Walks the record stream from
    /// the top (footer-independent), CRC-checking every record; returns
    /// a typed error naming the byte offset on any corruption.
    pub fn scan(store: &mut impl ChunkStore) -> Result<Self> {
        let total = store.len();
        if total < 8 {
            return Err(Error::BagFormat(format!(
                "bag too short to scan ({total} bytes)"
            )));
        }
        let head = store.read_at(0, 8)?;
        if &head[..7] != format::MAGIC {
            return Err(Error::BagFormat("bad magic: not an AVBAG file".into()));
        }
        if head[7] != format::FORMAT_VERSION {
            return Err(Error::BagFormat(format!(
                "unsupported bag version {}",
                head[7]
            )));
        }

        let mut chunks = Vec::new();
        // conn_id → message timestamps, filled chunk by chunk; resolved
        // to topics once the trailing connection records arrive.
        let mut conn_times: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
        let mut connections: Vec<format::Connection> = Vec::new();
        let mut saw_footer = false;

        let mut off = 8u64;
        while off < total {
            let remaining = total - off;
            if remaining == format::FOOTER_LEN {
                let buf = store.read_at(off, format::FOOTER_LEN as usize)?;
                format::decode_footer(&buf).map_err(|_| {
                    Error::BagFormat(format!(
                        "trailing {} bytes at byte offset {off} are not a valid \
                         footer — truncated bag?",
                        format::FOOTER_LEN
                    ))
                })?;
                saw_footer = true;
                break;
            }
            // minimum stored record: type(1) + len(4) + crc(4)
            if remaining < 9 {
                return Err(Error::BagFormat(format!(
                    "bag truncated mid-record at byte offset {off}: only \
                     {remaining} byte(s) remain"
                )));
            }
            let head = store.read_at(off, 5)?;
            let rec_type = head[0];
            let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]) as u64;
            let stored = 9 + len;
            if off + stored > total {
                return Err(Error::BagFormat(format!(
                    "record type {rec_type} at byte offset {off} claims {stored} \
                     bytes but only {remaining} remain — truncated trailing chunk?"
                )));
            }
            let buf = store.read_at(off, stored as usize)?;
            let (t, payload, consumed) = format::decode_record(&buf).map_err(|e| {
                Error::BagFormat(format!("record at byte offset {off}: {e}"))
            })?;
            debug_assert_eq!(consumed as u64, stored);
            match t {
                format::REC_CHUNK => {
                    let msgs = format::decode_chunk(payload).map_err(|e| {
                        Error::BagFormat(format!("chunk at byte offset {off}: {e}"))
                    })?;
                    if msgs.is_empty() {
                        return Err(Error::BagFormat(format!(
                            "empty chunk (zero messages) at byte offset {off}"
                        )));
                    }
                    let start_time = msgs.iter().map(|m| m.time).min().unwrap();
                    let end_time = msgs.iter().map(|m| m.time).max().unwrap();
                    chunks.push(ChunkInfo {
                        offset: off,
                        stored_len: stored as u32,
                        start_time,
                        end_time,
                        message_count: msgs.len() as u32,
                    });
                    for m in &msgs {
                        conn_times.entry(m.conn_id).or_default().push(m.time.nanos);
                    }
                }
                format::REC_CONNECTION => {
                    let mut r = crate::util::bytes::ByteReader::new(payload);
                    connections.push(format::Connection::decode(&mut r).map_err(|e| {
                        Error::BagFormat(format!(
                            "connection record at byte offset {off}: {e}"
                        ))
                    })?);
                }
                // the footer index is redundant with this scan; skip it
                format::REC_INDEX => {}
                other => {
                    return Err(Error::BagFormat(format!(
                        "unknown record type {other} at byte offset {off}"
                    )))
                }
            }
            off += stored;
        }
        if !saw_footer {
            return Err(Error::BagFormat(format!(
                "bag ends at byte offset {off} without a footer — truncated bag?"
            )));
        }

        // resolve conn ids → topics and fold per-topic stats
        let mut topics: BTreeMap<String, TopicIndex> = BTreeMap::new();
        let mut times: Vec<u64> = Vec::new();
        for (conn_id, mut ts) in conn_times {
            let conn = connections
                .iter()
                .find(|c| c.conn_id == conn_id)
                .ok_or_else(|| {
                    Error::BagFormat(format!(
                        "chunk messages reference connection {conn_id} but the bag \
                         has no such connection record"
                    ))
                })?;
            ts.sort_unstable();
            let max_gap = ts.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0);
            times.extend_from_slice(&ts);
            let entry = topics.entry(conn.topic.clone()).or_insert_with(|| TopicIndex {
                messages: 0,
                type_name: conn.type_name.clone(),
                first: Time::from_nanos(*ts.first().unwrap()),
                last: Time::from_nanos(*ts.last().unwrap()),
                max_gap: 0,
            });
            entry.messages += ts.len() as u64;
            entry.first = entry.first.min(Time::from_nanos(*ts.first().unwrap()));
            entry.last = entry.last.max(Time::from_nanos(*ts.last().unwrap()));
            entry.max_gap = entry.max_gap.max(max_gap);
        }
        times.sort_unstable();
        Ok(Self {
            chunks,
            topics,
            messages: times.len() as u64,
            times,
            bytes: total,
        })
    }

    /// [`BagIndex::scan`] over a disk bag. An unopenable file is a
    /// typed error naming the path (the common operator mistake is a
    /// path that does not resolve on this host — see the data plane's
    /// `--publish` mode for shipping the bytes instead).
    pub fn scan_path(path: impl AsRef<Path>) -> Result<Self> {
        let p = path.as_ref();
        let mut store = super::chunked_file::DiskChunkedFile::open(p).map_err(|e| {
            Error::Storage(format!("bag '{}': {e}", p.display()))
        })?;
        Self::scan(&mut store)
    }

    /// Bag time span (first, last message timestamp), `None` when empty.
    pub fn time_range(&self) -> Option<(Time, Time)> {
        Some((
            Time::from_nanos(*self.times.first()?),
            Time::from_nanos(*self.times.last()?),
        ))
    }

    /// Timeline cut points for `slices` message-balanced slices:
    /// `k+1` ascending nanosecond boundaries (first = first message
    /// time, last = last message time + 1, i.e. exclusive), where
    /// `k ≤ slices` (equal timestamps can merge adjacent cuts). A pure
    /// function of the bag's message timestamps — independent of chunk
    /// layout, worker count, and backend. Empty bag ⇒ empty vec.
    pub fn cut_points(&self, slices: usize) -> Vec<u64> {
        let Some((first, last)) = self.time_range() else {
            return Vec::new();
        };
        let n = slices.max(1).min(self.times.len());
        let mut cuts = Vec::with_capacity(n + 1);
        cuts.push(first.nanos);
        for k in 1..n {
            let t = self.times[self.times.len() * k / n];
            if t > *cuts.last().unwrap() && t <= last.nanos {
                cuts.push(t);
            }
        }
        cuts.push(last.nanos + 1);
        cuts
    }

    /// The warm-up prefix an overlapping slice needs so that, for every
    /// selected topic (empty = all), the predecessor of the slice's
    /// first in-window message falls inside the warm-up window: the max
    /// per-topic inter-message gap. Replay state that depends on one
    /// previous message (odometry scan pairs, latency gaps) is then
    /// guaranteed to converge before the slice's own window starts.
    pub fn min_warmup(&self, topics: &[String]) -> Duration {
        let gap = self
            .topics
            .iter()
            .filter(|(name, _)| topics.is_empty() || topics.contains(*name))
            .map(|(_, t)| t.max_gap)
            .max()
            .unwrap_or(0);
        Duration::from_nanos(gap)
    }

    /// Messages recorded on `topic` (0 when absent).
    pub fn topic_messages(&self, topic: &str) -> u64 {
        self.topics.get(topic).map(|t| t.messages).unwrap_or(0)
    }

    /// Total messages on the selected topics (empty = all).
    pub fn selected_messages(&self, topics: &[String]) -> u64 {
        if topics.is_empty() {
            self.messages
        } else {
            topics.iter().map(|t| self.topic_messages(t)).sum()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bag::format::Compression;
    use crate::bag::memory::MemoryChunkedFile;
    use crate::bag::writer::BagWriter;
    use crate::msg::{Image, Message, PointCloud};

    /// 2 topics, small chunks so the bag has several chunk records.
    fn build_bag() -> MemoryChunkedFile {
        let mut w = BagWriter::new(MemoryChunkedFile::new(), Compression::None, 2048).unwrap();
        for i in 0..20u64 {
            if i % 2 == 0 {
                w.write("/camera", Time::from_nanos(i * 100), &Image::synthetic(8, 8, i))
                    .unwrap();
            } else {
                w.write("/lidar", Time::from_nanos(i * 100), &PointCloud::synthetic(16, i))
                    .unwrap();
            }
        }
        w.finish().unwrap()
    }

    #[test]
    fn scan_matches_bag_contents() {
        let mut store = build_bag();
        let idx = BagIndex::scan(&mut store).unwrap();
        assert_eq!(idx.messages, 20);
        assert!(idx.chunks.len() >= 2, "expected several chunks");
        assert_eq!(idx.topics.len(), 2);
        let cam = &idx.topics["/camera"];
        assert_eq!(cam.messages, 10);
        assert_eq!(cam.type_name, Image::TYPE_NAME);
        assert_eq!(cam.first, Time::from_nanos(0));
        assert_eq!(cam.last, Time::from_nanos(1800));
        assert_eq!(cam.max_gap, 200, "camera messages every 200 ns");
        assert_eq!(idx.time_range().unwrap(), (Time::from_nanos(0), Time::from_nanos(1900)));
        assert_eq!(idx.min_warmup(&[]).as_nanos(), 200);
        assert_eq!(idx.selected_messages(&["/camera".into()]), 10);
        // chunk info must agree with the reader's footer index
        let r = crate::bag::BagReader::open(store).unwrap();
        assert_eq!(idx.messages, r.message_count());
    }

    #[test]
    fn cut_points_are_balanced_and_cover_the_timeline() {
        let mut store = build_bag();
        let idx = BagIndex::scan(&mut store).unwrap();
        for n in [1usize, 2, 4, 7] {
            let cuts = idx.cut_points(n);
            assert!(cuts.len() >= 2 && cuts.len() <= n + 1, "{n}: {cuts:?}");
            assert!(cuts.windows(2).all(|w| w[0] < w[1]), "{cuts:?} not ascending");
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap(), 1901, "exclusive end");
            // every message falls in exactly one [cuts[i], cuts[i+1])
            let covered: u64 = cuts
                .windows(2)
                .map(|w| {
                    idx.times.iter().filter(|&&t| t >= w[0] && t < w[1]).count() as u64
                })
                .sum();
            assert_eq!(covered, idx.messages);
        }
    }

    #[test]
    fn empty_chunk_is_a_typed_error_with_offset() {
        // handcraft: magic + a chunk record with zero messages + footer
        let mut bytes = Vec::new();
        bytes.extend_from_slice(format::MAGIC);
        bytes.push(format::FORMAT_VERSION);
        let chunk = format::encode_chunk(&[], Compression::None).unwrap();
        let chunk_off = bytes.len();
        bytes.extend_from_slice(&chunk);
        bytes.extend_from_slice(&format::encode_footer(8, 0));
        let mut store = MemoryChunkedFile::from_bytes(&bytes);
        let err = BagIndex::scan(&mut store).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::BagFormat(_)), "{msg}");
        assert!(msg.contains("empty chunk"), "{msg}");
        assert!(msg.contains(&format!("byte offset {chunk_off}")), "{msg}");
    }

    #[test]
    fn truncated_trailing_chunk_is_a_typed_error_with_offset() {
        let full = build_bag().to_vec();
        let idx = {
            let mut store = MemoryChunkedFile::from_bytes(&full);
            BagIndex::scan(&mut store).unwrap()
        };
        // cut the file in the middle of the last chunk record
        let last = idx.chunks.last().unwrap();
        let cut = (last.offset + last.stored_len as u64 / 2) as usize;
        let mut store = MemoryChunkedFile::from_bytes(&full[..cut]);
        let err = BagIndex::scan(&mut store).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::BagFormat(_)), "{msg}");
        assert!(msg.contains("truncated"), "{msg}");
        assert!(msg.contains("byte offset"), "{msg}");
    }

    #[test]
    fn bag_without_footer_is_rejected() {
        // records intact but footer missing entirely
        let full = build_bag().to_vec();
        let cut = full.len() - format::FOOTER_LEN as usize;
        let mut store = MemoryChunkedFile::from_bytes(&full[..cut]);
        let err = BagIndex::scan(&mut store).unwrap_err();
        assert!(err.to_string().contains("footer"), "{err}");
    }

    #[test]
    fn corrupt_chunk_crc_is_rejected_with_offset() {
        let mut full = build_bag().to_vec();
        let idx_of_payload = {
            let mut store = MemoryChunkedFile::from_bytes(&full);
            BagIndex::scan(&mut store).unwrap().chunks[0].offset as usize + 6
        };
        full[idx_of_payload] ^= 0xff;
        let mut store = MemoryChunkedFile::from_bytes(&full);
        let err = BagIndex::scan(&mut store).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("CRC"), "{msg}");
        assert!(msg.contains("byte offset"), "{msg}");
    }

    #[test]
    fn garbage_is_rejected() {
        let mut store = MemoryChunkedFile::from_bytes(&[7u8; 64]);
        assert!(BagIndex::scan(&mut store).is_err());
    }
}
