//! AVBAG — the platform's rosbag analogue (paper §2.1, §3.2).
//!
//! Two-tier design mirroring the paper's Fig 2: the upper `Bag` layer
//! ([`BagWriter`] / [`BagReader`]) understands records, chunks,
//! connections and the index; the lower layer is the [`ChunkStore`]
//! byte-storage trait with a disk implementation ([`DiskChunkedFile`])
//! and the in-memory cache implementation ([`MemoryChunkedFile`]) that
//! Fig 6 benchmarks against each other.

pub mod cache;
pub mod chunked_file;
pub mod format;
pub mod index;
pub mod memory;
pub mod reader;
pub mod writer;

pub use cache::BagCache;
pub use chunked_file::{ChunkStore, DiskChunkedFile};
pub use format::{Compression, Connection};
pub use index::{BagIndex, TopicIndex};
pub use memory::MemoryChunkedFile;
pub use reader::{BagReader, PlayedMessage};
pub use writer::BagWriter;

use crate::error::Result;
use crate::msg::Time;
use std::path::Path;

/// Convenience: open a disk bag for reading.
pub fn open_disk(path: impl AsRef<Path>) -> Result<BagReader<DiskChunkedFile>> {
    BagReader::open(DiskChunkedFile::open(path)?)
}

/// Convenience: create a disk bag writer with default chunking.
pub fn create_disk(path: impl AsRef<Path>) -> Result<BagWriter<DiskChunkedFile>> {
    BagWriter::new(DiskChunkedFile::create(path)?, Compression::None, 4 * 1024 * 1024)
}

/// Convenience: build an in-memory bag from (topic, type, time, payload)
/// tuples — used heavily by tests and the pipe.
pub fn build_memory_bag(
    msgs: impl IntoIterator<Item = (String, String, Time, Vec<u8>)>,
) -> Result<MemoryChunkedFile> {
    let mut w = BagWriter::new(MemoryChunkedFile::new(), Compression::None, 1 << 20)?;
    for (topic, ty, time, data) in msgs {
        w.write_raw(&topic, &ty, time, data)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::msg::{Image, Message};

    #[test]
    fn disk_bag_end_to_end() {
        let dir = std::env::temp_dir().join("av_simd_test_bagmod");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("e2e_{}.bag", std::process::id()));
        {
            let mut w = create_disk(&p).unwrap();
            for i in 0..5u64 {
                w.write("/camera", Time::from_nanos(i), &Image::synthetic(4, 4, i)).unwrap();
            }
            w.finish().unwrap();
        }
        let mut r = open_disk(&p).unwrap();
        let msgs = r.play(None).unwrap();
        assert_eq!(msgs.len(), 5);
        assert_eq!(msgs[2].decode_as::<Image>().unwrap(), Image::synthetic(4, 4, 2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn memory_and_disk_bags_are_byte_identical() {
        // The same writes through either ChunkStore must produce the same
        // bytes — the Fig 6 comparison is *only* about the I/O medium.
        let write_into = |store_is_mem: bool| -> Vec<u8> {
            let dir = std::env::temp_dir().join("av_simd_test_bagmod");
            std::fs::create_dir_all(&dir).unwrap();
            let p = dir.join(format!("ident_{}.bag", std::process::id()));
            let msgs: Vec<_> = (0..8u64)
                .map(|i| {
                    (
                        "/camera".to_string(),
                        Image::TYPE_NAME.to_string(),
                        Time::from_nanos(i),
                        Image::synthetic(4, 4, i).encode(),
                    )
                })
                .collect();
            if store_is_mem {
                build_memory_bag(msgs).unwrap().to_vec()
            } else {
                let mut w = BagWriter::new(
                    DiskChunkedFile::create(&p).unwrap(),
                    Compression::None,
                    1 << 20,
                )
                .unwrap();
                for (t, ty, tm, d) in msgs {
                    w.write_raw(&t, &ty, tm, d).unwrap();
                }
                w.finish().unwrap();
                let v = std::fs::read(&p).unwrap();
                std::fs::remove_file(&p).ok();
                v
            }
        };
        assert_eq!(write_into(true), write_into(false));
    }
}
