//! The lower layer of the bag format: [`ChunkStore`] — the paper's
//! `ChunkedFile` abstraction (Fig 2).
//!
//! The upper `Bag` layer (writer/reader) only ever talks to this trait, so
//! swapping the disk-backed implementation for the in-memory one
//! ([`super::memory::MemoryChunkedFile`]) changes *nothing* above it —
//! exactly the paper's §3.2 design where `MemoryChunkedFile` "inherits
//! from the ChunkedFile class and overrides all the methods".

use crate::error::{Error, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Byte-level storage for a bag: append-only writes plus random reads.
pub trait ChunkStore: Send {
    /// Append `data`, returning the offset it was written at.
    fn append(&mut self, data: &[u8]) -> Result<u64>;

    /// Read exactly `len` bytes starting at `offset`.
    fn read_at(&mut self, offset: u64, len: usize) -> Result<Vec<u8>>;

    /// Read exactly `len` bytes starting at `offset` into `out`
    /// (cleared first) — the zero-copy fetch path: a reader reuses one
    /// envelope buffer across every chunk it replays instead of taking
    /// a fresh allocation per read. The default delegates to
    /// [`ChunkStore::read_at`]; backends override it to fill `out`
    /// directly.
    fn read_at_into(&mut self, offset: u64, len: usize, out: &mut Vec<u8>) -> Result<()> {
        let buf = self.read_at(offset, len)?;
        out.clear();
        out.extend_from_slice(&buf);
        Ok(())
    }

    /// Total bytes stored.
    fn len(&self) -> u64;

    /// True when nothing has been stored.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flush buffered writes to the backing medium.
    fn flush(&mut self) -> Result<()>;

    /// Human-readable backend name ("disk" / "memory"), used by benches.
    fn backend(&self) -> &'static str;
}

/// Any `&mut S` is itself a store — lets callers keep ownership while a
/// `BagReader`/`BagWriter` borrows it (e.g. replaying one in-memory bag
/// many times without copying).
impl<S: ChunkStore> ChunkStore for &mut S {
    fn append(&mut self, data: &[u8]) -> Result<u64> {
        (**self).append(data)
    }

    fn read_at(&mut self, offset: u64, len: usize) -> Result<Vec<u8>> {
        (**self).read_at(offset, len)
    }

    fn read_at_into(&mut self, offset: u64, len: usize, out: &mut Vec<u8>) -> Result<()> {
        (**self).read_at_into(offset, len, out)
    }

    fn len(&self) -> u64 {
        (**self).len()
    }

    fn flush(&mut self) -> Result<()> {
        (**self).flush()
    }

    fn backend(&self) -> &'static str {
        (**self).backend()
    }
}

/// Disk-backed store — the paper's original `ChunkedFile`. Writes go
/// through a buffered writer; reads reopen a read handle at the requested
/// offset. `O_DIRECT`-style cache bypass is not portable, so the Fig 6
/// disk baseline additionally fsyncs on flush
/// ([`DiskChunkedFile::set_sync_on_flush`]) to make the disk path honest.
pub struct DiskChunkedFile {
    path: PathBuf,
    writer: Option<BufWriter<File>>,
    reader: Option<File>,
    len: u64,
    /// fsync on every flush (used by the write benchmark for honesty).
    sync_on_flush: bool,
}

impl DiskChunkedFile {
    /// Create (truncate) a bag file for writing.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let f = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(Self {
            path,
            writer: Some(BufWriter::with_capacity(256 * 1024, f)),
            reader: None,
            len: 0,
            sync_on_flush: false,
        })
    }

    /// Open an existing bag file for reading.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let f = File::open(&path)?;
        let len = f.metadata()?.len();
        Ok(Self { path, writer: None, reader: Some(f), len, sync_on_flush: false })
    }

    /// Enable fsync-on-flush (disk benchmark honesty knob).
    pub fn set_sync_on_flush(&mut self, on: bool) {
        self.sync_on_flush = on;
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn ensure_reader(&mut self) -> Result<&mut File> {
        if self.reader.is_none() {
            self.reader = Some(File::open(&self.path)?);
        }
        Ok(self.reader.as_mut().unwrap())
    }
}

impl ChunkStore for DiskChunkedFile {
    fn append(&mut self, data: &[u8]) -> Result<u64> {
        let w = self
            .writer
            .as_mut()
            .ok_or_else(|| Error::Io(std::io::Error::other("bag opened read-only")))?;
        let offset = self.len;
        w.write_all(data)?;
        self.len += data.len() as u64;
        // Invalidate the read handle's view (it may have a stale length).
        self.reader = None;
        Ok(offset)
    }

    fn read_at(&mut self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.read_at_into(offset, len, &mut buf)?;
        Ok(buf)
    }

    fn read_at_into(&mut self, offset: u64, len: usize, out: &mut Vec<u8>) -> Result<()> {
        // Reads must observe buffered writes.
        if let Some(w) = self.writer.as_mut() {
            w.flush()?;
        }
        let r = self.ensure_reader()?;
        r.seek(SeekFrom::Start(offset))?;
        out.clear();
        out.resize(len, 0);
        r.read_exact(out).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Error::Corrupt(format!("bag truncated at offset {offset} (+{len})"))
            } else {
                Error::Io(e)
            }
        })
    }

    fn len(&self) -> u64 {
        self.len
    }

    fn flush(&mut self) -> Result<()> {
        if let Some(w) = self.writer.as_mut() {
            w.flush()?;
            if self.sync_on_flush {
                w.get_ref().sync_data()?;
            }
        }
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "disk"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("av_simd_test_chunked");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}_{}", std::process::id()))
    }

    #[test]
    fn append_then_read_back() {
        let p = tmp("rw.bag");
        let mut f = DiskChunkedFile::create(&p).unwrap();
        let o1 = f.append(b"hello").unwrap();
        let o2 = f.append(b"world!").unwrap();
        assert_eq!(o1, 0);
        assert_eq!(o2, 5);
        assert_eq!(f.len(), 11);
        assert_eq!(f.read_at(0, 5).unwrap(), b"hello");
        assert_eq!(f.read_at(5, 6).unwrap(), b"world!");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn read_past_end_is_corrupt() {
        let p = tmp("short.bag");
        let mut f = DiskChunkedFile::create(&p).unwrap();
        f.append(b"abc").unwrap();
        assert!(matches!(f.read_at(1, 10), Err(Error::Corrupt(_))));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reopen_for_read() {
        let p = tmp("reopen.bag");
        {
            let mut f = DiskChunkedFile::create(&p).unwrap();
            f.append(b"persisted").unwrap();
            f.flush().unwrap();
        }
        let mut f = DiskChunkedFile::open(&p).unwrap();
        assert_eq!(f.len(), 9);
        assert_eq!(f.read_at(0, 9).unwrap(), b"persisted");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn readonly_append_fails() {
        let p = tmp("ro.bag");
        {
            let mut f = DiskChunkedFile::create(&p).unwrap();
            f.append(b"x").unwrap();
            f.flush().unwrap();
        }
        let mut f = DiskChunkedFile::open(&p).unwrap();
        assert!(f.append(b"y").is_err());
        std::fs::remove_file(&p).ok();
    }
}
