//! `MemoryChunkedFile` — the paper's §3.2 contribution: a drop-in
//! replacement for the disk-backed `ChunkedFile` that keeps the whole bag
//! in RAM, so ROSBag play reads and record writes never touch disk I/O.
//!
//! Storage is a list of fixed-size pages rather than one `Vec<u8>` so
//! appends never copy previously written data (a 1 GiB bag would otherwise
//! pay repeated realloc-copies), mirroring the "chunked" nature of the
//! original class.

use super::chunked_file::ChunkStore;
use crate::error::{Error, Result};
use std::path::Path;

const PAGE_SIZE: usize = 1 << 20; // 1 MiB pages

/// In-memory bag storage.
pub struct MemoryChunkedFile {
    pages: Vec<Box<[u8; PAGE_SIZE]>>,
    len: u64,
}

impl Default for MemoryChunkedFile {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryChunkedFile {
    /// Empty in-memory file.
    pub fn new() -> Self {
        Self { pages: Vec::new(), len: 0 }
    }

    /// Load a bag file from disk into memory (cache warm-up path).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let data = std::fs::read(path.as_ref())?;
        let mut f = Self::new();
        f.append(&data)?;
        Ok(f)
    }

    /// Wrap an existing byte buffer (zero-setup for tests and the pipe).
    pub fn from_bytes(data: &[u8]) -> Self {
        let mut f = Self::new();
        f.append(data).expect("memory append is infallible");
        f
    }

    /// Persist the in-memory bag to disk (cache write-back path).
    pub fn persist(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        use std::io::Write;
        let mut remaining = self.len as usize;
        for page in &self.pages {
            let take = remaining.min(PAGE_SIZE);
            out.write_all(&page[..take])?;
            remaining -= take;
        }
        out.flush()?;
        Ok(())
    }

    /// Copy the full contents out as one contiguous buffer.
    pub fn to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len as usize);
        let mut remaining = self.len as usize;
        for page in &self.pages {
            let take = remaining.min(PAGE_SIZE);
            out.extend_from_slice(&page[..take]);
            remaining -= take;
        }
        out
    }

    /// Bytes of RAM currently held (page-granular).
    pub fn capacity_bytes(&self) -> u64 {
        (self.pages.len() * PAGE_SIZE) as u64
    }
}

impl ChunkStore for MemoryChunkedFile {
    fn append(&mut self, data: &[u8]) -> Result<u64> {
        let offset = self.len;
        let mut src = data;
        while !src.is_empty() {
            let page_off = (self.len as usize) % PAGE_SIZE;
            if page_off == 0 && self.len as usize / PAGE_SIZE == self.pages.len() {
                // Zeroed page allocation; avoids Box<[u8; N]> stack copy.
                let page = vec![0u8; PAGE_SIZE].into_boxed_slice();
                let page: Box<[u8; PAGE_SIZE]> =
                    page.try_into().expect("page size fixed");
                self.pages.push(page);
            }
            let page = self.pages.last_mut().unwrap();
            let take = src.len().min(PAGE_SIZE - page_off);
            page[page_off..page_off + take].copy_from_slice(&src[..take]);
            self.len += take as u64;
            src = &src[take..];
        }
        Ok(offset)
    }

    fn read_at(&mut self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.read_at_into(offset, len, &mut out)?;
        Ok(out)
    }

    fn read_at_into(&mut self, offset: u64, len: usize, out: &mut Vec<u8>) -> Result<()> {
        // checked: a corrupt index can carry offsets near u64::MAX, and a
        // wrapped sum here would pass the bound and panic on page lookup
        if offset.checked_add(len as u64).is_none_or(|end| end > self.len) {
            return Err(Error::Corrupt(format!(
                "memory bag read past end: offset {offset} + {len} > {}",
                self.len
            )));
        }
        out.clear();
        out.reserve(len);
        let mut pos = offset as usize;
        let mut remaining = len;
        while remaining > 0 {
            let page = &self.pages[pos / PAGE_SIZE];
            let page_off = pos % PAGE_SIZE;
            let take = remaining.min(PAGE_SIZE - page_off);
            out.extend_from_slice(&page[page_off..page_off + take]);
            pos += take;
            remaining -= take;
        }
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len
    }

    fn flush(&mut self) -> Result<()> {
        Ok(()) // nothing to flush — that's the point
    }

    fn backend(&self) -> &'static str {
        "memory"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_within_page() {
        let mut f = MemoryChunkedFile::new();
        f.append(b"hello").unwrap();
        f.append(b" world").unwrap();
        assert_eq!(f.len(), 11);
        assert_eq!(f.read_at(0, 11).unwrap(), b"hello world");
        assert_eq!(f.read_at(6, 5).unwrap(), b"world");
    }

    #[test]
    fn crosses_page_boundaries() {
        let mut f = MemoryChunkedFile::new();
        let blob: Vec<u8> = (0..(PAGE_SIZE * 2 + 123)).map(|i| (i % 251) as u8).collect();
        f.append(&blob).unwrap();
        assert_eq!(f.len() as usize, blob.len());
        // read spanning the first page boundary
        let r = f.read_at((PAGE_SIZE - 10) as u64, 20).unwrap();
        assert_eq!(&r, &blob[PAGE_SIZE - 10..PAGE_SIZE + 10]);
        assert_eq!(f.to_vec(), blob);
    }

    #[test]
    fn read_past_end_rejected() {
        let mut f = MemoryChunkedFile::from_bytes(b"abc");
        assert!(f.read_at(1, 5).is_err());
    }

    #[test]
    fn persist_and_load_roundtrip() {
        let dir = std::env::temp_dir().join("av_simd_test_memchunk");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("m_{}.bag", std::process::id()));
        let blob: Vec<u8> = (0..50_000).map(|i| (i * 7 % 256) as u8).collect();
        let f = MemoryChunkedFile::from_bytes(&blob);
        f.persist(&p).unwrap();
        let mut g = MemoryChunkedFile::load(&p).unwrap();
        assert_eq!(g.len() as usize, blob.len());
        assert_eq!(g.read_at(0, blob.len()).unwrap(), blob);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn backend_name() {
        assert_eq!(MemoryChunkedFile::new().backend(), "memory");
    }
}
